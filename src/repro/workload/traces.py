"""Real-workload-derived traces (§6, Fig. 6).

The AutoScale paper's workloads report only per-minute average request
rates over an hour. Following the paper, we re-scale the peak to a target
max throughput and synthesize inter-arrivals by sampling a Gamma(CV=1)
process for each constant-rate segment.

Two canonical shapes are bundled, mirroring Fig. 6:
  * "big_spike"  — a diurnal-ish baseline with one large sustained spike.
  * "dual_phase" — slow rise, instantaneous spike, then rapid fall-off.
"""

from __future__ import annotations

import numpy as np

# Per-minute mean rates, unit-normalized (max = 1.0). 60 entries = 1 hour.
_BIG_SPIKE = np.array(
    [0.28, 0.27, 0.29, 0.30, 0.28, 0.30, 0.31, 0.30, 0.32, 0.33,
     0.32, 0.34, 0.35, 0.34, 0.36, 0.38, 0.37, 0.39, 0.40, 0.42,
     0.45, 0.55, 0.75, 0.92, 1.00, 0.97, 0.90, 0.78, 0.62, 0.50,
     0.44, 0.41, 0.40, 0.39, 0.38, 0.37, 0.38, 0.36, 0.35, 0.36,
     0.35, 0.34, 0.35, 0.33, 0.34, 0.33, 0.32, 0.33, 0.32, 0.31,
     0.32, 0.31, 0.30, 0.31, 0.30, 0.29, 0.30, 0.29, 0.28, 0.29])

_DUAL_PHASE = np.array(
    [0.20, 0.21, 0.22, 0.24, 0.26, 0.28, 0.30, 0.33, 0.36, 0.39,
     0.42, 0.46, 0.50, 0.54, 0.58, 0.62, 0.66, 0.94, 1.00, 0.96,
     0.90, 0.82, 0.74, 0.66, 0.58, 0.50, 0.43, 0.37, 0.31, 0.26,
     0.22, 0.19, 0.16, 0.14, 0.12, 0.11, 0.10, 0.09, 0.09, 0.08,
     0.08, 0.07, 0.07, 0.07, 0.06, 0.06, 0.06, 0.06, 0.05, 0.05,
     0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05, 0.05])

_SHAPES = {"big_spike": _BIG_SPIKE, "dual_phase": _DUAL_PHASE}


def autoscale_derived_trace(
    shape: str = "big_spike",
    max_qps: float = 300.0,
    segment_s: float = 30.0,
    cv: float = 1.0,
    seed: int = 0,
) -> np.ndarray:
    """Synthesize a full inter-arrival trace from a per-minute rate shape.

    Follows §6: iterate through the mean rates, re-scaled so the max is
    ``max_qps``, sampling Gamma(cv) inter-arrivals for ``segment_s``
    seconds per entry.
    """
    try:
        rates = _SHAPES[shape] * max_qps
    except KeyError:
        raise KeyError(f"unknown trace shape {shape!r}; have {sorted(_SHAPES)}")
    rng = np.random.default_rng(seed)
    k = 1.0 / cv
    out = []
    t0 = 0.0
    for lam in rates:
        if lam > 1e-9:
            theta = cv / lam
            n_est = int(lam * segment_s * 1.6) + 32
            gaps = rng.gamma(k, theta, size=n_est)
            t = np.cumsum(gaps)
            while t[-1] < segment_s:
                t = np.concatenate(
                    [t, t[-1] + np.cumsum(rng.gamma(k, theta, size=n_est))])
            out.append(t0 + t[t < segment_s])
        t0 += segment_s
    return np.concatenate(out) if out else np.zeros(0)


def split_plan_serve(arrivals: np.ndarray, plan_frac: float = 0.25
                     ) -> tuple[np.ndarray, np.ndarray]:
    """First `plan_frac` of the trace for the Planner, rest for live serving
    (§6: "first 25% ... as the sample for the Planner")."""
    if arrivals.size == 0:
        return arrivals, arrivals
    t_cut = float(arrivals.max()) * plan_frac
    head = arrivals[arrivals < t_cut]
    tail = arrivals[arrivals >= t_cut] - t_cut
    return head, tail
