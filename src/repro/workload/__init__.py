from repro.workload.generator import (  # noqa: F401
    gamma_trace,
    time_varying_trace,
    cv_ramp_trace,
    rate_ramp_trace,
)
from repro.workload.slo_classes import (  # noqa: F401
    ClassedTrace,
    SLOClass,
    classed_trace,
)
from repro.workload.traces import autoscale_derived_trace  # noqa: F401
