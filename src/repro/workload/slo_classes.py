"""Class-tagged arrival traces for mixed per-query SLO workloads.

Production pipelines serve interactive and batch traffic side by side:
an interactive class with a tight end-to-end deadline and a bulk class
that tolerates seconds of latency, sharing one replica fleet. A
:class:`SLOClass` names one such traffic class (its own arrival rate,
burstiness, and latency SLO); :func:`classed_trace` samples each class's
Gamma arrival process independently and interleaves them into a single
sorted arrival stream with an aligned per-query class-id array.

The resulting :class:`ClassedTrace` is what flows end-to-end through the
stack: ``slo_per_query``/``deadline`` feed the engine's deadline-aware
queueing policies (:mod:`repro.sim.queueing`), ``class_ids`` lets
:class:`repro.sim.SimResult` report per-class latency/miss/drop
breakdowns, and ``Planner.plan_classed`` provisions against the
multi-class feasibility objective (every class meets its own percentile
deadline).

Determinism contract: class ``i`` is sampled with ``seed + i``, so a
single-class trace is *bit-identical* to ``gamma_trace(..., seed=seed)``
— the golden-equivalence guard in ``tests/test_slo_classes.py`` pins the
whole classed path to the seed engine through this property.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.workload.generator import gamma_trace


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One traffic class: its arrival process and latency objective."""

    name: str
    lam: float                     # mean arrival rate (queries/s)
    cv: float                      # inter-arrival coefficient of variation
    slo_s: float                   # end-to-end latency SLO (seconds)

    def __post_init__(self):
        if self.lam < 0 or self.cv <= 0 or self.slo_s <= 0:
            raise ValueError(f"bad SLOClass {self}")


@dataclasses.dataclass
class ClassedTrace:
    """A merged arrival stream with per-query class tags.

    ``arrivals`` is sorted ascending; ``class_ids[q]`` indexes into
    ``classes`` for query ``q``.
    """

    arrivals: np.ndarray           # (n,) merged sorted arrival times
    class_ids: np.ndarray          # (n,) int index into `classes`
    classes: Tuple[SLOClass, ...]

    @property
    def n(self) -> int:
        return int(self.arrivals.shape[0])

    @property
    def class_names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.classes)

    @property
    def slo_per_query(self) -> np.ndarray:
        """(n,) per-query SLO in seconds — the engine's `slo_s` vector."""
        slos = np.asarray([c.slo_s for c in self.classes], dtype=np.float64)
        return slos[self.class_ids]

    @property
    def deadline(self) -> np.ndarray:
        """(n,) absolute completion deadlines (arrival + class SLO)."""
        return self.arrivals + self.slo_per_query

    @property
    def min_slo_s(self) -> float:
        return min(c.slo_s for c in self.classes)

    def mask(self, name: str) -> np.ndarray:
        """(n,) bool mask selecting queries of the named class."""
        return self.class_ids == self.class_names.index(name)

    def counts(self) -> Dict[str, int]:
        return {c.name: int((self.class_ids == i).sum())
                for i, c in enumerate(self.classes)}


def classed_trace(classes: Sequence[SLOClass], duration_s: float,
                  seed: int = 0, t0: float = 0.0) -> ClassedTrace:
    """Interleave independent Gamma streams, one per class.

    Class ``i`` uses ``seed + i``, so a one-class trace reproduces
    ``gamma_trace(lam, cv, duration_s, seed)`` exactly (see module
    docstring). Ties between classes break by class order (stable merge),
    which keeps repeat calls deterministic.
    """
    if not classes:
        raise ValueError("need at least one SLOClass")
    names = [c.name for c in classes]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate class names: {names}")
    parts, ids = [], []
    for i, c in enumerate(classes):
        t = gamma_trace(c.lam, c.cv, duration_s, seed=seed + i, t0=t0)
        parts.append(t)
        ids.append(np.full(t.shape[0], i, dtype=np.int64))
    arrivals = np.concatenate(parts) if parts else np.zeros(0)
    class_ids = np.concatenate(ids) if ids else np.zeros(0, dtype=np.int64)
    order = np.argsort(arrivals, kind="stable")
    return ClassedTrace(arrivals[order], class_ids[order], tuple(classes))
