"""Runtime-agnostic control-plane interface (extracted from
:mod:`repro.sim.control`).

InferLine's Tuner is a controller over an abstract serving runtime: it
consumes per-epoch telemetry (:class:`repro.sim.result.EpochTelemetry`)
and emits :class:`ControlEvent` s — replica scale-ups/downs, admission
control (slo-drop shed margins), and queueing-policy switches. TWO loop
drivers speak this interface with identical semantics:

* :class:`repro.sim.control.ControlLoopSession` — epoch-stepped
  co-simulation over the cone-memoized trace session;
* :class:`repro.serving.loop.LiveControlLoop` — wall-clock serving on
  the thread-pool :class:`~repro.serving.executor.PipelineExecutor`.

A controller written against ``step(EpochTelemetry) -> [ControlEvent]``
(the :class:`~repro.core.tuner.ClosedLoopTuner`, the
:class:`~repro.core.tuner.OpenLoopTunerController` adapter, or the
:class:`ScheduleController` below) therefore drives simulated queues and
real threads interchangeably — the sim<->real fidelity harness
(``benchmarks/bench_live_loop.py``) runs the same controller against
both backends on the same trace.

This module also hosts the shared cost accounting:
:func:`replica_cost_timeline` (the $/hr step function of a run's replica
schedule) and :func:`integrate_cost` (its time integral, guarded against
degenerate empty timelines).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core.hardware import get_hardware
from repro.core.pipeline import Pipeline, PipelineConfig

# Event-stream aliases shared by both loop drivers.
ReplicaSchedules = Dict[str, List[Tuple[float, int]]]
ShedSchedules = Dict[str, List[Tuple[float, float]]]
PolicySchedules = Dict[str, List[Tuple[float, str]]]

CONTROL_EVENT_KINDS = ("up", "down", "shed", "policy")


@dataclasses.dataclass(frozen=True)
class ControlEvent:
    """One controller decision.

    ``kind``:
    * ``"up"``     — add ``int(value)`` replicas to ``stage`` (value > 0)
    * ``"down"``   — retire ``int(-value)`` replicas (value < 0); the
      runtime drains them (an in-service batch always completes)
    * ``"shed"``   — set the stage's slo-drop shed margin to ``value``
      seconds from ``t_effective`` on (see repro.core.policy)
    * ``"policy"`` — switch the stage's queueing policy to ``policy``
      (fifo/edf/slo-drop) from ``t_effective`` on; ``value`` is unused
    """

    t: float                 # decision time (the epoch boundary)
    t_effective: float       # when the event lands in the runtime
    stage: str
    kind: str                # one of CONTROL_EVENT_KINDS
    value: float
    policy: Optional[str] = None   # kind == "policy" only

    def as_record(self) -> Dict[str, object]:
        rec: Dict[str, object] = {
            "t": self.t, "t_effective": self.t_effective,
            "stage": self.stage, "kind": self.kind, "value": self.value}
        if self.policy is not None:
            rec["policy"] = self.policy
        return rec


class Controller(Protocol):
    """What both loop drivers require of a controller."""

    def step(self, tele) -> List[ControlEvent]:
        """Consume one EpochTelemetry record; return the events to apply."""
        ...


class NoOpController:
    """Feedback disabled: never issues an event (the open-loop guard)."""

    def step(self, tele) -> List[ControlEvent]:
        del tele
        return []


class ScheduleController:
    """Replays a pre-planned event list through either loop driver.

    Events fire at the first epoch boundary at/after their decision time
    ``t`` (with ``t_effective`` re-clamped to stay causal), which makes
    any schedule — including mid-run fifo->edf policy switches —
    expressible as ordinary control events rather than a separate
    configuration channel. The per-epoch policy-switching follow-up from
    the co-simulation PR lands through exactly this path.
    """

    def __init__(self, events: Sequence[ControlEvent]):
        self.pending = sorted(events, key=lambda e: e.t)
        self._i = 0

    def step(self, tele) -> List[ControlEvent]:
        now = tele.t_end
        out: List[ControlEvent] = []
        while self._i < len(self.pending) and self.pending[self._i].t <= now:
            ev = self.pending[self._i]
            self._i += 1
            if ev.t_effective < now:       # keep the replay causal
                ev = dataclasses.replace(ev, t=now, t_effective=now)
            out.append(ev)
        return out


def fold_control_event(
    ev: ControlEvent,
    stages: Sequence[str],
    now: float,
    replica_schedules: ReplicaSchedules,
    shed_schedules: ShedSchedules,
    policy_schedules: PolicySchedules,
) -> None:
    """Validate one event and fold it into the per-stage schedule streams.

    Shared by the co-simulation loop and (for record-keeping) the live
    loop, so both enforce the same contract: events must target known
    stages, carry a known kind, and land causally (``t_effective`` at or
    after the deciding boundary). Each stream stays time-sorted — the
    replica pool and the piecewise schedules all assume sorted input.
    """
    if ev.stage not in stages:
        raise ValueError(f"control event for unknown stage {ev.stage!r}")
    if ev.t_effective < now - 1e-9:
        raise ValueError(f"acausal control event: decided at {now}, "
                         f"effective {ev.t_effective}")
    if ev.kind in ("up", "down"):
        sched = replica_schedules.setdefault(ev.stage, [])
        sched.append((ev.t_effective, int(ev.value)))
        # ups land at t+activation, downs at t: keep each stage's
        # stream time-sorted for the replica pool
        sched.sort(key=lambda e: e[0])
    elif ev.kind == "shed":
        sched = shed_schedules.setdefault(ev.stage, [])
        sched.append((ev.t_effective, float(ev.value)))
        sched.sort(key=lambda e: e[0])
    elif ev.kind == "policy":
        if not ev.policy:
            raise ValueError("policy control event carries no policy name")
        pol = policy_schedules.setdefault(ev.stage, [])
        pol.append((ev.t_effective, str(ev.policy)))
        pol.sort(key=lambda e: e[0])
    else:
        raise ValueError(f"unknown control event kind {ev.kind!r}")


# -- shared cost accounting -------------------------------------------------


def replica_cost_timeline(
    pipeline: Pipeline,
    config: PipelineConfig,
    schedules: Optional[Dict[str, Sequence[Tuple[float, int]]]],
    t_end: float,
) -> Tuple[np.ndarray, np.ndarray, Dict[str, List[Tuple[float, int]]]]:
    """(times, $/hr step function, per-stage replica timeline) for a run.

    Shared by the open-loop live-cluster simulation, the closed-loop
    co-simulation, and the live executor's run records, so every cost
    comparison integrates the same step function.
    """
    counts = {s: config[s].replicas for s in pipeline.stages}
    hw_cost = {
        s: get_hardware(config[s].hardware).cost_per_hr
        for s in pipeline.stages
    }
    events: List[Tuple[float, str, int]] = []
    for s, evs in (schedules or {}).items():
        for t, d in evs:
            events.append((t, s, d))
    events.sort()
    times = [0.0]
    costs = [sum(counts[s] * hw_cost[s] for s in counts)]
    timeline: Dict[str, List[Tuple[float, int]]] = {
        s: [(0.0, counts[s])] for s in counts
    }
    for t, s, d in events:
        if t > t_end:
            break
        counts[s] += d
        times.append(t)
        costs.append(sum(counts[k] * hw_cost[k] for k in counts))
        timeline[s].append((t, counts[s]))
    return np.asarray(times), np.asarray(costs), timeline


def integrate_cost(cost_times: np.ndarray, cost_per_hr: np.ndarray,
                   t_end: float) -> float:
    """$ integrated over [0, t_end] of the $/hr step function.

    A degenerate (empty) timeline integrates to 0 rather than indexing
    ``cost_per_hr[-1]`` — an empty pipeline or zero-length run is a
    valid (free) run record.
    """
    if cost_per_hr is None or len(cost_per_hr) == 0:
        return 0.0
    ts = np.append(cost_times, t_end)
    cs = np.append(cost_per_hr, cost_per_hr[-1])
    return float((cs[:-1] * np.diff(ts)).sum() / 3600.0)


def mean_cost_per_hr(cost_times: np.ndarray, cost_per_hr: np.ndarray,
                     t_end: float) -> float:
    """Run-averaged $/hr of the step function (0 for degenerate runs)."""
    return integrate_cost(cost_times, cost_per_hr, t_end) * 3600.0 \
        / max(t_end, 1e-9)


class CostAccounting:
    """Mixin for run-result records carrying a ``cost_times`` /
    ``cost_per_hr`` step function: one implementation of the
    total/mean-cost accounting for every backend's result type
    (LiveRunResult, ClosedLoopResult, LiveLoopResult), so a change to
    the cost convention cannot silently diverge between them.

    Subclasses provide :meth:`_cost_t_end_default` — the run horizon
    used when the caller passes no ``t_end`` (conventionally the last
    arrival). Deliberately carries no annotated attributes: dataclass
    subclasses must not inherit extra fields from the mixin.
    """

    def _cost_t_end_default(self) -> float:
        raise NotImplementedError

    def _t_end(self, t_end: Optional[float]) -> float:
        return t_end if t_end is not None else self._cost_t_end_default()

    def total_cost(self, t_end: Optional[float] = None) -> float:
        """$ integrated over the run (degenerate empty timelines cost 0)."""
        return integrate_cost(self.cost_times, self.cost_per_hr,
                              self._t_end(t_end))

    def mean_cost_per_hr(self, t_end: Optional[float] = None) -> float:
        return mean_cost_per_hr(self.cost_times, self.cost_per_hr,
                                self._t_end(t_end))
