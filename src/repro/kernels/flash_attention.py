"""Pallas TPU flash attention (prefill/train path).

Blockwise-softmax attention with explicit VMEM tiling via BlockSpec:
grid = (batch, q_heads, q_blocks, kv_blocks); the innermost grid dimension
iterates sequentially on TPU, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across kv blocks. GQA is
native: K/V blocks are indexed with ``h // group`` so shared KV heads are
fetched once per group without materializing the expanded KV.

Tiling: q blocks (BQ=128 rows) x kv blocks (BK=128) with the full head_dim
resident — MXU-aligned (128 lanes) and comfortably inside VMEM:
2*(BK*D) + BQ*D + BQ*BK fp32 words ~= 0.4 MiB for D=256.

Causal/sliding-window masking is applied with block-level iota compares;
fully-masked kv blocks still execute but contribute zero weight (block
skipping is a documented §Perf follow-up).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, sq: int, sk: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)      # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)      # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)      # (bk, dv)
    s = jnp.dot(q, k.T) * scale              # (bq, bk)

    # absolute positions; queries are offset by sk - sq so the causal
    # diagonal aligns when attending over a longer prefix
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        keep = k_pos <= q_pos
        if window > 0:
            keep &= (q_pos - k_pos) < window
        s = jnp.where(keep, s, NEG_INF)

    m_prev = m_ref[...]                       # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                    # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)           # (bq, 1)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,                # (B, Sq, H, D)
    k: jnp.ndarray,                # (B, Sk, KV, D)
    v: jnp.ndarray,                # (B, Sk, KV, Dv)
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (B, Sq, H, Dv)."""
    b, sq, h, d = q.shape
    _, sk, kv, dv = v.shape
    if h % kv:
        raise ValueError(f"q heads {h} not divisible by kv heads {kv}")
    group = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq lens ({sq},{sk}) must divide blocks ({bq},{bk})")
    n_q, n_kv = sq // bq, sk // bk

    # (B,S,H,D) -> (B,H,S,D): head_dim on the lane dimension
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, sq=sq, sk=sk, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.swapaxes(1, 2)
