"""Pallas TPU kernels for the served models' compute hot-spots.

flash_attention (prefill/train), decode_attention (single-token serve),
rmsnorm (fused norm). Each has a pure-jnp oracle in ref.py; ops.py is the
jit'd dispatch layer (Pallas on TPU, ref elsewhere, interpret on demand).
"""
