"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def _expand_gqa(k: jnp.ndarray, h: int) -> jnp.ndarray:
    kv = k.shape[2]
    if kv == h:
        return k
    assert h % kv == 0
    return jnp.repeat(k, h // kv, axis=2)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D[v]); mask broadcastable to
    (B,H,Sq,Sk). Returns (B,Sq,H,Dv). fp32 softmax.

    GQA is computed in grouped layout — q reshaped to (B,Sq,KV,G,D) —
    so shared KV heads are never materialized H/KV times (the expanded
    K/V of a 32k x 128-stream qwen2 decode step is 8x the cache, per
    layer, per read). Head-shaped masks (rare; none in this codebase)
    fall back to the expanded form.
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    head_mask = mask is not None and mask.ndim >= 4 and \
        mask.shape[-3] not in (1, None) and mask.shape[-3] == h and kv != h
    if kv == h or head_mask:
        k = _expand_gqa(k, h)
        v = _expand_gqa(v, h)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if mask is not None:
            s = jnp.where(mask, s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
        return out.astype(q.dtype)

    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale      # (B,KV,G,Sq,Sk)
    if mask is not None:
        # broadcastable-to-(B,H,Sq,Sk) masks with a unit/absent head dim
        # broadcast over (KV,G) after inserting one axis
        m = mask
        while m.ndim < 4:
            m = m[None]
        s = jnp.where(m[:, :, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def causal_mask_ref(sq: int, sk: int, window: int = 0,
                    offset: int = 0) -> jnp.ndarray:
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window > 0:
        m &= (qi - kj) < window
    return m


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        scale: Optional[float] = None):
    """Oracle for the prefill flash kernel; q,k,v: (B,S,H|KV,D)."""
    sq, sk = q.shape[1], k.shape[1]
    mask = causal_mask_ref(sq, sk, window, offset=sk - sq) if causal else None
    return attention_ref(q, k, v, mask, scale)


def decode_attention_ref(q, k, v, valid_len, window: int = 0,
                         scale: Optional[float] = None):
    """Oracle for the decode kernel.

    q: (B,1,H,D); k,v: (B,Smax,KV,D); valid_len: scalar or (B,) — number of
    populated cache slots (the new token is at index valid_len-1).
    """
    smax = k.shape[1]
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = jnp.full((q.shape[0],), vl)
    kj = jnp.arange(smax)[None, :]
    mask = kj < vl[:, None]
    if window > 0:
        mask &= (vl[:, None] - 1 - kj) < window
    return attention_ref(q, k, v, mask[:, None, None, :], scale)


def mamba_scan_ref(dt, x, b, c, a, h0):
    """Oracle for the mamba selective-scan kernel.

    dt, x: (B,S,D); b, c: (B,S,N); a: (D,N); h0: (B,D,N).
    Returns (y (B,S,D), h_last (B,D,N)). Sequential fp32 recurrence:
      h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
      y_t = <h_t, C_t>
    """
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp            # (B,D),(B,D),(B,N),(B,N)
        a_bar = jnp.exp(dt_t[..., None] * af)            # (B,D,N)
        h = a_bar * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1)      # (B,D)
        return h, y_t

    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (dtf.swapaxes(0, 1), xf.swapaxes(0, 1),
         bf.swapaxes(0, 1), cf.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), h_last.astype(h0.dtype)
