"""Jit-friendly kernel entry points with backend dispatch.

On TPU the Pallas kernels run natively; elsewhere (this CPU container,
and any non-TPU backend) the pure-jnp references execute so models, smoke
tests, and the dry-run lowering all use the XLA path. Set
``REPRO_FORCE_PALLAS_INTERPRET=1`` to route through the Pallas kernels in
interpret mode (slow; used to exercise kernel code paths end-to-end).
"""

from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref, xla_flash
from repro.kernels.decode_attention import decode_attention as _pallas_decode
from repro.kernels.flash_attention import flash_attention as _pallas_flash
from repro.kernels.rmsnorm import rmsnorm as _pallas_rmsnorm

# Below this KV length the naive reference is used on non-TPU backends
# (compiles faster, and the S^2 scores are negligible); above it the
# blockwise xla_flash path keeps live scores O(bq x bk).
XLA_FLASH_MIN_SK = 2048


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _force_interpret() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS_INTERPRET", "0") == "1"


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray,
            eps: float = 1e-6) -> jnp.ndarray:
    if _use_pallas():
        return _pallas_rmsnorm(x, scale, eps)
    if _force_interpret():
        return _pallas_rmsnorm(x, scale, eps, interpret=True)
    return ref.rmsnorm_ref(x, scale, eps)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              mask: Optional[jnp.ndarray], compute_dtype,
              kind: Optional[str] = None, window: int = 0,
              valid_len=None) -> jnp.ndarray:
    """General attention entry point.

    `kind` describes the mask structurally so the TPU path can use the
    flash kernels: "causal" | "full" | "decode". When kind is None (or an
    explicit irregular mask is supplied) the jnp reference handles it.
    """
    q = q.astype(compute_dtype)
    k = k.astype(compute_dtype)
    v = v.astype(compute_dtype)
    pallas = _use_pallas()
    interp = _force_interpret()
    if (pallas or interp) and kind in ("causal", "full"):
        sq, sk = q.shape[1], k.shape[1]
        if sq % min(128, sq) == 0 and sk % min(128, sk) == 0:
            return _pallas_flash(q, k, v, causal=(kind == "causal"),
                                 window=window, interpret=interp)
    if (pallas or interp) and kind == "decode" and valid_len is not None:
        smax = k.shape[1]
        if smax % min(512, smax) == 0:
            return _pallas_decode(q, k, v, valid_len, window=window,
                                  interpret=interp)
    scale = 1.0 / math.sqrt(q.shape[-1])
    sq, sk = q.shape[1], k.shape[1]
    if kind in ("causal", "full") and mask is None:
        # XLA path for structural masks: blockwise flash above the size
        # threshold (keeps live scores O(bq x bk) — see xla_flash.py),
        # materialized mask below it.
        if sk >= XLA_FLASH_MIN_SK and xla_flash.supported(sq, sk):
            return xla_flash.flash_attention_xla(
                q, k, v, causal=(kind == "causal"), window=window,
                scale=scale)
        if kind == "causal":
            mask = ref.causal_mask_ref(sq, sk, window, offset=sk - sq)
    return ref.attention_ref(q, k, v, mask, scale)


def mamba_chunk(dt, x, b, c, a, h0):
    """One chunk of the mamba selective scan: fused on TPU, associative
    scan elsewhere.

    dt, x: (B,L,D); b, c: (B,L,N); a: (D,N); h0: (B,D,N) fp32.
    Returns (y (B,L,D) fp32, h_last (B,D,N) fp32).
    """
    if _use_pallas() or _force_interpret():
        from repro.kernels.mamba_scan import mamba_scan
        y, h = mamba_scan(dt, x, b, c, a, h0.astype(jnp.float32),
                          chunk=dt.shape[1],
                          interpret=_force_interpret())
        return y.astype(jnp.float32), h

    # XLA path: discretize + log-depth associative scan (parallel in L)
    a_bar = jnp.exp(dt[..., None].astype(jnp.float32)
                    * a.astype(jnp.float32))               # (B,L,D,N)
    bx = (dt * x).astype(jnp.float32)[..., None] * \
        b.astype(jnp.float32)[:, :, None, :]

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_s, b_s = jax.lax.associative_scan(comb, (a_bar, bx), axis=1)
    h_all = a_s * h0.astype(jnp.float32)[:, None] + b_s
    y = jnp.einsum("bldn,bln->bld", h_all, c.astype(jnp.float32))
    return y, h_all[:, -1]
