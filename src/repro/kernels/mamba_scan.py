"""Pallas TPU kernel: mamba chunked selective-scan.

The roofline table (EXPERIMENTS.md §Roofline) classifies every
ssm/hybrid pair as memory-bound: the XLA path discretizes and scans the
(L, D, N) state update through HBM each chunk. This kernel fuses
discretization (a_bar = exp(dt*A), b_bar*x = dt*B*x), the linear
recurrence h_t = a_bar_t * h_{t-1} + bx_t, and the output contraction
y_t = <h_t, C_t> into one VMEM-resident pass, so HBM traffic per token
is just the inputs (dt, x, B, C) and output y — never the (L, D, N)
state trajectory.

Grid: (batch, d_blocks, n_chunks); the chunk axis iterates innermost
(sequentially on TPU), carrying the running state h in a VMEM scratch
tile (D_blk, N) — the same persistence pattern the flash kernel uses for
its softmax state. Block shapes keep D_blk on the sublane dim and N on
the lane dim; with D_blk=256, N<=64, the working set is < 4 MiB of VMEM.

The time recurrence runs as an in-kernel fori_loop over the chunk: each
step is a (D_blk, N) vector op — wide enough to keep the VPU busy — and
a (D_blk,) store into the output tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_D_BLOCK = 256


def _mamba_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
                  y_ref, hout_ref, h_scratch, *,
                  chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)            # (D_blk, N)
    dt = dt_ref[0].astype(jnp.float32)            # (chunk, D_blk)
    x = x_ref[0].astype(jnp.float32)              # (chunk, D_blk)
    bm = b_ref[0].astype(jnp.float32)             # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)             # (chunk, N)

    def step(t, h):
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]   # (D_blk,)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]
        b_t = jax.lax.dynamic_slice_in_dim(bm, t, 1, 0)[0]    # (N,)
        c_t = jax.lax.dynamic_slice_in_dim(cm, t, 1, 0)[0]
        a_bar = jnp.exp(dt_t[:, None] * a)                    # (D_blk, N)
        h = a_bar * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)               # (D_blk,)
        # NB: every ref index must be a slice (pl.ds/:): a raw int index
        # crashes interpret-mode state discharge (_swap_discharge_rule)
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y_t[None, None, :].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(ci == n_chunks - 1)
    def _flush():
        hout_ref[...] = h[None].astype(hout_ref.dtype)


def mamba_scan(
    dt: jnp.ndarray,     # (B, S, D)   discretization step (post-softplus)
    x: jnp.ndarray,      # (B, S, D)   conv+silu'd input
    b: jnp.ndarray,      # (B, S, N)   input-dependent B
    c: jnp.ndarray,      # (B, S, N)   input-dependent C
    a: jnp.ndarray,      # (D, N)      state matrix (negative)
    h0: jnp.ndarray,     # (B, D, N)   carried state
    chunk: int = 256,
    d_block: int = DEFAULT_D_BLOCK,
    interpret: bool = False,
):
    """Returns (y (B,S,D), h_last (B,D,N)); fp32 state, x.dtype output."""
    bsz, s, d = dt.shape
    n = a.shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    n_chunks = s // chunk
    db = min(d_block, d)
    if d % db:
        db = d
    nd = d // db

    kernel = functools.partial(_mamba_kernel, chunk=chunk,
                               n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(bsz, nd, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, db), lambda bb, di, ci: (bb, ci, di)),
            pl.BlockSpec((1, chunk, db), lambda bb, di, ci: (bb, ci, di)),
            pl.BlockSpec((1, chunk, n), lambda bb, di, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, di, ci: (bb, ci, 0)),
            pl.BlockSpec((db, n), lambda bb, di, ci: (di, 0)),
            pl.BlockSpec((1, db, n), lambda bb, di, ci: (bb, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, db), lambda bb, di, ci: (bb, ci, di)),
            pl.BlockSpec((1, db, n), lambda bb, di, ci: (bb, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((db, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, b, c, a, h0)
    return y, h_last
