"""Blockwise (flash-style) attention in pure jnp for the XLA path.

The Pallas flash kernel runs only on TPU; every other backend — including
the multi-pod DRY-RUN lowering, which compiles the CPU path — previously
fell back to the naive reference that materializes the full (B,H,Sq,Sk)
score tensor. At the assigned shapes that tensor dominates per-device
temp memory (llama3.2-1b train_4k: ~206 GiB/device; whisper-small
train_4k: ~4.4 TiB/device) and makes the compiled artifact useless for
memory analysis.

Forward: outer ``lax.map`` over q blocks, inner ``lax.scan`` over kv
blocks carrying the online-softmax state (acc, m, l) — live scores are
O(bq x bk). Masking uses block-index iota compares; fully-masked blocks
still execute (≈2x attention-FLOP overhead vs triangle skipping).

Backward: CUSTOM VJP implementing the flash backward — recompute each
(qi, ki) probability block from the saved (q, k, v, out, lse) and
accumulate dq / dk / dv blockwise. Plain ``jax.checkpoint`` is NOT
enough: during the rematerialized backward, scan-AD stacks every
probability block across both loops, reviving an O(S^2) buffer
(observed: 32 GiB/device live on jamba-1.5-large train_4k).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _largest_block(n: int, cap: int = 512) -> int:
    for b in (512, 256, 128, 64, 32):
        if b <= cap and n % b == 0:
            return b
    return 0


def supported(sq: int, sk: int) -> bool:
    """Always true — ragged lengths are padded to the block size."""
    return sq >= 1 and sk >= 1


def flash_attention_xla(
    q: jnp.ndarray,                # (B, Sq, H, D)
    k: jnp.ndarray,                # (B, Sk, KV, D)
    v: jnp.ndarray,                # (B, Sk, KV, Dv)
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Returns (B, Sq, H, Dv); fp32 softmax state, q.dtype output."""
    b, sq, h, d = q.shape
    _, sk, kvh, dv = v.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    bq = _largest_block(sq) or 512
    bk = _largest_block(sk) or 512
    sq_pad = -(-sq // bq) * bq
    sk_pad = -(-sk // bk) * bk
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))

    fn = functools.partial(
        _flash, causal=causal, window=window, scale=scale,
        bq=bq, bk=bk, sk_orig=sk, offset=sk - sq)
    out = fn(q, k, v)
    return out[:, :sq]


def _keep_mask(qi, ki, bq: int, bk: int, causal: bool, window: int,
               offset: int, sk_orig: int, pad_k: bool):
    """(bq, bk) bool mask for block (qi, ki); None if nothing masks."""
    if not (causal or window > 0 or pad_k):
        return None
    q_pos = qi * bq + jnp.arange(bq) + offset
    k_pos = ki * bk + jnp.arange(bk)
    keep = jnp.ones((bq, bk), bool)
    if causal:
        keep &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        keep &= (q_pos[:, None] - k_pos[None, :]) < window
    if pad_k:
        keep &= (k_pos < sk_orig)[None, :]
    return keep


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, scale, bq, bk, sk_orig, offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, scale, bq, bk,
                             sk_orig, offset)
    return out


def _flash_fwd_impl(q, k, v, causal, window, scale, bq, bk, sk_orig,
                    offset):
    b, sq_pad, h, d = q.shape
    _, sk_pad, kvh, dv = v.shape
    group = h // kvh
    nq, nk = sq_pad // bq, sk_pad // bk
    pad_k = sk_pad != sk_orig

    qb = q.reshape(b, nq, bq, h, d).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(b, nk, bk, kvh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, kvh, dv).transpose(1, 0, 2, 3, 4)

    def q_block(args):
        qi, qblk = args                       # (B, bq, H, D)
        qf = qblk.astype(jnp.float32) * scale

        def kv_step(carry, inp):
            acc, m, l = carry
            ki, kblk, vblk = inp
            kf = kblk.astype(jnp.float32)
            vf = vblk.astype(jnp.float32)
            if group > 1:
                kf = jnp.repeat(kf, group, axis=2)
                vf = jnp.repeat(vf, group, axis=2)
            s = jnp.einsum("bqhd,bkhd->bqhk", qf, kf)
            keep = _keep_mask(qi, ki, bq, bk, causal, window, offset,
                              sk_orig, pad_k)
            if keep is not None:
                s = jnp.where(keep[None, :, None, :], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p, vf)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, bq, h, dv), jnp.float32)
        m0 = jnp.full((b, bq, h), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, bq, h), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nk), kb, vb))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m + jnp.log(l_safe)             # (B, bq, H)
        return out, lse

    ob, lseb = jax.lax.map(q_block, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(b, sq_pad, h, dv)
    lse = lseb.transpose(1, 0, 2, 3).reshape(b, sq_pad, h)
    return out, lse


def _flash_fwd(q, k, v, causal, window, scale, bq, bk, sk_orig, offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, scale, bq, bk,
                               sk_orig, offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, scale, bq, bk, sk_orig, offset, res, do):
    """Flash backward: p recomputed per block from (q, k, v, lse);
    dk/dv accumulated in a full-size fp32 carry (O(Sk) state), dq emitted
    per q block. Peak live = carries + one (B,bq,H,bk) block."""
    q, k, v, out, lse = res
    b, sq_pad, h, d = q.shape
    _, sk_pad, kvh, dv = v.shape
    group = h // kvh
    nq, nk = sq_pad // bq, sk_pad // bk
    pad_k = sk_pad != sk_orig

    qb = q.reshape(b, nq, bq, h, d).transpose(1, 0, 2, 3, 4)
    dob = do.reshape(b, nq, bq, h, dv).transpose(1, 0, 2, 3, 4)
    outb = out.reshape(b, nq, bq, h, dv).transpose(1, 0, 2, 3, 4)
    lseb = lse.reshape(b, nq, bq, h).transpose(1, 0, 2, 3)
    kb = k.reshape(b, nk, bk, kvh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, kvh, dv).transpose(1, 0, 2, 3, 4)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry                 # (nk,B,bk,KV,D/(Dv)) fp32
        qi, qblk, doblk, oblk, lseblk = inp
        qf = qblk.astype(jnp.float32) * scale
        dof = doblk.astype(jnp.float32)
        # delta_i = rowsum(do * out)  (B,bq,H)
        delta = jnp.einsum("bqhd,bqhd->bqh", dof,
                           oblk.astype(jnp.float32))

        def kv_step(dq_acc, inp2):
            ki, kblk, vblk = inp2
            kf = kblk.astype(jnp.float32)
            vf = vblk.astype(jnp.float32)
            if group > 1:
                kfe = jnp.repeat(kf, group, axis=2)
                vfe = jnp.repeat(vf, group, axis=2)
            else:
                kfe, vfe = kf, vf
            s = jnp.einsum("bqhd,bkhd->bqhk", qf, kfe)
            keep = _keep_mask(qi, ki, bq, bk, causal, window, offset,
                              sk_orig, pad_k)
            if keep is not None:
                s = jnp.where(keep[None, :, None, :], s, NEG_INF)
            p = jnp.exp(s - lseblk.astype(jnp.float32)[..., None])
            dp = jnp.einsum("bqhd,bkhd->bqhk", dof, vfe)
            ds = p * (dp - delta[..., None])               # (B,bq,H,bk)
            # dq w.r.t. the raw (unscaled) q: qf already carries `scale`,
            # so d(s)/d(q) contributes one more factor of scale here.
            dq_blk = jnp.einsum("bqhk,bkhd->bqhd", ds, kfe) * scale
            # dk/dv: fold GQA groups back onto the compact KV heads
            if group > 1:
                ds_g = ds.reshape(b, bq, kvh, group, bk)
                p_g = p.reshape(b, bq, kvh, group, bk)
                qf_g = qf.reshape(b, bq, kvh, group, d)
                dof_g = dof.reshape(b, bq, kvh, group, dv)
                dk_blk = jnp.einsum("bqkgs,bqkgd->bskd", ds_g, qf_g)
                dv_blk = jnp.einsum("bqkgs,bqkgd->bskd", p_g, dof_g)
            else:
                dk_blk = jnp.einsum("bqhk,bqhd->bkhd", ds, qf)
                dv_blk = jnp.einsum("bqhk,bqhd->bkhd", p, dof)
            return dq_acc + dq_blk, (ki, dk_blk, dv_blk)

        dq0 = jnp.zeros((b, bq, h, d), jnp.float32)
        dq_blk, (kis, dk_blks, dv_blks) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(nk), kb, vb))
        dk_acc = dk_acc + dk_blks
        dv_acc = dv_acc + dv_blks
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros((nk, b, bk, kvh, d), jnp.float32)
    dv0 = jnp.zeros((nk, b, bk, kvh, dv), jnp.float32)
    (dk_acc, dv_acc), dqb = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nq), qb, dob, outb, lseb))
    dq = dqb.transpose(1, 0, 2, 3, 4).reshape(b, sq_pad, h, d)
    dk = dk_acc.transpose(1, 0, 2, 3, 4).reshape(b, sk_pad, kvh, d)
    dv = dv_acc.transpose(1, 0, 2, 3, 4).reshape(b, sk_pad, kvh, dv)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)
