"""Pallas TPU fused RMSNorm kernel.

Row-tiled: grid over blocks of rows, each block normalizing (BR, D) in
VMEM with an fp32 mean-of-squares reduction fused with the scale multiply,
avoiding the separate variance/normalize/scale HLO round-trips through HBM.
D is the lane dimension; BR rows per block keeps the tile MXU/VPU aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype)
                  * s_ref[...].astype(o_ref.dtype))


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = False) -> jnp.ndarray:
    """x: (..., D); scale: (D,). Returns x normalized*scale, x.dtype."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for dim in orig_shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    if rows % br:
        br = 1  # ragged fallback: one row at a time
    n = rows // br

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
