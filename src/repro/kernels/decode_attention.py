"""Pallas TPU decode attention (single-token serve_step path).

Flash-decode-style attention of one query token against a long KV cache.
The KV length is the long axis, so the grid parallelizes over KV blocks:
grid = (batch, kv_heads, kv_blocks). All q heads in a GQA group are
processed together in one kernel instance — the group's queries form an
(G, D) tile that hits the MXU against each (BK, D) key block, turning a
memory-bound per-head matvec into a small matmul (TPU-native adaptation
of GPU flash-decode's warp-level split-K).

The cache is allocated to Smax but only ``valid_len`` slots are populated;
valid_len arrives via scalar prefetch (SMEM) and masks the tail block.
Online-softmax state persists in VMEM scratch across the innermost
(sequential) kv-block grid dimension.

Sliding-window decode (llama3.2-1b-sw long_500k config) masks keys older
than ``window`` positions behind the current token.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, window: int, bk: int, n_kv: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid_len = vl_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)          # (G, D) — the GQA group
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (BK, Dv)
    s = jnp.dot(q, k.T) * scale                  # (G, BK)

    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    keep = k_pos < valid_len
    if window > 0:
        keep &= (valid_len - 1 - k_pos) < window
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,                # (B, 1, H, D)
    k: jnp.ndarray,                # (B, Smax, KV, D)
    v: jnp.ndarray,                # (B, Smax, KV, Dv)
    valid_len,                     # scalar int — populated cache slots
    window: int = 0,
    scale: Optional[float] = None,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (B, 1, H, Dv)."""
    b, sq, h, d = q.shape
    _, smax, kv, dv = v.shape
    assert sq == 1, "decode kernel processes exactly one new token"
    if h % kv:
        raise ValueError(f"q heads {h} not divisible by kv heads {kv}")
    group = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bk = min(block_k, smax)
    if smax % bk:
        raise ValueError(f"cache len {smax} must divide block {bk}")
    n_kv = smax // bk

    # (B,1,H,D) -> (B,KV,G,D): group queries per shared KV head
    qg = q[:, 0].reshape(b, kv, group, d)
    kt = k.swapaxes(1, 2)                        # (B,KV,Smax,D)
    vt = v.swapaxes(1, 2)
    vl = jnp.asarray(valid_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               bk=bk, n_kv=n_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda bb, hh, ki, vl_: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, ki, vl_: (bb, hh, ki, 0)),
            pl.BlockSpec((1, 1, bk, dv), lambda bb, hh, ki, vl_: (bb, hh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dv),
                               lambda bb, hh, ki, vl_: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, dv), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, group, dv), q.dtype),
        interpret=interpret,
    )(vl, qg, kt, vt)
    return out.reshape(b, 1, h, dv)
