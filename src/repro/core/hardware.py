"""TPU-native hardware menu and cost model.

The paper provisions over a heterogeneous CPU/K80 menu (§6, "CPU costs were
computed by dividing the total hourly cost of an instance by the number of
CPUs ..."). We adapt the menu to a TPU-native fleet (see DESIGN.md §2): a
CPU host core and v5e slices of 1/4/8 chips. The Planner only requires that
hardware has a *total ordering of latency across all batch sizes* (§9) —
the menu below preserves that ordering.

All constants used by the analytic profile backend and the roofline
analysis live here so there is exactly one source of truth.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

# --- TPU v5e chip constants (also used by roofline/analysis.py) ----------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
VMEM_BYTES = 128 * 1024**2    # ~128 MiB VMEM per chip
HBM_BYTES = 16 * 1024**3      # 16 GiB per v5e chip

# CPU host core (measured-profile fallback / non-acceleratable stages)
CPU_PEAK_FLOPS = 0.15e12      # effective fp32 FLOP/s for one host core
CPU_MEM_BW = 25e9             # bytes/s effective


@dataclasses.dataclass(frozen=True)
class HardwareType:
    """One entry in the provisioning menu."""

    name: str
    chips: int                 # accelerator chips (0 => CPU)
    peak_flops: float          # FLOP/s aggregate
    mem_bw: float              # bytes/s aggregate (HBM or host DRAM)
    ici_bw: float              # bytes/s per link between chips (0 if n/a)
    cost_per_hr: float         # $/hr, marginal-cost accounting as in §6
    # Fixed per-batch overhead (dispatch + RPC + PCIe/ICI latency floor).
    overhead_s: float

    @property
    def cost_per_s(self) -> float:
        return self.cost_per_hr / 3600.0

    def is_accelerator(self) -> bool:
        return self.chips > 0


# Menu ordered by descending capability; BestHardware == first entry.
# Prices follow public v5e on-demand pricing shape ($1.20/chip-hr) and a
# $0.05/core-hr host CPU (paper's marginal-cost accounting).
HARDWARE_MENU: Tuple[HardwareType, ...] = (
    # 4x4 ICI slice — the smallest slice that holds >=140 GB of bf16
    # weights (qwen2-72b) with cache headroom.
    HardwareType("tpu-v5e-16", 16, 16 * PEAK_FLOPS_BF16, 16 * HBM_BW,
                 ICI_BW, cost_per_hr=16 * 1.20, overhead_s=0.0022),
    HardwareType("tpu-v5e-8", 8, 8 * PEAK_FLOPS_BF16, 8 * HBM_BW, ICI_BW,
                 cost_per_hr=8 * 1.20, overhead_s=0.0018),
    HardwareType("tpu-v5e-4", 4, 4 * PEAK_FLOPS_BF16, 4 * HBM_BW, ICI_BW,
                 cost_per_hr=4 * 1.20, overhead_s=0.0015),
    HardwareType("tpu-v5e-1", 1, PEAK_FLOPS_BF16, HBM_BW, 0.0,
                 cost_per_hr=1.20, overhead_s=0.0012),
    HardwareType("cpu-1", 0, CPU_PEAK_FLOPS, CPU_MEM_BW, 0.0,
                 cost_per_hr=0.05, overhead_s=0.0005),
)

HARDWARE_BY_NAME: Dict[str, HardwareType] = {h.name: h for h in HARDWARE_MENU}


def get_hardware(name: str) -> HardwareType:
    try:
        return HARDWARE_BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware {name!r}; menu: {sorted(HARDWARE_BY_NAME)}"
        ) from None


def cheaper_hardware(name: str) -> Tuple[str, ...]:
    """Hardware strictly cheaper than `name`, most capable first.

    Used by the Planner's DowngradeHW action.
    """
    cur = get_hardware(name)
    return tuple(
        h.name for h in HARDWARE_MENU if h.cost_per_hr < cur.cost_per_hr
    )
