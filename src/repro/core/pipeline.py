"""Prediction-pipeline DAG specification (§2).

A pipeline is a DAG whose vertices are models (or basic data transforms)
and whose edges carry dataflow. Conditional control flow (Social Media /
Video Monitoring / TF Cascade motifs) is captured by per-edge traversal
probabilities; the Profiler folds those into per-model *scale factors*
``s_m`` — the unconditional probability that a query entering the pipeline
visits model m (§4.1).

The same structure is consumed by the Estimator (simulation), the Planner
(configuration search), and the Tuner (scaling decisions).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.hardware import HARDWARE_MENU, get_hardware


@dataclasses.dataclass(frozen=True)
class Stage:
    """One vertex: a model reference plus serving-relevant metadata."""

    name: str
    model_id: str                  # key into the profile store / model zoo
    # Candidate hardware for this stage. Data transforms that cannot use an
    # accelerator (paper Fig. 3 "preprocess") list only "cpu-1".
    hardware_options: Tuple[str, ...] = tuple(h.name for h in HARDWARE_MENU)

    def __post_init__(self):
        for hw in self.hardware_options:
            get_hardware(hw)  # validate eagerly


@dataclasses.dataclass(frozen=True)
class Edge:
    src: str                       # stage name ("__source__" for ingress)
    dst: str
    probability: float = 1.0       # conditional traversal probability

    def __post_init__(self):
        if not (0.0 < self.probability <= 1.0):
            raise ValueError(f"edge {self.src}->{self.dst}: bad p={self.probability}")


SOURCE = "__source__"


@dataclasses.dataclass
class Pipeline:
    """Immutable DAG description (configuration lives in PipelineConfig)."""

    name: str
    stages: Dict[str, Stage]
    edges: List[Edge]

    def __post_init__(self):
        names = set(self.stages)
        for e in self.edges:
            if e.src != SOURCE and e.src not in names:
                raise ValueError(f"edge src {e.src!r} not a stage")
            if e.dst not in names:
                raise ValueError(f"edge dst {e.dst!r} not a stage")
        self._toposort()  # raises on cycles

    # -- graph helpers ----------------------------------------------------
    def children(self, stage: str) -> List[Edge]:
        return [e for e in self.edges if e.src == stage]

    def parents(self, stage: str) -> List[Edge]:
        return [e for e in self.edges if e.dst == stage]

    def entry_edges(self) -> List[Edge]:
        return [e for e in self.edges if e.src == SOURCE]

    def sinks(self) -> List[str]:
        has_out = {e.src for e in self.edges}
        return [s for s in self.stages if s not in has_out]

    def _toposort(self) -> List[str]:
        indeg = {s: 0 for s in self.stages}
        for e in self.edges:
            if e.src != SOURCE:
                indeg[e.dst] += 1
        ready = sorted([s for s, d in indeg.items() if d == 0])
        order: List[str] = []
        while ready:
            s = ready.pop()
            order.append(s)
            for e in self.children(s):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    ready.append(e.dst)
        if len(order) != len(self.stages):
            raise ValueError(f"pipeline {self.name!r} has a cycle")
        return order

    def toposort(self) -> List[str]:
        return self._toposort()

    # -- scale factors (§4.1) ---------------------------------------------
    def scale_factors(self) -> Dict[str, float]:
        """Unconditional visit probability per stage.

        s_m = sum over incoming edges of s_parent * p_edge, capped at 1
        (join semantics: a query visits a stage at most once).
        """
        s: Dict[str, float] = {name: 0.0 for name in self.stages}
        for stage in self.toposort():
            p = 0.0
            for e in [e for e in self.edges if e.dst == stage]:
                p_src = 1.0 if e.src == SOURCE else s[e.src]
                p += p_src * e.probability
            s[stage] = min(1.0, p)
        return s

    def longest_path_stages(self) -> List[str]:
        """Stages on the longest (max #stages) source->sink path."""
        best: Dict[str, Tuple[int, List[str]]] = {}
        for stage in self.toposort():
            incoming = [e for e in self.edges if e.dst == stage]
            cand: Tuple[int, List[str]] = (1, [stage])
            for e in incoming:
                if e.src != SOURCE and e.src in best:
                    n, path = best[e.src]
                    if n + 1 > cand[0]:
                        cand = (n + 1, path + [stage])
            best[stage] = cand
        return max(best.values(), key=lambda t: t[0])[1] if best else []


# -- per-stage and whole-pipeline configuration ---------------------------


@dataclasses.dataclass
class StageConfig:
    """The three control dimensions per model (§1), plus two beyond-paper
    knobs consumed by the simulation engine (:mod:`repro.sim`):

    * ``timeout_s`` — batch-formation timeout: hold a batch open up to
      ``timeout_s`` from the head-of-line arrival to trade head latency
      for per-replica throughput (0 = the paper's greedy batching).
    * ``policy`` — per-stage queueing policy name from
      ``repro.sim.queueing.QUEUE_POLICIES``: ``"fifo"`` (paper),
      ``"edf"`` (earliest-deadline-first), or ``"slo-drop"``
      (SLO-aware load shedding).
    """

    hardware: str
    batch_size: int
    replicas: int
    timeout_s: float = 0.0
    policy: str = "fifo"

    def __post_init__(self):
        get_hardware(self.hardware)
        if self.batch_size < 1 or self.replicas < 1 or self.timeout_s < 0:
            raise ValueError(f"bad StageConfig {self}")
        if not isinstance(self.policy, str) or not self.policy:
            raise ValueError(f"bad queueing policy in StageConfig {self}")

    def copy(self) -> "StageConfig":
        return StageConfig(self.hardware, self.batch_size, self.replicas,
                           self.timeout_s, self.policy)

    def key(self) -> Tuple:
        """Hashable identity used by simulation/planner caches."""
        return (self.hardware, self.batch_size, self.replicas,
                self.timeout_s, self.policy)


@dataclasses.dataclass
class PipelineConfig:
    """A full assignment of StageConfig per stage."""

    stage_configs: Dict[str, StageConfig]

    def copy(self) -> "PipelineConfig":
        return PipelineConfig(
            {k: v.copy() for k, v in self.stage_configs.items()}
        )

    def cost_per_hr(self) -> float:
        return sum(
            get_hardware(c.hardware).cost_per_hr * c.replicas
            for c in self.stage_configs.values()
        )

    def cache_key(self) -> Tuple:
        """Hashable whole-config identity (stage order independent)."""
        return tuple(sorted(
            (s, c.key()) for s, c in self.stage_configs.items()))

    def __getitem__(self, stage: str) -> StageConfig:
        return self.stage_configs[stage]

    def describe(self) -> str:
        rows = [
            f"  {name:24s} hw={c.hardware:10s} batch={c.batch_size:<4d} "
            f"replicas={c.replicas}"
            for name, c in sorted(self.stage_configs.items())
        ]
        return "\n".join(rows + [f"  total cost: ${self.cost_per_hr():.2f}/hr"])


def linear_pipeline(name: str, model_ids: Sequence[str],
                    hardware_options: Optional[Mapping[str, Sequence[str]]] = None
                    ) -> Pipeline:
    """Convenience builder for chain pipelines (Image Processing motif)."""
    hardware_options = hardware_options or {}
    stages = {}
    edges = []
    prev = SOURCE
    for i, mid in enumerate(model_ids):
        sname = f"s{i}_{mid}"
        opts = tuple(hardware_options.get(mid, ())) or tuple(
            h.name for h in HARDWARE_MENU
        )
        stages[sname] = Stage(sname, mid, opts)
        edges.append(Edge(prev, sname))
        prev = sname
    return Pipeline(name, stages, edges)
