"""Low-frequency Planner (§4.3): greedy constrained cost minimization.

Phase 1 (Alg. 1 `Initialize`): latency-minimizing feasible configuration —
batch=1, lowest-latency hardware per stage; if the bare service time
already exceeds the SLO the constraint is infeasible. Otherwise replicate
the throughput bottleneck until the Estimator deems the pipeline feasible.

Phase 2 (Alg. 2 `MinimizeCost`): repeatedly apply, over all stages, the
single action from {IncreaseBatch (x2), RemoveReplica, DowngradeHW} that
maximally decreases cost while remaining feasible per the Estimator.
IncreaseBatch never changes cost; per the paper it is taken (at equal
cost) because it unlocks subsequent replica removals. DowngradeHW runs a
localized re-initialization of the downgraded stage (batch and replicas
re-searched on the cheaper hardware).

Guarantees at termination (§4.3): (1) if a feasible configuration exists
under the menu, one is returned; (2) no single action reduces cost without
violating the SLO.

Search-loop engineering (EXPERIMENTS.md §Perf): every candidate the
greedy loop, the downgrade binary search, and the annealer evaluate
differs from its incumbent in exactly ONE stage, so all feasibility
checks run through one incremental :class:`repro.sim.TraceSession` —
only the mutated stage's downstream cone is re-simulated, and repeated
whole configurations are scalar cache hits (this subsumes the seed
planner's private whole-config ``_cache``). On top of that, candidate
*sets* — the downgrade action's (hw, batch) probe grid, its replica
binary searches (run in lockstep), and the :class:`BeamPlanner`
frontier — are scored through the session's batched ``percentile_many``
surface. Outputs are bit-identical to full re-simulation;
``BENCH_engine.json`` / ``BENCH_planner_scale.json`` record the
wall-clock wins.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.estimator import Estimator
from repro.core.hardware import cheaper_hardware, get_hardware
from repro.core.pipeline import Pipeline, PipelineConfig, StageConfig
from repro.core.profiler import ProfileStore

MAX_REPLICAS_PER_STAGE = 512
MAX_BATCH = 128


class _ScalarSession:
    """Feasibility session for estimator-like objects without an engine
    session (e.g. the frozen golden oracle): whole-config p-th percentile
    memo over full re-simulations — exactly the seed planner's cache."""

    def __init__(self, estimator, arrivals: np.ndarray):
        self.estimator = estimator
        self.arrivals = arrivals
        self._pctl: Dict[Tuple, float] = {}
        self.stats = {"full_sims": 0, "stage_sims": 0, "stage_hits": 0}

    @staticmethod
    def _key(config: PipelineConfig) -> Tuple:
        if hasattr(config, "cache_key"):
            return config.cache_key()
        return tuple(sorted(
            (s, c.hardware, c.batch_size, c.replicas)
            for s, c in config.stage_configs.items()))

    def percentile(self, config: PipelineConfig, p: float) -> float:
        key = (self._key(config), p)
        val = self._pctl.get(key)
        if val is None:
            self.stats["full_sims"] += 1
            val = self.estimator.simulate(
                config, self.arrivals).percentile(p)
            self._pctl[key] = val
        return val

    def percentile_many(self, configs, p: float):
        """Same batched-scoring surface as TraceSession (memo-backed
        loop here — the oracle has no shared-entry machinery)."""
        return [self.percentile(c, p) for c in configs]


@dataclasses.dataclass
class PlannerResult:
    feasible: bool
    config: Optional[PipelineConfig]
    cost_per_hr: float
    estimated_p99: float
    iterations: int
    simulations: int
    # per-class estimated percentile latency, set by plan_classed() only
    per_class_p: Optional[Dict[str, float]] = None

    def describe(self) -> str:
        if not self.feasible:
            return "INFEASIBLE under the current hardware menu/SLO"
        assert self.config is not None
        txt = (f"{self.config.describe()}\n  est. P99 = "
               f"{self.estimated_p99 * 1e3:.1f} ms "
               f"({self.iterations} iters, {self.simulations} sims)")
        if self.per_class_p:
            txt += "".join(f"\n  class {name}: P = {p * 1e3:.1f} ms"
                           for name, p in self.per_class_p.items())
        return txt


class Planner:
    def __init__(self, pipeline: Pipeline, profiles: ProfileStore,
                 estimator: Optional[Estimator] = None,
                 percentile: float = 99.0, policy: str = "fifo",
                 backend: str = "numpy", failure_headroom: int = 0):
        self.pipeline = pipeline
        self.profiles = profiles
        self.estimator = estimator or Estimator(pipeline, profiles)
        self.percentile = percentile
        # survivable-failure headroom: after the cost search converges,
        # every stage is grown (post-pass, see _harden) until the plan
        # stays SLO-feasible with `failure_headroom` replicas removed —
        # over-provisioning for crash tolerance (repro.faults)
        self.failure_headroom = int(failure_headroom)
        # queueing policy stamped on every stage of the search space —
        # "edf" lets a multi-class plan serve tight-deadline traffic from
        # fewer replicas (deadline scheduling instead of overprovisioning)
        self.policy = policy
        # simulation backend for the session's candidate scoring:
        # "jax" routes the downgrade/beam probe grids through the
        # vmapped device kernel (repro.sim.jax_backend) — same plan
        # decisions, bit-identical feasibility values
        self.backend = backend
        self._session = None
        self._session_token = None
        # scale factors are a pure function of the (immutable) pipeline:
        # computed once per planner, not once per action probe
        self._scale_cache: Optional[Dict[str, float]] = None
        # set by plan_classed() for the duration of the search: feasibility
        # then means EVERY class meets its own percentile deadline
        self._classed = None

    # ---------------------------------------------------------------- utils
    def _stage_hw_options(self, stage: str) -> List[str]:
        st = self.pipeline.stages[stage]
        prof = self.profiles.get(st.model_id)
        return [h for h in st.hardware_options if prof.supports(h)]

    def _best_hardware(self, stage: str) -> str:
        """Lowest batch-1 latency (Alg. 1 line 5)."""
        prof = self.profiles.get(self.pipeline.stages[stage].model_id)
        return min(self._stage_hw_options(stage),
                   key=lambda h: prof.batch_latency(h, 1))

    def _open_session(self, arrivals: np.ndarray) -> None:
        """One incremental session per plan() call: all candidate
        evaluations share the per-stage memoization."""
        if hasattr(self.estimator, "session"):
            # pass the backend only when non-default: other session()
            # implementers (adapters, test doubles) need not know the kwarg
            kw = {} if self.backend == "numpy" else {"backend": self.backend}
            if self._classed is not None:
                t = self._classed
                self._session = self.estimator.session(
                    arrivals, slo_s=t.slo_per_query,
                    class_ids=t.class_ids, class_names=t.class_names, **kw)
            else:
                self._session = self.estimator.session(arrivals, **kw)
        else:  # estimator-like object without an engine (golden oracle)
            if self._classed is not None:
                raise ValueError(
                    "multi-class planning requires an engine-backed "
                    "estimator (got a session-less estimator)")
            self._session = _ScalarSession(self.estimator, arrivals)
        self._session_token = self._trace_token(arrivals)

    @staticmethod
    def _trace_token(arrivals: np.ndarray) -> Tuple:
        """Cheap trace identity: repeated probes against the bound trace
        must not pay an O(n) array compare per call. The id() is backed
        by the endpoint fingerprint so a recycled address cannot silently
        alias a different trace of the same length."""
        n = arrivals.shape[0]
        return (id(arrivals), n,
                float(arrivals[0]) if n else 0.0,
                float(arrivals[-1]) if n else 0.0)

    def _ensure_session(self, arrivals: np.ndarray) -> None:
        """Bind a session to `arrivals` unless one already is (lets
        initialize() be called directly, not only via plan())."""
        if self._session is None or \
                self._session_token != self._trace_token(arrivals):
            self._open_session(arrivals)

    def _scale_factors(self) -> Dict[str, float]:
        if self._scale_cache is None:
            self._scale_cache = self.pipeline.scale_factors()
        return self._scale_cache

    @property
    def _sims(self) -> int:
        return self._session.stats["full_sims"] if self._session else 0

    def _p99(self, config: PipelineConfig) -> float:
        """Percentile latency on the session's bound trace (the arrivals
        handed to plan(); this is the incremental simulate_delta path)."""
        return self._session.percentile(config, self.percentile)

    def _feasible(self, config: PipelineConfig, slo: float) -> bool:
        if self._classed is not None:
            # multi-class objective: every class meets its OWN percentile
            # deadline (the scalar `slo` threaded through the search loops
            # is the min over classes, used only for service-time
            # prefilters — a necessary condition for the tightest class)
            return all(
                self._session.class_percentile(config, self.percentile, cid)
                <= c.slo_s
                for cid, c in enumerate(self._classed.classes))
        return self._p99(config) <= slo

    def _feasible_many(self, configs: List[PipelineConfig], slo: float
                       ) -> List[bool]:
        """Batched feasibility: one ``percentile_many`` call scores the
        whole candidate set against the session's shared stage entries
        (identical booleans to per-config ``_feasible``)."""
        if not configs:
            return []
        if self._classed is not None:
            return [self._feasible(c, slo) for c in configs]
        vals = self._session.percentile_many(configs, self.percentile)
        return [v <= slo for v in vals]

    def _throughput(self, config: PipelineConfig, stage: str) -> float:
        cfg = config[stage]
        prof = self.profiles.get(self.pipeline.stages[stage].model_id)
        return cfg.replicas * prof.throughput(cfg.hardware, cfg.batch_size)

    def _harden(self, config: PipelineConfig, slo: float) -> PipelineConfig:
        """Failure-headroom post-pass: grow each stage until the plan
        would stay feasible after losing ``failure_headroom`` replicas
        of that stage (single-stage failure model — the planner's
        survivable-failure target). Runs AFTER the cost search so the
        headroom rides the cheapest feasible shape rather than steering
        it; a stage is left at ``MAX_REPLICAS_PER_STAGE`` if even the
        cap cannot buy the headroom (best effort)."""
        f = self.failure_headroom
        if f <= 0:
            return config
        for stage in self.pipeline.stages:
            while True:
                k = config[stage].replicas
                if k - f >= 1:
                    probe = config.copy()
                    probe[stage].replicas = k - f
                    if self._feasible(probe, slo):
                        break
                if k + 1 > MAX_REPLICAS_PER_STAGE:
                    break
                config[stage].replicas = k + 1
        return config

    # ------------------------------------------------------------ Algorithm 1
    def initialize(self, arrivals: np.ndarray, slo: float
                   ) -> Optional[PipelineConfig]:
        arrivals = np.asarray(arrivals, dtype=np.float64)
        self._ensure_session(arrivals)
        config = PipelineConfig({
            s: StageConfig(self._best_hardware(s), 1, 1, policy=self.policy)
            for s in self.pipeline.stages
        })
        if self.estimator.service_time(config) > slo:
            return None  # infeasible: bare service time exceeds the SLO
        scale = self._scale_factors()
        while not self._feasible(config, slo):
            # throughput bottleneck, demand-normalized by scale factor
            bottleneck = min(
                config.stage_configs,
                key=lambda s: self._throughput(config, s) / max(scale[s], 1e-9),
            )
            config[bottleneck].replicas += 1
            if config[bottleneck].replicas > MAX_REPLICAS_PER_STAGE:
                return None
        return config

    # ---------------------------------------------------- Algorithm 2 actions
    def _action_increase_batch(self, config: PipelineConfig, stage: str
                               ) -> Optional[PipelineConfig]:
        cfg = config[stage]
        if cfg.batch_size * 2 > MAX_BATCH:
            return None
        new = config.copy()
        new[stage].batch_size *= 2
        return new

    def _action_remove_replica(self, config: PipelineConfig, stage: str
                               ) -> Optional[PipelineConfig]:
        if config[stage].replicas <= 1:
            return None
        new = config.copy()
        new[stage].replicas -= 1
        return new

    def _downgrade_grid(self, config: PipelineConfig, stage: str,
                        arrivals: np.ndarray, slo: float):
        """One (config, stage) downgrade job: the statically-prefiltered
        (hw, batch, k0, k_cap) probe grid plus its candidate constructor,
        or None when no cheaper option survives the prefilters (cost cap
        + bare service time + required throughput). Split from the
        search so :class:`BeamPlanner` can concatenate every frontier
        member's grids into ONE lockstep search per round."""
        cfg = config[stage]
        options = [h for h in cheaper_hardware(cfg.hardware)
                   if h in self._stage_hw_options(stage)]
        if not options:
            return None
        prof = self.profiles.get(self.pipeline.stages[stage].model_id)
        scale = self._scale_factors()[stage]
        duration = float(arrivals.max() - arrivals.min()) if arrivals.size > 1 else 1.0
        lam_m = arrivals.size * scale / max(duration, 1e-9)
        old_stage_cost = get_hardware(cfg.hardware).cost_per_hr * cfg.replicas

        def with_k(hw: str, batch: int, k: int) -> PipelineConfig:
            cand = config.copy()
            cand.stage_configs[stage] = dataclasses.replace(
                cfg, hardware=hw, batch_size=batch, replicas=k)
            return cand

        grid: List[Tuple[str, int, int, int]] = []   # (hw, batch, k0, k_cap)
        for hw in options:
            hw_cost = get_hardware(hw).cost_per_hr
            # replicas beyond which the downgrade cannot reduce total cost
            k_cap = int(math.floor((old_stage_cost - 1e-9) / hw_cost))
            for batch in prof.batch_sizes:
                if batch > MAX_BATCH:
                    continue
                # prefilter: bare service time must fit before simulating
                if self.estimator.service_time(with_k(hw, batch, 1)) > slo:
                    continue
                mu = prof.throughput(hw, batch)
                k0 = max(1, math.ceil(lam_m / mu))
                if k0 > k_cap:
                    continue
                grid.append((hw, batch, k0, k_cap))
        if not grid:
            return None
        return (with_k, grid, config.cost_per_hr())

    def _downgrade_search_many(self, jobs: List, slo: float
                               ) -> List[Optional[PipelineConfig]]:
        """Lockstep replica search over the union of downgrade jobs.

        One ``percentile_many`` call decides every grid point's
        feasibility at its cost cap, then the survivors binary-search
        their minimal replica counts in lockstep — one batched call per
        halving round, across ALL jobs at once. Feasibility is monotone
        in replicas, so predicate values (and hence each job's returned
        candidate) match the sequential per-job formulation exactly."""
        flat: List[Tuple[int, str, int, int, int]] = []
        for j, (with_k, grid, _) in enumerate(jobs):
            flat.extend((j, hw, b, k0, k_cap) for hw, b, k0, k_cap in grid)
        feas = self._feasible_many(
            [jobs[j][0](hw, b, k_cap) for j, hw, b, _, k_cap in flat], slo)
        search = [[j, hw, b, k0, k_cap]
                  for (j, hw, b, k0, k_cap), ok in zip(flat, feas) if ok]
        while True:
            open_i = [i for i, (_, _, _, lo, hi) in enumerate(search)
                      if lo < hi]
            if not open_i:
                break
            mids = [(search[i][3] + search[i][4]) // 2 for i in open_i]
            ok_mid = self._feasible_many(
                [jobs[search[i][0]][0](search[i][1], search[i][2], m)
                 for i, m in zip(open_i, mids)], slo)
            for i, m, ok in zip(open_i, mids, ok_mid):
                if ok:
                    search[i][4] = m
                else:
                    search[i][3] = m + 1

        best: List[Optional[PipelineConfig]] = [None] * len(jobs)
        for j, hw, b, lo, _ in search:
            cand = jobs[j][0](hw, b, lo)
            if cand.cost_per_hr() < jobs[j][2] - 1e-12 and (
                    best[j] is None
                    or cand.cost_per_hr() < best[j].cost_per_hr()):
                best[j] = cand
        return best

    def _action_downgrade_hw(self, config: PipelineConfig, stage: str,
                             arrivals: np.ndarray, slo: float
                             ) -> Optional[PipelineConfig]:
        """Localized re-init + cost minimization on cheaper hardware (§4.3).

        The whole (hw, batch) probe grid is scored through the session's
        ``percentile_many`` surface (one feasibility call at the cost
        caps, then lockstep replica halving — see
        :meth:`_downgrade_search_many`). Each probe still simulates once
        on a miss; the win is that the whole grid shares the session's
        stage-entry, assembly-prefix, and percentile caches — and, on the
        jax backend, scores as one vmapped device program. Selection
        order and predicate values match the sequential formulation
        exactly (same returned candidate)."""
        job = self._downgrade_grid(config, stage, arrivals, slo)
        if job is None:
            return None
        return self._downgrade_search_many([job], slo)[0]

    # ------------------------------------------------------------ Algorithm 2
    def plan(self, arrivals: np.ndarray, slo: float) -> PlannerResult:
        arrivals = np.asarray(arrivals, dtype=np.float64)
        self._open_session(arrivals)
        config = self.initialize(arrivals, slo)
        if config is None:
            return PlannerResult(False, None, math.inf, math.inf, 0, self._sims)

        iterations = 0
        while True:
            iterations += 1
            current_cost = config.cost_per_hr()
            best: Optional[PipelineConfig] = None
            best_cost = current_cost
            best_is_batch = False
            for stage in self.pipeline.stages:
                candidates: List[Tuple[Optional[PipelineConfig], bool]] = [
                    (self._action_increase_batch(config, stage), True),
                    (self._action_remove_replica(config, stage), False),
                    (self._action_downgrade_hw(config, stage, arrivals, slo),
                     False),
                ]
                for cand, is_batch in candidates:
                    if cand is None:
                        continue
                    c = cand.cost_per_hr()
                    if c > best_cost + 1e-12:
                        continue
                    if not self._feasible(cand, slo):
                        continue
                    if c < best_cost - 1e-12:
                        best, best_cost, best_is_batch = cand, c, is_batch
                    elif is_batch and best is None and c <= current_cost + 1e-12:
                        # cost-neutral batch increase: taken only when no
                        # strictly cost-reducing action exists (§4.3)
                        best, best_cost, best_is_batch = cand, c, True
            if best is None:
                break
            config = best

        config = self._harden(config, slo)
        p99 = self._p99(config)
        return PlannerResult(True, config, config.cost_per_hr(), p99,
                             iterations, self._sims)

    # ------------------------------------------------- multi-class objective
    def plan_classed(self, trace, **plan_kwargs) -> PlannerResult:
        """Provision for a mixed per-query SLO workload.

        ``trace`` is a :class:`repro.workload.slo_classes.ClassedTrace`:
        interleaved arrival stream plus per-query class tags, each class
        carrying its own latency SLO. The search is the paper's greedy
        loop (or the annealed refinement on :class:`AnnealedPlanner`)
        with the feasibility predicate replaced by the multi-class
        objective — the configured percentile of EVERY class must meet
        that class's own deadline — while cost is minimized across the
        mix. Service-time prefilters use the tightest class's SLO (a
        necessary condition, so no feasible configuration is pruned).

        Uniform-SLO degenerate case: with one class this reduces exactly
        to ``plan(trace.arrivals, slo)`` feasibility-wise (one constraint
        over all queries).
        """
        if not getattr(trace, "classes", None):
            raise ValueError("plan_classed needs a ClassedTrace with >=1 "
                             "SLOClass")
        self._classed = trace
        try:
            result = self.plan(trace.arrivals, trace.min_slo_s,
                               **plan_kwargs)
            if result.feasible:
                result.per_class_p = {
                    c.name: self._session.class_percentile(
                        result.config, self.percentile, cid)
                    for cid, c in enumerate(trace.classes)
                }
            return result
        finally:
            self._classed = None


# ---------------------------------------------------------------------------
# Beyond-paper: beam-search refinement over the Alg. 2 action set
# ---------------------------------------------------------------------------

class BeamPlanner(Planner):
    """Greedy (Alg. 1+2) followed by a k-wide beam search.

    Where the greedy loop commits to the single best action per
    iteration, the beam keeps the ``beam_width`` cheapest feasible
    configurations reached so far and expands *all* of their actions —
    so an early cost-neutral move (e.g. a batch increase on a stage the
    greedy rule never favors) can pay off several actions later. The
    whole frontier's successor set is scored per round through the
    session's ``percentile_many`` surface, whose shared stage-entry /
    assembly-prefix / percentile caches are what make the wider search
    affordable (BENCH_planner_scale.json records the search cost next
    to greedy's).

    Guarantees: the greedy fixed point is computed first on the same
    incremental session (its probes stay cache-hot for the beam) and is
    only ever *improved on* — the returned plan is feasible and costs at
    most the greedy plan, preserving both §4.3 guarantees.
    """

    def __init__(self, pipeline: Pipeline, profiles: ProfileStore,
                 estimator: Optional[Estimator] = None,
                 percentile: float = 99.0, policy: str = "fifo",
                 beam_width: Optional[int] = None, max_rounds: int = 64,
                 backend: str = "numpy"):
        super().__init__(pipeline, profiles, estimator=estimator,
                         percentile=percentile, policy=policy,
                         backend=backend)
        if beam_width is None:
            # device-backed scoring makes candidates near-free: default
            # to a wider frontier on the jax backend (EXPERIMENTS.md
            # §Device-planner)
            beam_width = 8 if backend == "jax" else 4
        if beam_width < 1:
            raise ValueError(f"beam_width must be >= 1, got {beam_width}")
        self.beam_width = beam_width
        self.max_rounds = max_rounds

    def plan(self, arrivals: np.ndarray, slo: float) -> PlannerResult:
        arrivals = np.asarray(arrivals, dtype=np.float64)
        greedy = super().plan(arrivals, slo)
        if not greedy.feasible:
            return greedy
        best = greedy.config
        best_cost = greedy.cost_per_hr

        init = self.initialize(arrivals, slo)   # cache-hot replay
        frontier: List[PipelineConfig] = []
        visited = set()
        for cfg in (init, greedy.config):
            key = cfg.cache_key()
            if key not in visited:
                visited.add(key)
                frontier.append(cfg)

        stages = list(self.pipeline.stages)
        rounds = 0
        while frontier and rounds < self.max_rounds:
            rounds += 1
            # expand every frontier member's full action set; feasibility
            # for the flat moves is decided by ONE batched scoring call,
            # and every (member, stage) downgrade grid joins ONE union
            # lockstep search instead of a search per pair
            flat: List[PipelineConfig] = []
            kept: List[PipelineConfig] = []   # pre-verified (downgrades)
            jobs: List = []
            for cfg in frontier:
                for stage in stages:
                    for cand in (self._action_increase_batch(cfg, stage),
                                 self._action_remove_replica(cfg, stage)):
                        if cand is None:
                            continue
                        key = cand.cache_key()
                        if key not in visited:
                            visited.add(key)
                            flat.append(cand)
                    job = self._downgrade_grid(cfg, stage, arrivals, slo)
                    if job is not None:
                        jobs.append(job)
            for dg in self._downgrade_search_many(jobs, slo):
                if dg is not None:
                    key = dg.cache_key()
                    if key not in visited:
                        visited.add(key)
                        kept.append(dg)
            feas = self._feasible_many(flat, slo)
            kept.extend(c for c, ok in zip(flat, feas) if ok)
            if not kept:
                break
            kept.sort(key=lambda c: c.cost_per_hr())
            frontier = kept[:self.beam_width]
            front_cost = frontier[0].cost_per_hr()
            if front_cost < best_cost - 1e-12:
                best, best_cost = frontier[0], front_cost

        best = self._harden(best, slo)
        best_cost = best.cost_per_hr()
        p = self._p99(best)
        return PlannerResult(True, best, best_cost, p,
                             greedy.iterations + rounds, self._sims)


# ---------------------------------------------------------------------------
# Beyond-paper: simulated-annealing refinement (EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

class AnnealedPlanner(Planner):
    """Greedy (Alg. 1+2) followed by simulated-annealing refinement.

    The paper notes (§7.2) that the greedy optimizer "occasionally finds
    sub-optimal configurations, as it makes locally optimal decisions".
    This variant escapes those local optima with random joint moves —
    re-batching one stage WHILE re-replicating another — which no single
    greedy action can express. Feasibility stays Estimator-checked, so
    guarantee (1) is preserved; guarantee (2) holds for the returned
    config because annealing only ever returns configs at least as cheap
    as the greedy fixed point.
    """

    def plan(self, arrivals: np.ndarray, slo: float,
             steps: int = 150, t0: float = 0.3,
             seed: int = 0) -> PlannerResult:
        greedy = super().plan(arrivals, slo)
        if not greedy.feasible:
            return greedy
        rng = np.random.default_rng(seed)
        arrivals = np.asarray(arrivals, dtype=np.float64)
        cur = greedy.config.copy()
        cur_cost = cur.cost_per_hr()
        best, best_cost = cur.copy(), cur_cost
        stages = list(self.pipeline.stages)

        def neighbor(cfg: PipelineConfig) -> Optional[PipelineConfig]:
            new = cfg.copy()
            for _ in range(int(rng.integers(1, 3))):  # 1-2 joint moves
                stage = stages[int(rng.integers(len(stages)))]
                sc = new[stage]
                move = int(rng.integers(4))
                if move == 0 and sc.batch_size * 2 <= MAX_BATCH:
                    sc.batch_size *= 2
                elif move == 1 and sc.batch_size > 1:
                    sc.batch_size //= 2
                elif move == 2:
                    sc.replicas = max(1, sc.replicas
                                      + int(rng.choice([-1, 1])))
                else:
                    opts = self._stage_hw_options(stage)
                    sc_hw = opts[int(rng.integers(len(opts)))]
                    new.stage_configs[stage] = dataclasses.replace(
                        sc, hardware=sc_hw)
            return new

        for i in range(steps):
            temp = t0 * (1.0 - i / steps) + 1e-6
            cand = neighbor(cur)
            cost = cand.cost_per_hr()
            # Metropolis on relative cost; only feasible moves accepted
            if cost <= cur_cost or rng.random() < math.exp(
                    -(cost - cur_cost) / (temp * max(cur_cost, 1e-9))):
                if self._feasible(cand, slo):
                    cur, cur_cost = cand, cost
                    if cost < best_cost - 1e-12:
                        best, best_cost = cand.copy(), cost
        best = self._harden(best, slo)
        best_cost = best.cost_per_hr()
        p99 = self._p99(best)
        return PlannerResult(True, best, best_cost, p99,
                             greedy.iterations + steps, self._sims)
