"""Per-model performance profiles (§4.1).

A profile captures ``batch latency = f(hardware type, max batch size)`` for
one model. The paper measures these empirically on CPUs/K80s; this port has
two backends (DESIGN.md §2):

* **analytic** — a roofline latency model over a :class:`ModelSpec`
  (FLOPs / weight bytes / activation bytes per query), evaluated against
  the TPU-native hardware menu. The FLOP/byte numbers for the assigned
  architectures are derived from the *compiled dry-run* artifacts
  (``repro.roofline``), keeping "profile once, plan offline".
* **measured** — wall-clock timing of a real callable (used for the tiny
  CPU-served models in the end-to-end executor tests/examples).

Profiles are plain tables; the Estimator interpolates them to arbitrary
batch sizes <= the configured maximum.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.hardware import (
    HARDWARE_MENU,
    HardwareType,
    get_hardware,
)

# Sustained MXU efficiency assumed by the analytic backend (fraction of
# peak for dense matmul-dominated inference at moderate batch).
MXU_EFFICIENCY = 0.55
CPU_EFFICIENCY = 0.30

DEFAULT_BATCH_SIZES: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static workload description of one model, per single query.

    A "query" is one inference request at this stage's native input size
    (e.g. one image / one `seq_len`-token text fragment).
    """

    name: str
    flops_per_query: float          # forward-pass FLOPs for batch=1
    weight_bytes: float             # parameter bytes read per batch
    act_bytes_per_query: float      # activation traffic per query
    # Bytes crossing ICI per query on a multi-chip slice (tensor-parallel
    # all-reduces); scaled by (chips-1)/chips at evaluation time.
    collective_bytes_per_query: float = 0.0
    # False for stages with no internal parallelism (paper Fig. 3
    # "preprocess"): they see no batching benefit and cannot use an
    # accelerator's parallel units.
    parallelizable: bool = True


@dataclasses.dataclass
class ModelProfile:
    """Measured/derived latency table for one model.

    ``table[(hardware_name, batch)] = seconds to process that batch``.
    """

    model_id: str
    table: Dict[Tuple[str, int], float]
    batch_sizes: Tuple[int, ...] = DEFAULT_BATCH_SIZES

    def hardware_types(self) -> List[str]:
        return sorted({hw for hw, _ in self.table})

    def supports(self, hardware: str) -> bool:
        return any(hw == hardware for hw, _ in self.table)

    def batch_latency(self, hardware: str, batch: int) -> float:
        """Latency for an arbitrary batch size (linear interpolation).

        The queueing system forms batches of any size up to the configured
        maximum, so the simulator needs off-grid points.
        """
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        pts = sorted(b for hw, b in self.table if hw == hardware)
        if not pts:
            raise KeyError(f"{self.model_id}: no profile for {hardware}")
        if batch in pts:
            return self.table[(hardware, batch)]
        if batch < pts[0]:
            return self.table[(hardware, pts[0])] * batch / pts[0]
        if batch > pts[-1]:
            # extrapolate linearly from the last segment
            if len(pts) == 1:
                return self.table[(hardware, pts[0])] * batch / pts[0]
            b0, b1 = pts[-2], pts[-1]
            l0, l1 = self.table[(hardware, b0)], self.table[(hardware, b1)]
            slope = (l1 - l0) / (b1 - b0)
            return l1 + slope * (batch - b1)
        import bisect

        i = bisect.bisect_left(pts, batch)
        b0, b1 = pts[i - 1], pts[i]
        l0, l1 = self.table[(hardware, b0)], self.table[(hardware, b1)]
        frac = (batch - b0) / (b1 - b0)
        return l0 + frac * (l1 - l0)

    def latency_lut(self, hardware: str, max_batch: int) -> np.ndarray:
        """``lut[b]`` = latency of batch b, for b in [0, max_batch]."""
        lut = np.zeros(max_batch + 1, dtype=np.float64)
        for b in range(1, max_batch + 1):
            lut[b] = self.batch_latency(hardware, b)
        return lut

    def throughput(self, hardware: str, batch: int) -> float:
        """Steady-state queries/s of ONE replica at this (hw, max batch)."""
        return batch / self.batch_latency(hardware, batch)

    def max_throughput(self, hardware: str) -> float:
        return max(self.throughput(hardware, b) for b in self.batch_sizes)

    def best_batch(self, hardware: str) -> int:
        return max(self.batch_sizes, key=lambda b: self.throughput(hardware, b))


# --------------------------------------------------------------------------
# Analytic backend
# --------------------------------------------------------------------------


def analytic_batch_latency(spec: ModelSpec, hw: HardwareType,
                           batch: int) -> float:
    """Roofline latency for one batch on one hardware type.

    latency = overhead + max(compute, memory) + collective

    * compute  = batch * flops / (peak * efficiency)
    * memory   = (weights + batch * activations) / bandwidth — weight reads
      amortize across the batch, which is exactly why batching raises
      throughput on accelerators (paper Fig. 3).
    * collective = tensor-parallel ICI traffic on multi-chip slices.

    Non-parallelizable stages run serially: latency scales linearly with
    batch and accelerators confer no benefit.
    """
    if not spec.parallelizable:
        # Runs on a single host core whatever the slice; an accelerator
        # confers no benefit and batching only serializes (Fig. 3,
        # "preprocess").
        serial = spec.flops_per_query / (
            get_hardware("cpu-1").peak_flops * CPU_EFFICIENCY
        )
        return hw.overhead_s + batch * serial

    eff = MXU_EFFICIENCY if hw.is_accelerator() else CPU_EFFICIENCY
    compute = batch * spec.flops_per_query / (hw.peak_flops * eff)
    memory = (spec.weight_bytes + batch * spec.act_bytes_per_query) / hw.mem_bw
    lat = hw.overhead_s + max(compute, memory)
    if hw.chips > 1 and hw.ici_bw > 0:
        frac = (hw.chips - 1) / hw.chips
        lat += batch * spec.collective_bytes_per_query * frac / hw.ici_bw
    return lat


def profile_model_analytic(
    spec: ModelSpec,
    hardware_options: Optional[Iterable[str]] = None,
    batch_sizes: Tuple[int, ...] = DEFAULT_BATCH_SIZES,
) -> ModelProfile:
    names = list(hardware_options) if hardware_options is not None else [
        h.name for h in HARDWARE_MENU
    ]
    table: Dict[Tuple[str, int], float] = {}
    for name in names:
        hw = get_hardware(name)
        for b in batch_sizes:
            table[(name, b)] = analytic_batch_latency(spec, hw, b)
    return ModelProfile(spec.name, table, batch_sizes)


# --------------------------------------------------------------------------
# Measured backend
# --------------------------------------------------------------------------


def profile_model_measured(
    model_id: str,
    run_batch: Callable[[int], None],
    hardware_name: str = "cpu-1",
    batch_sizes: Tuple[int, ...] = (1, 2, 4, 8, 16, 32),
    repeats: int = 3,
    warmup: int = 1,
) -> ModelProfile:
    """Wall-clock profile of a real callable (used with tiny JAX models).

    ``run_batch(b)`` must execute one batch of size ``b`` synchronously
    (i.e. call ``jax.block_until_ready`` internally).
    """
    table: Dict[Tuple[str, int], float] = {}
    for b in batch_sizes:
        for _ in range(warmup):
            run_batch(b)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            run_batch(b)
            best = min(best, time.perf_counter() - t0)
        table[(hardware_name, b)] = best
    return ModelProfile(model_id, table, batch_sizes)


class ProfileStore:
    """Registry mapping model_id -> ModelProfile (saved & reused, §4.1)."""

    def __init__(self, profiles: Optional[Dict[str, ModelProfile]] = None):
        self._profiles: Dict[str, ModelProfile] = dict(profiles or {})

    def add(self, profile: ModelProfile) -> None:
        self._profiles[profile.model_id] = profile

    def get(self, model_id: str) -> ModelProfile:
        try:
            return self._profiles[model_id]
        except KeyError:
            raise KeyError(
                f"no profile for {model_id!r}; have {sorted(self._profiles)}"
            ) from None

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._profiles

    def model_ids(self) -> List[str]:
        return sorted(self._profiles)
