"""The Estimator (§4.2): thin façade over the unified simulation engine.

Given a pipeline configuration, per-model profiles, and an arrival trace,
returns an accurate latency estimate for *each query* in the trace.

The actual discrete-event core lives in :mod:`repro.sim` (engine design
notes in that module and EXPERIMENTS.md §Perf); this module keeps the
paper-facing API — ``Estimator.simulate`` and the planner helpers — and
re-exports :class:`repro.sim.SimResult` so existing imports keep working.
Consumers that evaluate many configurations against one trace (the
Planner, the Tuner sweeps) should open ``Estimator.session(arrivals)``
to get incremental re-simulation.

Dynamic replica schedules (for the live-cluster simulation driving the
Tuner) are supported via per-stage ``(time, +1/-1)`` replica events; see
``repro.serving.cluster``.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.pipeline import Pipeline, PipelineConfig
from repro.core.profiler import ProfileStore
from repro.sim import DEFAULT_RPC_DELAY_S, SimEngine, SimResult, TraceSession
from repro.sim.queueing import simulate_stage as _policy_simulate_stage

__all__ = ["DEFAULT_RPC_DELAY_S", "Estimator", "SimResult"]


def _simulate_stage(
    ready: np.ndarray,
    order: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Back-compat shim for the seed's private stage simulator.

    `order` is the original-index alignment kept by the caller; the
    returned completions align with the sorted `ready` input, exactly as
    before. New code should call :func:`repro.sim.simulate_stage`.
    """
    del order  # alignment is the caller's concern, as in the seed
    done, batches, _ = _policy_simulate_stage(
        "fifo", ready, latency_lut, max_batch, replicas,
        replica_events, timeout_s)
    return done, batches


class Estimator:
    """Simulates a pipeline configuration over an arrival trace."""

    def __init__(
        self,
        pipeline: Pipeline,
        profiles: ProfileStore,
        rpc_delay_s: float = DEFAULT_RPC_DELAY_S,
        seed: int = 0,
    ):
        self.pipeline = pipeline
        self.profiles = profiles
        self.rpc_delay_s = rpc_delay_s
        self.seed = seed
        self.engine = SimEngine(pipeline, profiles, rpc_delay_s=rpc_delay_s,
                                seed=seed)

    def session(self, arrivals: np.ndarray,
                slo_s: Optional[Union[float, np.ndarray]] = None,
                class_ids: Optional[np.ndarray] = None,
                class_names: Optional[Sequence[str]] = None,
                backend: str = "numpy") -> TraceSession:
        """Bind to one trace for incremental re-simulation across configs.

        ``backend="jax"`` routes eligible candidate grids through the
        device kernels (:mod:`repro.sim.jax_backend`); bit-identical to
        the default numpy path."""
        return self.engine.session(arrivals, slo_s=slo_s,
                                   class_ids=class_ids,
                                   class_names=class_names,
                                   backend=backend)

    def simulate(
        self,
        config: PipelineConfig,
        arrivals: np.ndarray,
        replica_schedules: Optional[Dict[str, Sequence[Tuple[float, int]]]] = None,
        slo_s: Optional[Union[float, np.ndarray]] = None,
        class_ids: Optional[np.ndarray] = None,
        class_names: Optional[Sequence[str]] = None,
    ) -> SimResult:
        """Run the trace through the configured pipeline.

        Args:
          config: per-stage (hardware, batch, replicas[, policy]).
          arrivals: (n,) sorted arrival times in seconds.
          replica_schedules: optional dynamic scaling events per stage
            (used by the live-cluster simulation; see module docstring).
          slo_s: optional per-query deadline horizon (arrival + slo_s),
            consumed by deadline-aware policies (``edf``, ``slo-drop``).
            Scalar = uniform SLO; an (n,) vector carries mixed per-query
            SLO classes (:mod:`repro.workload.slo_classes`).
          class_ids / class_names: optional per-query SLO-class tags for
            ``SimResult.per_class`` breakdowns.
        """
        return self.engine.simulate(config, arrivals,
                                    replica_schedules=replica_schedules,
                                    slo_s=slo_s, class_ids=class_ids,
                                    class_names=class_names)

    def simulate_many(
        self,
        configs: Sequence[PipelineConfig],
        arrivals: np.ndarray,
        replica_schedules: Optional[Dict[str, Sequence[Tuple[float, int]]]] = None,
    ) -> Sequence[SimResult]:
        """Batched candidate evaluation over one trace: every distinct
        stage entry is simulated exactly once and result assembly is
        shared across candidates with common configuration prefixes
        (see :meth:`repro.sim.TraceSession.simulate_many`). Element-wise
        equal to ``[self.simulate(c, arrivals) for c in configs]``."""
        return self.session(arrivals).simulate_many(
            configs, replica_schedules=replica_schedules)

    # -- planner-facing helpers ----------------------------------------------
    def estimate_p99(self, config: PipelineConfig, arrivals: np.ndarray) -> float:
        return self.simulate(config, arrivals).p99

    def is_feasible(self, config: PipelineConfig, arrivals: np.ndarray,
                    slo: float, percentile: float = 99.0) -> bool:
        res = self.simulate(config, arrivals)
        return res.percentile(percentile) <= slo

    def service_time(self, config: PipelineConfig) -> float:
        """Sum of batch-size-configured latencies along the longest path
        (queueing excluded) — Alg. 1's `ServiceTime`."""
        return self.engine.service_time(config)
