"""The Estimator (§4.2): continuous-time discrete-event pipeline simulator.

Given a pipeline configuration, per-model profiles, and an arrival trace,
returns an accurate latency estimate for *each query* in the trace.

Engine design (beyond-paper fast path, recorded in EXPERIMENTS.md §Perf):
the paper implements a global event heap over the whole pipeline. Because
(a) routing is feed-forward (DAG) and (b) the centralized batched queue at
a stage depends only on that stage's input arrival times and its own
replica schedule, we simulate *stage-by-stage in topological order*. Each
stage is a single-queue / R-server / batch-service system simulated with a
tiny heap over replica free-times — O(n log R) per stage instead of a
global O(E log E) heap. Hours of traces simulate in hundreds of
milliseconds, matching the paper's C++ estimator in Python.

Dynamic replica schedules (for the live-cluster simulation driving the
Tuner) are supported via per-stage ``(time, +1/-1)`` replica events; see
``repro.serving.cluster``.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline import SOURCE, Pipeline, PipelineConfig
from repro.core.profiler import ProfileStore

# Per-hop RPC/serialization delay. The frontend adapters (Fig. 13) override
# this: the "tfs"-style frontend carries extra serialization overhead.
DEFAULT_RPC_DELAY_S = 0.0005

_FAR_FUTURE = 1e18


@dataclasses.dataclass
class SimResult:
    """Per-query outcome of one simulation run."""

    arrival: np.ndarray            # (n,) arrival time of each query
    latency: np.ndarray            # (n,) end-to-end latency (s)
    per_stage_batches: Dict[str, np.ndarray]  # stage -> batch sizes formed

    @property
    def num_queries(self) -> int:
        return int(self.arrival.shape[0])

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latency, p)) if self.latency.size else 0.0

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean(self) -> float:
        return float(self.latency.mean()) if self.latency.size else 0.0

    def slo_miss_rate(self, slo: float) -> float:
        if not self.latency.size:
            return 0.0
        return float((self.latency > slo).mean())

    def slo_attainment(self, slo: float) -> float:
        return 1.0 - self.slo_miss_rate(slo)

    def windowed_miss_rate(self, slo: float, window_s: float = 5.0
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """(window_start_times, miss_rate per window) for time-series plots."""
        if not self.latency.size:
            return np.zeros(0), np.zeros(0)
        t_end = float(self.arrival.max())
        edges = np.arange(0.0, t_end + window_s, window_s)
        idx = np.clip(np.digitize(self.arrival, edges) - 1, 0, len(edges) - 1)
        miss = (self.latency > slo).astype(np.float64)
        rates = np.full(len(edges), np.nan)
        for w in range(len(edges)):
            sel = idx == w
            if sel.any():
                rates[w] = miss[sel].mean()
        return edges, rates


def _simulate_stage(
    ready: np.ndarray,
    order: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Simulate one stage's centralized batched queue.

    Args:
      ready: (k,) ready times of the queries visiting this stage, SORTED.
      order: (k,) original query indices aligned with `ready`.
      latency_lut: lut[b] = batch latency of batch size b (len max_batch+1).
      max_batch: configured maximum batch size.
      replicas: initial replica count.
      replica_events: optional [(t, +1/-1), ...] dynamic scaling events,
        sorted by t. +1 adds a replica that becomes available at t (the
        activation delay is applied by the caller); -1 retires the next
        replica to become idle at/after t.

    Returns:
      (completion_times aligned with `order`, batch sizes formed).
    """
    k = ready.shape[0]
    done = np.empty(k, dtype=np.float64)
    batches: List[int] = []
    if k == 0:
        return done, np.zeros(0, dtype=np.int64)

    # Replica pool: heap of free-at times.
    free: List[float] = [0.0] * max(replicas, 0)
    heapq.heapify(free)
    ev = list(replica_events or [])
    ev_i = 0
    pending_removals: List[float] = []   # times at which a removal takes effect

    def apply_events(now: float) -> None:
        nonlocal ev_i
        while ev_i < len(ev) and ev[ev_i][0] <= now:
            t, delta = ev[ev_i]
            ev_i += 1
            if delta > 0:
                for _ in range(delta):
                    heapq.heappush(free, t)
            else:
                for _ in range(-delta):
                    pending_removals.append(t)

    ptr = 0
    lat_len = latency_lut.shape[0]
    while ptr < k:
        if not free:
            # all replicas retired; fast-forward to next add event
            if ev_i < len(ev):
                apply_events(ev[ev_i][0])
                continue
            # no capacity ever again: remaining queries never complete
            done[ptr:] = _FAR_FUTURE
            break
        f = heapq.heappop(free)
        start = max(f, ready[ptr])
        apply_events(start)
        # retire this replica if a removal is pending at/earlier than now
        if pending_removals and pending_removals[0] <= start:
            pending_removals.pop(0)
            continue
        # batch = all queries ready by `start`, capped at max_batch
        hi = ptr
        limit = ptr + max_batch
        while hi < k and hi < limit and ready[hi] <= start:
            hi += 1
        if hi == ptr:
            # replica was idle before the next arrival: it serves that
            # arrival (plus any simultaneous ones) immediately
            start = ready[ptr]
            while hi < k and hi < limit and ready[hi] <= start:
                hi += 1
        if timeout_s > 0.0 and hi < limit and hi < k:
            # timeout batching (beyond-paper): hold the batch open until
            # either max_batch queries are ready or `timeout_s` elapses
            # from the head-of-line query's arrival — trading head
            # latency for per-replica throughput
            deadline = ready[ptr] + timeout_s
            if deadline > start:
                fill_t = ready[limit - 1] if limit - 1 < k else _FAR_FUTURE
                start = min(max(start, fill_t), deadline)
                while hi < k and hi < limit and ready[hi] <= start:
                    hi += 1
        b = hi - ptr
        lat = latency_lut[b] if b < lat_len else latency_lut[-1] * b / (lat_len - 1)
        end = start + lat
        done[ptr:hi] = end
        batches.append(b)
        ptr = hi
        heapq.heappush(free, end)

    completion = np.empty(k, dtype=np.float64)
    completion[:] = done
    return completion, np.asarray(batches, dtype=np.int64)


class Estimator:
    """Simulates a pipeline configuration over an arrival trace."""

    def __init__(
        self,
        pipeline: Pipeline,
        profiles: ProfileStore,
        rpc_delay_s: float = DEFAULT_RPC_DELAY_S,
        seed: int = 0,
    ):
        self.pipeline = pipeline
        self.profiles = profiles
        self.rpc_delay_s = rpc_delay_s
        self.seed = seed
        self._topo = pipeline.toposort()
        self._edges_in: Dict[str, List] = {
            s: [e for e in pipeline.edges if e.dst == s] for s in self._topo
        }

    # -- conditional routing ------------------------------------------------
    def _edge_draws(self, n: int) -> Dict[Tuple[str, str], np.ndarray]:
        """Pre-sample Bernoulli outcomes per (edge, query).

        Fixed seed => identical routing across candidate configurations, as
        the paper reuses one sample trace across the whole search.
        """
        rng = np.random.default_rng(self.seed)
        draws = {}
        for e in self.pipeline.edges:
            if e.probability >= 1.0:
                draws[(e.src, e.dst)] = np.ones(n, dtype=bool)
            else:
                draws[(e.src, e.dst)] = rng.random(n) < e.probability
        return draws

    def simulate(
        self,
        config: PipelineConfig,
        arrivals: np.ndarray,
        replica_schedules: Optional[Dict[str, Sequence[Tuple[float, int]]]] = None,
    ) -> SimResult:
        """Run the trace through the configured pipeline.

        Args:
          config: per-stage (hardware, batch, replicas).
          arrivals: (n,) sorted arrival times in seconds.
          replica_schedules: optional dynamic scaling events per stage
            (used by the live-cluster simulation; see module docstring).
        """
        arrivals = np.asarray(arrivals, dtype=np.float64)
        n = arrivals.shape[0]
        draws = self._edge_draws(n)

        visited: Dict[str, np.ndarray] = {SOURCE: np.ones(n, dtype=bool)}
        # ready_time[s][q] = time query q is ready at stage s (AND-join: max
        # over active incoming deliveries); completion[s][q] = finish time.
        ready_time: Dict[str, np.ndarray] = {SOURCE: arrivals}
        completion: Dict[str, np.ndarray] = {SOURCE: arrivals}
        last_done = np.array(arrivals, copy=True)  # ingress counts as t0
        per_stage_batches: Dict[str, np.ndarray] = {}

        for stage in self._topo:
            vis = np.zeros(n, dtype=bool)
            ready = np.zeros(n, dtype=np.float64)
            for e in self._edges_in[stage]:
                active = visited[e.src] & draws[(e.src, e.dst)]
                deliver = completion[e.src] + self.rpc_delay_s
                # AND-join over active parents
                ready = np.where(active, np.maximum(ready, deliver), ready)
                vis |= active
            visited[stage] = vis
            k = int(vis.sum())
            if k == 0:
                ready_time[stage] = ready
                completion[stage] = np.full(n, -np.inf)
                per_stage_batches[stage] = np.zeros(0, dtype=np.int64)
                continue

            cfg = config[stage]
            prof = self.profiles.get(self.pipeline.stages[stage].model_id)
            lut = prof.latency_lut(cfg.hardware, cfg.batch_size)

            idx = np.nonzero(vis)[0]
            order = idx[np.argsort(ready[idx], kind="stable")]
            sorted_ready = ready[order]
            sched = (replica_schedules or {}).get(stage)
            comp_sorted, batches = _simulate_stage(
                sorted_ready, order, lut, cfg.batch_size, cfg.replicas,
                sched, timeout_s=getattr(cfg, "timeout_s", 0.0)
            )
            comp = np.full(n, -np.inf)
            comp[order] = comp_sorted
            ready_time[stage] = ready
            completion[stage] = comp
            per_stage_batches[stage] = batches
            last_done = np.where(vis, np.maximum(last_done, comp), last_done)

        latency = last_done - arrivals + self.rpc_delay_s  # final reply hop
        return SimResult(arrivals, latency, per_stage_batches)

    # -- planner-facing helpers ----------------------------------------------
    def estimate_p99(self, config: PipelineConfig, arrivals: np.ndarray) -> float:
        return self.simulate(config, arrivals).p99

    def is_feasible(self, config: PipelineConfig, arrivals: np.ndarray,
                    slo: float, percentile: float = 99.0) -> bool:
        res = self.simulate(config, arrivals)
        return res.percentile(percentile) <= slo

    def service_time(self, config: PipelineConfig) -> float:
        """Sum of batch-size-configured latencies along the longest path
        (queueing excluded) — Alg. 1's `ServiceTime`."""
        total = 0.0
        path = self.pipeline.longest_path_stages()
        for stage in path:
            cfg = config[stage]
            prof = self.profiles.get(self.pipeline.stages[stage].model_id)
            total += prof.batch_latency(cfg.hardware, cfg.batch_size)
            total += self.rpc_delay_s
        return total + self.rpc_delay_s
