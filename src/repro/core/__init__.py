"""InferLine core: pipeline spec, profiler, estimator, planner, tuner."""

from repro.core.envelope import TrafficEnvelope, envelope_windows  # noqa: F401
from repro.core.hardware import (  # noqa: F401
    HARDWARE_MENU,
    HardwareType,
    cheaper_hardware,
    get_hardware,
)
from repro.core.pipeline import (  # noqa: F401
    SOURCE,
    Edge,
    Pipeline,
    PipelineConfig,
    Stage,
    StageConfig,
    linear_pipeline,
)
from repro.core.profiler import (  # noqa: F401
    ModelProfile,
    ModelSpec,
    ProfileStore,
    profile_model_analytic,
    profile_model_measured,
)

# Estimator/Planner/Tuner re-exports are lazy (PEP 562): estimator and
# planner pull in repro.sim, which itself imports repro.core.pipeline —
# importing them eagerly here would make `import repro.sim` fail when it
# runs before `import repro.core` (circular package init).
_LAZY_EXPORTS = {
    "Estimator": "repro.core.estimator",
    "SimResult": "repro.core.estimator",
    "Planner": "repro.core.planner",
    "PlannerResult": "repro.core.planner",
    "Tuner": "repro.core.tuner",
    "TunerPlanInfo": "repro.core.tuner",
    "run_tuner_offline": "repro.core.tuner",
}


def __getattr__(name):
    module = _LAZY_EXPORTS.get(name)
    if module is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(module), name)
