"""InferLine core: pipeline spec, profiler, estimator, planner, tuner."""

from repro.core.envelope import TrafficEnvelope, envelope_windows  # noqa: F401
from repro.core.estimator import Estimator, SimResult  # noqa: F401
from repro.core.hardware import (  # noqa: F401
    HARDWARE_MENU,
    HardwareType,
    cheaper_hardware,
    get_hardware,
)
from repro.core.pipeline import (  # noqa: F401
    SOURCE,
    Edge,
    Pipeline,
    PipelineConfig,
    Stage,
    StageConfig,
    linear_pipeline,
)
from repro.core.planner import Planner, PlannerResult  # noqa: F401
from repro.core.profiler import (  # noqa: F401
    ModelProfile,
    ModelSpec,
    ProfileStore,
    profile_model_analytic,
    profile_model_measured,
)
from repro.core.tuner import (  # noqa: F401
    Tuner,
    TunerPlanInfo,
    run_tuner_offline,
)
