"""Network-calculus traffic envelopes (§5, Fig. 4).

A traffic envelope maps window sizes ``dT_i`` to the maximum number of
queries observed in ANY window of that width over a trace — a multi-scale
characterization that simultaneously captures burstiness (small windows)
and sustained rate (large windows).

Window sizes follow the paper: the smallest is the pipeline service time
``T_s``, doubling up to 60 seconds.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


def envelope_windows(service_time_s: float, max_window_s: float = 60.0
                     ) -> np.ndarray:
    """dT_i = T_s * 2^i, i = 0.. until >= max_window_s (inclusive cap)."""
    ts = max(service_time_s, 1e-3)
    ws = [ts]
    while ws[-1] < max_window_s:
        ws.append(min(ws[-1] * 2.0, max_window_s))
    # dedupe the cap if T_s*2^k lands exactly on 60
    out = np.asarray(sorted(set(ws)))
    return out


def max_queries_in_window(arrivals: np.ndarray, window_s: float) -> int:
    """Max #arrivals in any half-open interval of width `window_s`.

    Two-pointer sweep anchored at each arrival: the maximizing window can
    always be shifted to start at an arrival instant.
    """
    n = arrivals.shape[0]
    if n == 0:
        return 0
    j = 0
    best = 1
    for i in range(n):
        if arrivals[i] < arrivals[j]:
            raise ValueError("arrivals must be sorted")
        while arrivals[i] - arrivals[j] >= window_s:
            j += 1
        best = max(best, i - j + 1)
    return best


def _max_counts_vectorized(arrivals: np.ndarray, windows: np.ndarray
                           ) -> np.ndarray:
    """Vectorized two-pointer via searchsorted, one pass per window."""
    n = arrivals.shape[0]
    out = np.zeros(windows.shape[0], dtype=np.int64)
    if n == 0:
        return out
    for wi, w in enumerate(windows):
        # count of arrivals in [t_j, t_j + w) for every anchor j
        hi = np.searchsorted(arrivals, arrivals + w, side="left")
        out[wi] = int((hi - np.arange(n)).max())
    return out


@dataclasses.dataclass(frozen=True)
class TrafficEnvelope:
    """Envelope: windows (s) -> max query counts -> implied max rates."""

    windows: np.ndarray          # (W,) seconds
    max_counts: np.ndarray       # (W,) queries

    @property
    def rates(self) -> np.ndarray:
        """r_i = q_i / dT_i (queries/s)."""
        return self.max_counts / self.windows

    @staticmethod
    def from_trace(arrivals: np.ndarray, service_time_s: float,
                   max_window_s: float = 60.0) -> "TrafficEnvelope":
        arrivals = np.asarray(arrivals, dtype=np.float64)
        windows = envelope_windows(service_time_s, max_window_s)
        counts = _max_counts_vectorized(arrivals, windows)
        return TrafficEnvelope(windows, counts)

    def exceeded_by(self, other: "TrafficEnvelope",
                    tolerance: float = 1.05,
                    abs_slack: int = 2) -> Tuple[bool, float]:
        """Does `other` (current workload) exceed this (planned) envelope?

        Returns (exceeded, r_max) where r_max is the largest violating rate
        (§5: "In the case that multiple rates have exceeded their sample
        trace counterpart, we take the max rate.").

        A small tolerance (rel x abs) filters sampling noise: a fresh
        same-law trace exceeds SOME window's exact max count ~half the
        time, and a handful of extra queries in a 100 ms window converts
        into a large sustained-rate requirement (observed: 2.5x
        over-scaling on a flat trace). Genuine burstiness/rate changes
        move counts well past 5%+2.
        """
        if not np.allclose(self.windows, other.windows):
            raise ValueError("envelopes must share window sizes")
        limit = np.maximum(self.max_counts * tolerance,
                           self.max_counts + abs_slack)
        over = other.max_counts > limit
        if not over.any():
            return False, 0.0
        return True, float(other.rates[over].max())

    def describe(self) -> str:
        rows = [
            f"  dT={w:8.3f}s  q_max={int(q):7d}  rate={r:9.2f} qps"
            for w, q, r in zip(self.windows, self.max_counts, self.rates)
        ]
        return "\n".join(rows)


class IncrementalEnvelope:
    """Streaming traffic envelope over a growing arrival prefix.

    The closed-loop co-simulation (:mod:`repro.sim.control`) observes
    ingress one epoch at a time; recomputing ``TrafficEnvelope.from_trace``
    on the whole prefix every epoch is O(n * W) per step. This maintains
    the same per-window max counts incrementally: each ``extend`` only
    scans the NEW arrivals, using the end-anchored formulation — the max
    over windows whose *last* contained arrival is index ``i`` is
    ``i - first index j with t_j > t_i - w + 1`` — which equals the
    start-anchored max of :func:`_max_counts_vectorized` (every maximal
    window can be shifted so an arrival is last in it).

    ``snapshot()`` is property-tested equal to ``from_trace`` on the
    prefix (``tests/test_envelope.py``).
    """

    def __init__(self, service_time_s: float, max_window_s: float = 60.0):
        self.windows = envelope_windows(service_time_s, max_window_s)
        self.max_counts = np.zeros(self.windows.shape[0], dtype=np.int64)
        self._arr = np.zeros(0, dtype=np.float64)

    @property
    def n(self) -> int:
        return int(self._arr.shape[0])

    def extend(self, new_arrivals: np.ndarray) -> "IncrementalEnvelope":
        """Fold in arrivals at/after everything seen so far (sorted)."""
        new = np.asarray(new_arrivals, dtype=np.float64)
        if new.size == 0:
            return self
        if new.size > 1 and np.any(np.diff(new) < 0):
            raise ValueError("new arrivals must be sorted")
        if self._arr.size and new[0] < self._arr[-1]:
            raise ValueError("arrivals must extend the observed prefix")
        n_old = self._arr.shape[0]
        arr = np.concatenate([self._arr, new])
        idx_new = np.arange(n_old, arr.shape[0])
        for wi, w in enumerate(self.windows):
            # window ending at each new arrival: count of t_j > t_new - w
            lo = np.searchsorted(arr, new - w, side="right")
            best = int((idx_new - lo + 1).max())
            if best > self.max_counts[wi]:
                self.max_counts[wi] = best
        self._arr = arr
        return self

    def snapshot(self) -> TrafficEnvelope:
        return TrafficEnvelope(self.windows, self.max_counts.copy())
