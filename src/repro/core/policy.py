"""Runtime-agnostic queueing-policy core (shared by sim and live serving).

InferLine's contract (§3, §5) is that the planner/tuner manage *any*
serving runtime offering centralized batched queues, a configurable max
batch size, and runtime replica scaling. That only holds if the
controller's model of the queue discipline matches what the runtime
actually does — so the batch-formation semantics of the three queueing
policies live HERE, in one module, and both backends consume them:

* the discrete-event simulator (:mod:`repro.sim.queueing`) calls the
  scalar selection primitives (:func:`edf_select`,
  :func:`slo_drop_select`) and the :class:`ShedMarginSchedule`
  evaluation inside its per-stage loops (its vectorized FIFO fill is an
  optimized equivalent, golden-guarded bit-identical to
  :func:`fifo_select`-driven stepping);
* the wall-clock executor (:mod:`repro.serving.executor`) drives a
  :class:`LiveQueue` per stage, whose ``form_batch`` applies the same
  primitives to streaming requests.

The module also hosts :func:`simulate_stage_ref` — a scalar reference
simulator over the primitives. It is the equivalence oracle for the
policy-core property suite (``tests/test_policy_core.py``: bit-identical
to every :mod:`repro.sim.queueing` policy on random traces) and the
execution path for *policy-switching* stages: a
:class:`PolicySchedule` (piecewise ``fifo -> edf`` etc.) is evaluated at
each batch start, which is exactly what a schedulable policy-switch
:class:`~repro.control.ControlEvent` folds into.

Policy semantics (shared, batch formed at dispatch instant ``start``):

* ``fifo``     — arrival order, up to ``max_batch`` of the queries with
  ``ready <= start`` (plus the optional batch-formation timeout hold);
* ``edf``      — among queries with ``ready <= start``, the ``max_batch``
  earliest deadlines;
* ``slo-drop`` — arrival order, but a query whose deadline cannot be met
  even by a batch-1 dispatch right now
  (``deadline < start + solo_latency + margin(start)``) is shed instead
  of served.
"""

from __future__ import annotations

import bisect
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_FAR_FUTURE = 1e18

POLICY_NAMES: Tuple[str, ...] = ("fifo", "edf", "slo-drop")


def check_policy_name(name: str) -> str:
    if name not in POLICY_NAMES:
        raise ValueError(
            f"unknown queueing policy {name!r}; have {sorted(POLICY_NAMES)}")
    return name


def effective_max_batch(latency_lut: np.ndarray, max_batch: int) -> int:
    """Clamp the configured max batch to the profiled LUT range (a batch
    above the largest profiled size must never silently extrapolate)."""
    lat_len = int(latency_lut.shape[0])
    if lat_len < 2:
        raise ValueError(
            f"latency LUT must cover at least batch=1 (got {lat_len} entries)")
    return min(int(max_batch), lat_len - 1)


# -- piecewise-constant control schedules -----------------------------------


class ShedMarginSchedule:
    """Piecewise-constant slo-drop shed margin ``m(t)``.

    Built from a sorted ``(t, margin_s)`` event list; before the first
    event the margin is 0 (the policy's historical behavior), ``m > 0``
    sheds proactively, ``m = -inf`` disables shedding entirely. Batch
    starts are not monotone under dynamic replica pools, so lookups
    bisect rather than stream.
    """

    __slots__ = ("ts", "ms")

    def __init__(self, events: Optional[Sequence[Tuple[float, float]]] = None):
        ev = sorted(events) if events else []
        self.ts: List[float] = [t for t, _ in ev]
        self.ms: List[float] = [m for _, m in ev]

    def margin(self, t: float) -> float:
        if not self.ts:
            return 0.0
        si = bisect.bisect_right(self.ts, t)
        return self.ms[si - 1] if si else 0.0

    def __bool__(self) -> bool:
        return bool(self.ts)


class PolicySchedule:
    """Piecewise-constant queueing policy ``p(t)``: a base policy plus
    sorted ``(t, policy_name)`` switch events. The policy in force for a
    batch is ``policy_at(start)`` of the batch's dispatch instant —
    the semantics a scheduled fifo->edf :class:`~repro.control
    .ControlEvent` lands with, in simulation and live serving alike."""

    __slots__ = ("base", "ts", "ps")

    def __init__(self, base: str,
                 events: Optional[Sequence[Tuple[float, str]]] = None):
        self.base = check_policy_name(base)
        ev = sorted(events, key=lambda e: e[0]) if events else []
        self.ts: List[float] = [t for t, _ in ev]
        self.ps: List[str] = [check_policy_name(p) for _, p in ev]

    def policy_at(self, t: float) -> str:
        if not self.ts:
            return self.base
        si = bisect.bisect_right(self.ts, t)
        return self.ps[si - 1] if si else self.base

    def constant(self) -> bool:
        return not self.ts

    def __bool__(self) -> bool:
        return bool(self.ts)


# -- the shared replica pool ------------------------------------------------


class ReplicaPool:
    """Heap of replica free-times plus the (t, +/-1) dynamic scale events.

    ``+1`` adds a replica free at ``t``; ``-1`` retires the next replica
    to go idle at/after ``t`` (scale-down drains: an in-service batch
    always completes). Shared by every simulator policy loop.
    """

    def __init__(self, replicas: int,
                 events: Optional[Sequence[Tuple[float, int]]]):
        self.free: List[float] = [0.0] * max(replicas, 0)
        heapq.heapify(self.free)
        # sort by t only (stable): a same-t (+1,-1) churn pair must keep
        # arrival order — a full-tuple sort would drain before adding
        self.events = (sorted(events, key=lambda e: e[0])
                       if events else [])
        self.ev_i = 0
        self.pending_removals: List[float] = []

    def apply_events(self, now: float) -> None:
        while self.ev_i < len(self.events) and self.events[self.ev_i][0] <= now:
            t, delta = self.events[self.ev_i]
            self.ev_i += 1
            if delta > 0:
                for _ in range(delta):
                    heapq.heappush(self.free, t)
            else:
                for _ in range(-delta):
                    self.pending_removals.append(t)

    def has_future_adds(self) -> bool:
        return self.ev_i < len(self.events)

    def fast_forward(self) -> None:
        self.apply_events(self.events[self.ev_i][0])

    def retire_if_pending(self, now: float) -> bool:
        """True if the just-popped replica is retired by a pending removal."""
        if self.pending_removals and self.pending_removals[0] <= now:
            self.pending_removals.pop(0)
            return True
        return False


# -- batch-formation primitives ---------------------------------------------
#
# These are the exact scalar selection loops of the simulator policies,
# parameterized so the live executor and the reference simulator can run
# them over non-contiguous pending sets: `served` (optional mapping
# index -> consumed?) lets a caller interleave policies over one queue.


def fifo_select(ready_l, served, i: int, k: int, start: float,
                max_batch: int) -> Tuple[List[int], int]:
    """Arrival-order batch at `start`: up to `max_batch` entries with
    ``ready <= start`` from cursor `i`. Returns (take, new_cursor).

    Semantics are mirrored by the streaming walk in
    :meth:`LiveQueue.form_batch` — change both together."""
    take: List[int] = []
    while i < k and len(take) < max_batch:
        if served is not None and served[i]:
            i += 1
            continue
        if ready_l[i] > start:
            break
        take.append(i)
        i += 1
    return take, i


def edf_select(pending: List[Tuple[float, int]], ready_l, start: float,
               max_batch: int, served=None) -> List[int]:
    """Pop the (up to) `max_batch` earliest-deadline READY entries off the
    ``(deadline, idx)`` heap. A popped entry not yet ready at `start`
    (dispatch times are not monotone across replicas) is deferred and
    re-pushed; an entry consumed by another policy while queued
    (``served``) is discarded."""
    take: List[int] = []
    deferred: List[Tuple[float, int]] = []
    while pending and len(take) < max_batch:
        item = heapq.heappop(pending)
        if served is not None and served[item[1]]:
            continue
        if ready_l[item[1]] <= start:
            take.append(item[1])
        else:
            deferred.append(item)
    for item in deferred:
        heapq.heappush(pending, item)
    return take


def slo_drop_select(ready_l, deadline_l, served, i: int, k: int,
                    start: float, floor: float, max_batch: int
                    ) -> Tuple[List[int], List[int], int]:
    """Arrival-order batch with SLO-aware shedding at dequeue: an entry
    whose ``deadline < floor`` (``floor = start + solo_latency +
    margin(start)``) is shed instead of served. Returns
    (take, shed, new_cursor); every scanned entry is consumed.

    Semantics are mirrored by the streaming walk in
    :meth:`LiveQueue.form_batch` — change both together."""
    take: List[int] = []
    shed: List[int] = []
    while i < k and len(take) < max_batch:
        if served is not None and served[i]:
            i += 1
            continue
        if ready_l[i] > start:
            break
        if deadline_l[i] < floor:
            shed.append(i)
        else:
            take.append(i)
        i += 1
    return take, shed, i


# -- scalar reference stage simulator ---------------------------------------


def simulate_stage_ref(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
    shed_events: Optional[Sequence[Tuple[float, float]]] = None,
    policy: str = "fifo",
    policy_events: Optional[Sequence[Tuple[float, str]]] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One centralized stage queue, R servers, policy-core stepping.

    The canonical scalar semantics of all three policies over one
    pending set — bit-identical to the dedicated (vectorized/hoisted)
    :mod:`repro.sim.queueing` policies when ``policy_events`` is empty
    (pinned by ``tests/test_policy_core.py``), and the execution path
    for piecewise policy schedules: the policy in force is evaluated at
    each batch's dispatch instant, so a fifo->edf switch at ``t`` starts
    deadline-ordering every batch dispatched from ``t`` on, over the
    queue as it stands.

    ``timeout_s`` applies to batches formed under ``fifo`` (the
    beyond-paper formation hold); ``edf``/``slo-drop`` batches ignore it,
    exactly as the dedicated policies do. Returns (completion times
    aligned with `ready`, per-batch sizes, shed mask).
    """
    k = int(ready.shape[0])
    done = np.full(k, _FAR_FUTURE, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return done, np.zeros(0, dtype=np.int64), dropped
    eff_batch = effective_max_batch(latency_lut, max_batch)
    pol = PolicySchedule(policy, policy_events)
    # the dedicated slo-drop (and edf key) semantics without deadlines:
    # slo-drop reduces to greedy fifo (timeout ignored), edf orders by
    # ready time
    have_deadline = deadline is not None
    ready_l: List[float] = ready.tolist()
    lut_l: List[float] = latency_lut.tolist()
    deadline_l: List[float] = (deadline.tolist() if have_deadline
                               else ready_l)
    solo_lat = lut_l[1]
    shed = ShedMarginSchedule(shed_events)
    pool = ReplicaPool(replicas, replica_events)
    served = [False] * k
    batches: List[int] = []
    edf_heap: List[Tuple[float, int]] = []     # (deadline, idx), lazily fed
    ai = 0                                     # next un-admitted index
    ptr = 0                                    # first possibly-pending index
    remaining = k

    while remaining > 0:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            break                   # starved: leftovers keep _FAR_FUTURE
        f = heapq.heappop(pool.free)
        while ptr < k and served[ptr]:
            ptr += 1
        r0 = ready_l[ptr]           # earliest pending ready (sorted input)
        start = r0 if r0 > f else f
        pool.apply_events(start)
        if pool.retire_if_pending(start):
            continue
        p = pol.policy_at(start)
        # the formation timeout belongs to fifo alone; a deadline-less
        # slo-drop batch degrades to greedy fifo but keeps timeout
        # disabled, so a stage config means the same system with and
        # without an slo_s (the dedicated policy's documented contract)
        p_timeout = timeout_s if p == "fifo" else 0.0
        if p == "slo-drop" and not have_deadline:
            p = "fifo"

        if p == "edf":
            while ai < k and ready_l[ai] <= start:
                if not served[ai]:
                    heapq.heappush(edf_heap, (deadline_l[ai], ai))
                ai += 1
            take = edf_select(edf_heap, ready_l, start, eff_batch, served)
            # start >= r0 and queries remain, so a batch always forms
        elif p == "slo-drop":
            floor = start + solo_lat + shed.margin(start)
            take, shed_idx, ptr = slo_drop_select(
                ready_l, deadline_l, served, ptr, k, start, floor, eff_batch)
            for i in shed_idx:
                dropped[i] = True
                done[i] = np.inf
                served[i] = True
            remaining -= len(shed_idx)
            if not take:             # everything scanned was shed
                heapq.heappush(pool.free, f)
                continue
        else:                        # fifo (+ optional formation timeout)
            take, hi = fifo_select(ready_l, served, ptr, k, start, eff_batch)
            if p_timeout > 0.0 and take:
                # candidate window: the first eff_batch pending entries in
                # arrival order, ready or not — the batch holds open until
                # it can fill (the window's last entry arrives) or
                # `timeout_s` elapses from the head-of-line arrival
                cand: List[int] = []
                j = ptr
                while j < k and len(cand) < eff_batch:
                    if not served[j]:
                        cand.append(j)
                    j += 1
                if len(take) < len(cand):
                    hold_until = r0 + timeout_s
                    if hold_until > start:
                        fill_t = (ready_l[cand[-1]]
                                  if len(cand) == eff_batch else _FAR_FUTURE)
                        start = min(max(start, fill_t), hold_until)
                        take = [i for i in cand if ready_l[i] <= start]
                        hi = take[-1] + 1
            ptr = hi

        b = len(take)
        end = start + lut_l[b]
        for i in take:
            done[i] = end
            served[i] = True
        remaining -= b
        batches.append(b)
        heapq.heappush(pool.free, end)

    return done, np.asarray(batches, dtype=np.int64), dropped


# -- live (streaming) centralized queue -------------------------------------


class LiveQueue:
    """Policy-aware centralized queue over streaming work items — the
    wall-clock executor's per-stage queue (:mod:`repro.serving.executor`).

    Items are pushed with their queue-ready instant (arrival + upstream
    hop delay) and optional deadline; :meth:`form_batch` implements the
    same batch-formation semantics the simulator's policies run — edf
    literally calls :func:`edf_select`, while the fifo/slo-drop branch
    is an arrival-heap walk mirroring :func:`fifo_select` /
    :func:`slo_drop_select` (those operate on index cursors, the live
    queue on a streaming heap; any semantics change there must land in
    both places — see the cross-references on the primitives). Policy,
    shed margin, and deadlines are all reprogrammable at runtime (the
    control plane's knobs).

    Not thread-safe by itself — the executor serializes access under the
    stage lock.
    """

    def __init__(self, policy: str = "fifo", timeout_s: float = 0.0):
        self.policy = check_policy_name(policy)
        self.shed_margin = 0.0
        # batch-formation hold (StageConfig.timeout_s): a partial fifo
        # batch is held open until `timeout_s` past the head-of-line
        # ready instant — the simulator's beyond-paper timeout semantics
        # (repro.sim.queueing.fifo). edf/slo-drop ignore it, as in the
        # simulator.
        self.timeout_s = float(timeout_s)
        self._seq = itertools.count()
        # arrival order: (ready, seq) heap; deadline order: (deadline, seq)
        self._arr: List[Tuple[float, int]] = []
        self._edf: List[Tuple[float, int]] = []
        self._items: Dict[int, object] = {}
        self._ready: Dict[int, float] = {}
        self._deadline: Dict[int, float] = {}
        # liveness view for the shared selection primitives: an entry is
        # consumed iff its seq left _items — no per-seq tombstone dict,
        # so bookkeeping cannot grow past the live set
        self._gone = _ConsumedView(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def clear(self) -> None:
        """Discard every queued item (a serving-run reset)."""
        self._items.clear()
        self._ready.clear()
        self._deadline.clear()
        self._arr.clear()
        self._edf.clear()

    def set_policy(self, name: str) -> None:
        self.policy = check_policy_name(name)

    def drain_all(self) -> List[object]:
        """Pop every queued item in arrival (push) order and empty the
        queue — the starved-stage release path: when no replica is left
        to serve a stage, the executor drains it and resolves the items
        upstream. Hedged duplicates of the same item come out once per
        queued occurrence; the caller's resolve-once dedup absorbs them."""
        out = [self._items[seq] for seq in sorted(self._items)]
        self.clear()
        return out

    def push(self, item, ready: float,
             deadline: float = float("inf")) -> None:
        seq = next(self._seq)
        self._items[seq] = item
        self._ready[seq] = ready
        self._deadline[seq] = deadline
        heapq.heappush(self._arr, (ready, seq))
        heapq.heappush(self._edf, (deadline, seq))

    def _prune(self, heap: List[Tuple[float, int]]) -> None:
        """Drop consumed entries off a heap's head — keeps BOTH heaps
        from accumulating tombstones of entries the other order already
        served (a fifo-only queue would otherwise never drain _edf)."""
        items = self._items
        while heap and heap[0][1] not in items:
            heapq.heappop(heap)

    def next_ready_after(self, now: float,
                         max_batch: Optional[int] = None) -> Optional[float]:
        """Earliest instant a dispatch could produce work after `now`
        (None if empty) — what a worker's timed wait should sleep until.

        With a fifo formation hold active (``timeout_s > 0``) and
        ``max_batch`` supplied, a head-of-line item inside its hold
        window reports the hold's *release* instant unless enough items
        are already ready to fill the batch — so workers sleep through
        the hold instead of busy-polling empty ``form_batch`` calls."""
        self._prune(self._arr)
        if not self._arr:
            return None
        head = self._arr[0][0]
        if (self.policy == "fifo" and self.timeout_s > 0.0
                and max_batch is not None and head <= now):
            release = head + self.timeout_s
            if release > now:
                n_ready = sum(1 for r in self._ready.values() if r <= now)
                if n_ready < max_batch:
                    return release
        return max(head, now)

    def _pop_seq(self, seq: int):
        item = self._items.pop(seq)
        self._ready.pop(seq)
        self._deadline.pop(seq)
        return item

    def form_batch(self, now: float, max_batch: int,
                   solo_latency_s: float = 0.0
                   ) -> Tuple[List[object], List[object]]:
        """(batch, shed) for a dispatch at `now` under the current policy.

        Consumes the returned items; an empty batch means nothing is
        serviceable at `now` (the caller waits for
        :meth:`next_ready_after`)."""
        take_seqs: List[int] = []
        shed_seqs: List[int] = []
        if self.policy == "edf":
            # the simulator's edf_select over the (deadline, seq) heap;
            # consumed entries are discarded lazily, not-ready ones
            # deferred
            take_seqs = edf_select(self._edf, self._ready, now, max_batch,
                                   served=self._gone)
        else:
            shed_floor = (now + solo_latency_s + self.shed_margin
                          if self.policy == "slo-drop" else None)
            popped: List[Tuple[float, int]] = []
            while self._arr and len(take_seqs) < max_batch:
                ready, seq = self._arr[0]
                if seq not in self._items:
                    heapq.heappop(self._arr)
                    continue
                if ready > now:
                    break
                heapq.heappop(self._arr)
                popped.append((ready, seq))
                if (shed_floor is not None
                        and self._deadline[seq] < shed_floor):
                    shed_seqs.append(seq)
                else:
                    take_seqs.append(seq)
            # fifo formation hold (StageConfig.timeout_s): a partial
            # batch stays queued until max_batch items are ready or the
            # hold expires `timeout_s` past the head-of-line ready
            # instant — mirrors the simulator's timeout batching
            # (repro.sim.queueing.fifo); slo-drop ignores the hold there
            # and here alike
            if (self.policy == "fifo" and self.timeout_s > 0.0
                    and take_seqs and len(take_seqs) < max_batch
                    and now < popped[0][0] + self.timeout_s):
                for entry in popped:
                    heapq.heappush(self._arr, entry)
                return [], []
        out = ([self._pop_seq(s) for s in take_seqs],
               [self._pop_seq(s) for s in shed_seqs])
        self._prune(self._arr)
        self._prune(self._edf)
        return out


class _ConsumedView:
    """`served`-mapping adapter for the selection primitives: truthy for
    any seq no longer in the live item table."""

    __slots__ = ("_items",)

    def __init__(self, items: Dict[int, object]):
        self._items = items

    def __getitem__(self, seq: int) -> bool:
        return seq not in self._items
