"""High-frequency Tuner (§5).

Detects arrival-process deviations from the planned workload via traffic
envelopes and re-scales per-model replica counts within seconds.

Scale-up: if any current-envelope rate exceeds the planned envelope, take
the max violating rate r_max and set, per model m,

    k_m = ceil( r_max * s_m / (mu_m * rho_m) )

where s_m is the scale factor, mu_m the single-replica throughput in the
model's current (hw, batch) configuration, and rho_m the max-provisioning
ratio computed at plan time — the "slack" the Planner decided model m
needs to absorb bursts:

    rho_m = (lambda_plan * s_m) / (k_m_plan * mu_m)

(at r_max = lambda_plan this recovers exactly the planned replica count).

Scale-down: conservative — 15 s hysteresis after any configuration change
(3x the 5 s replica activation time), lambda_new = max rate over the last
30 s in 5 s windows, and the pipeline-min rho_p = min_m rho_m.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.envelope import TrafficEnvelope
from repro.core.pipeline import Pipeline, PipelineConfig
from repro.core.profiler import ProfileStore

REPLICA_ACTIVATION_S = 5.0
DOWNSCALE_HYSTERESIS_S = 15.0   # 3x activation time (§5)
DOWNSCALE_OBS_WINDOW_S = 30.0
DOWNSCALE_SUBWINDOW_S = 5.0


@dataclasses.dataclass
class TunerPlanInfo:
    """Everything the Planner hands the Tuner at deployment time (§5)."""

    planned_envelope: TrafficEnvelope
    mu: Dict[str, float]            # single-replica throughput per stage
    rho: Dict[str, float]           # max-provisioning ratio per stage
    scale_factors: Dict[str, float]
    planned_replicas: Dict[str, int]
    service_time_s: float

    @staticmethod
    def from_plan(pipeline: Pipeline, config: PipelineConfig,
                  profiles: ProfileStore, sample_arrivals: np.ndarray,
                  service_time_s: float) -> "TunerPlanInfo":
        arr = np.asarray(sample_arrivals, dtype=np.float64)
        # lam = n / (max - min) diverges when the span is ~0 (0, 1, or
        # simultaneous arrivals); a degenerate sample carries no planned
        # rate, so fall back to rho = 1 (no burst slack: scale exactly to
        # demand) — a tiny rho floor here would make _replicas_for_rate,
        # which divides by rho, explode to millions of replicas on the
        # first real traffic
        duration = float(arr.max() - arr.min()) if arr.size > 1 else 0.0
        lam = arr.size / duration if duration > 1e-9 else 0.0
        s = pipeline.scale_factors()
        mu, rho, k = {}, {}, {}
        for stage, cfg in config.stage_configs.items():
            prof = profiles.get(pipeline.stages[stage].model_id)
            mu_m = prof.throughput(cfg.hardware, cfg.batch_size)
            mu[stage] = mu_m
            k[stage] = cfg.replicas
            lam_m = lam * s[stage]
            rho[stage] = max(lam_m / (cfg.replicas * mu_m), 1e-6) \
                if lam > 0.0 else 1.0
        env = TrafficEnvelope.from_trace(arr, service_time_s)
        return TunerPlanInfo(env, mu, rho, s, k, service_time_s)


class Tuner:
    """Stateful controller; call ``step`` on a fixed cadence (e.g. 1 s)."""

    def __init__(self, plan: TunerPlanInfo,
                 envelope_horizon_s: float = 60.0,
                 min_replicas: int = 1):
        self.plan = plan
        self.horizon = envelope_horizon_s
        self.min_replicas = min_replicas
        self.current: Dict[str, int] = dict(plan.planned_replicas)
        # deployment counts as a configuration change: hysteresis applies
        # from t=0, so the tuner cannot scale DOWN off a sliver of
        # history (a 1 s trace read as a 30 s window halves the fleet)
        self.last_change_t: float = 0.0
        self.rho_p: float = min(plan.rho.values())
        self.events: List[Tuple[float, str, str, int]] = []  # (t, kind, stage, delta)

    # -- required replicas for a given per-pipeline ingress rate ----------
    def _replicas_for_rate(self, rate: float, stage: str, rho: float) -> int:
        s_m = self.plan.scale_factors[stage]
        mu_m = self.plan.mu[stage]
        return max(self.min_replicas,
                   math.ceil(rate * s_m / (mu_m * rho)))

    def step(self, now: float, arrivals_so_far: np.ndarray
             ) -> Dict[str, int]:
        """Observe ingress arrivals up to `now`; return target replica counts.

        The caller (live cluster / real frontend) applies the deltas, adding
        REPLICA_ACTIVATION_S before a new replica serves traffic.
        """
        arr = arrivals_so_far
        recent = arr[arr > now - self.horizon]
        target = dict(self.current)

        # ---- scale up (immediate) ----------------------------------------
        cur_env = TrafficEnvelope.from_trace(recent, self.plan.service_time_s)
        exceeded, r_max = self.plan.planned_envelope.exceeded_by(cur_env)
        if exceeded:
            for stage in target:
                k_needed = self._replicas_for_rate(
                    r_max, stage, self.plan.rho[stage])
                if k_needed > target[stage]:
                    target[stage] = k_needed

        up = {s: k for s, k in target.items() if k > self.current[s]}
        if up:
            for stage, k in up.items():
                self.events.append((now, "up", stage, k - self.current[stage]))
                self.current[stage] = k
            self.last_change_t = now
            return dict(self.current)

        # ---- scale down (hysteresis-guarded) ------------------------------
        if now - self.last_change_t < DOWNSCALE_HYSTERESIS_S:
            return dict(self.current)
        if now < DOWNSCALE_OBS_WINDOW_S:
            # no full observation window yet — the windowed-max rate
            # would undercount and trigger a spurious scale-down
            return dict(self.current)
        obs = arr[arr > now - DOWNSCALE_OBS_WINDOW_S]
        if obs.size == 0:
            lam_new = 0.0
        else:
            edges = np.arange(now - DOWNSCALE_OBS_WINDOW_S, now
                              + DOWNSCALE_SUBWINDOW_S, DOWNSCALE_SUBWINDOW_S)
            counts, _ = np.histogram(obs, bins=edges)
            lam_new = float(counts.max()) / DOWNSCALE_SUBWINDOW_S
        changed = False
        for stage in target:
            k_needed = self._replicas_for_rate(lam_new, stage, self.rho_p)
            if k_needed < self.current[stage]:
                self.events.append(
                    (now, "down", stage, k_needed - self.current[stage]))
                self.current[stage] = k_needed
                changed = True
        if changed:
            self.last_change_t = now
        return dict(self.current)


def run_tuner_offline(
    tuner: Tuner,
    arrivals: np.ndarray,
    t_end: Optional[float] = None,
    interval_s: float = 1.0,
    activation_delay_s: float = REPLICA_ACTIVATION_S,
) -> Dict[str, List[Tuple[float, int]]]:
    """Drive the tuner over a full trace; emit per-stage replica events.

    The Tuner's decisions depend only on the ingress arrival process (§5),
    so the full scaling schedule can be computed ahead of the pipeline
    simulation and handed to the Estimator engine as replica_schedules.
    Scale-ups take effect after `activation_delay_s`; scale-downs are
    immediate (replicas drain and retire).
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    t_end = t_end if t_end is not None else (
        float(arrivals.max()) if arrivals.size else 0.0)
    schedules: Dict[str, List[Tuple[float, int]]] = {
        s: [] for s in tuner.current
    }
    before = dict(tuner.current)
    t = interval_s
    while t <= t_end + 1e-9:
        seen = arrivals[arrivals <= t]
        after = tuner.step(t, seen)
        for stage, k in after.items():
            delta = k - before[stage]
            if delta > 0:
                schedules[stage].append((t + activation_delay_s, delta))
            elif delta < 0:
                schedules[stage].append((t, delta))
        before = after
        t += interval_s
    # scale-ups land at t + activation_delay_s while scale-downs land at
    # t, so a down issued within activation_delay_s of an up would appear
    # *before* it in emission order — the engine's _ReplicaPool.apply_events
    # assumes a time-sorted (t, +/-1) stream, so merge-sort each schedule
    for evs in schedules.values():
        evs.sort(key=lambda e: e[0])
    return schedules
