"""High-frequency Tuner (§5).

Detects arrival-process deviations from the planned workload via traffic
envelopes and re-scales per-model replica counts within seconds.

Scale-up: if any current-envelope rate exceeds the planned envelope, take
the max violating rate r_max and set, per model m,

    k_m = ceil( r_max * s_m / (mu_m * rho_m) )

where s_m is the scale factor, mu_m the single-replica throughput in the
model's current (hw, batch) configuration, and rho_m the max-provisioning
ratio computed at plan time — the "slack" the Planner decided model m
needs to absorb bursts:

    rho_m = (lambda_plan * s_m) / (k_m_plan * mu_m)

(at r_max = lambda_plan this recovers exactly the planned replica count).

Scale-down: conservative — 15 s hysteresis after any configuration change
(3x the 5 s replica activation time), lambda_new = max rate over the last
30 s in 5 s windows, and the pipeline-min rho_p = min_m rho_m.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.control import ControlEvent
from repro.core.envelope import TrafficEnvelope
from repro.core.pipeline import Pipeline, PipelineConfig
from repro.core.profiler import ProfileStore
from repro.sim.result import EpochTelemetry

REPLICA_ACTIVATION_S = 5.0
DOWNSCALE_HYSTERESIS_S = 15.0   # 3x activation time (§5)
DOWNSCALE_OBS_WINDOW_S = 30.0
DOWNSCALE_SUBWINDOW_S = 5.0


@dataclasses.dataclass
class TunerPlanInfo:
    """Everything the Planner hands the Tuner at deployment time (§5)."""

    planned_envelope: TrafficEnvelope
    mu: Dict[str, float]            # single-replica throughput per stage
    rho: Dict[str, float]           # max-provisioning ratio per stage
    scale_factors: Dict[str, float]
    planned_replicas: Dict[str, int]
    service_time_s: float

    @staticmethod
    def from_plan(pipeline: Pipeline, config: PipelineConfig,
                  profiles: ProfileStore, sample_arrivals: np.ndarray,
                  service_time_s: float) -> "TunerPlanInfo":
        arr = np.asarray(sample_arrivals, dtype=np.float64)
        # lam = n / (max - min) diverges when the span is ~0 (0, 1, or
        # simultaneous arrivals); a degenerate sample carries no planned
        # rate, so fall back to rho = 1 (no burst slack: scale exactly to
        # demand) — a tiny rho floor here would make _replicas_for_rate,
        # which divides by rho, explode to millions of replicas on the
        # first real traffic
        duration = float(arr.max() - arr.min()) if arr.size > 1 else 0.0
        lam = arr.size / duration if duration > 1e-9 else 0.0
        s = pipeline.scale_factors()
        mu, rho, k = {}, {}, {}
        for stage, cfg in config.stage_configs.items():
            prof = profiles.get(pipeline.stages[stage].model_id)
            mu_m = prof.throughput(cfg.hardware, cfg.batch_size)
            mu[stage] = mu_m
            k[stage] = cfg.replicas
            lam_m = lam * s[stage]
            rho[stage] = max(lam_m / (cfg.replicas * mu_m), 1e-6) \
                if lam > 0.0 else 1.0
        env = TrafficEnvelope.from_trace(arr, service_time_s)
        return TunerPlanInfo(env, mu, rho, s, k, service_time_s)


class Tuner:
    """Stateful controller; call ``step`` on a fixed cadence (e.g. 1 s)."""

    def __init__(self, plan: TunerPlanInfo,
                 envelope_horizon_s: float = 60.0,
                 min_replicas: int = 1):
        self.plan = plan
        self.horizon = envelope_horizon_s
        self.min_replicas = min_replicas
        self.current: Dict[str, int] = dict(plan.planned_replicas)
        # deployment counts as a configuration change: hysteresis applies
        # from t=0, so the tuner cannot scale DOWN off a sliver of
        # history (a 1 s trace read as a 30 s window halves the fleet)
        self.last_change_t: float = 0.0
        self.rho_p: float = min(plan.rho.values())
        self.events: List[Tuple[float, str, str, int]] = []  # (t, kind, stage, delta)

    # -- required replicas for a given per-pipeline ingress rate ----------
    def _replicas_for_rate(self, rate: float, stage: str, rho: float) -> int:
        s_m = self.plan.scale_factors[stage]
        mu_m = self.plan.mu[stage]
        # the 1e-9 slack keeps the §5 identity k(lambda_plan) == k_plan
        # exact: rho is stored as a float quotient, so the re-division can
        # land one ulp above the integer and ceil would over-scale by one
        # (pinned by the property suite in tests/test_tuner_loop.py)
        return max(self.min_replicas,
                   math.ceil(rate * s_m / (mu_m * rho) - 1e-9))

    def scale_up_targets(self, r_max: float) -> Dict[str, int]:
        """Per-stage replica targets for a violating envelope rate
        ``r_max`` (§5 scale-up rule): never below the current counts."""
        return {
            stage: max(self.current[stage],
                       self._replicas_for_rate(r_max, stage,
                                               self.plan.rho[stage]))
            for stage in self.current
        }

    def detect_violation(self, now: float, arrivals_so_far: np.ndarray
                         ) -> Tuple[bool, float]:
        """Envelope detection over the trailing horizon: (exceeded, r_max)."""
        recent = arrivals_so_far[arrivals_so_far > now - self.horizon]
        cur_env = TrafficEnvelope.from_trace(recent, self.plan.service_time_s)
        return self.plan.planned_envelope.exceeded_by(cur_env)

    def downscale_rate(self, now: float, arrivals_so_far: np.ndarray,
                       obs_window_s: float = DOWNSCALE_OBS_WINDOW_S,
                       subwindow_s: float = DOWNSCALE_SUBWINDOW_S) -> float:
        """lambda_new for the conservative scale-down rule: the max rate
        over the trailing ``obs_window_s`` in ``subwindow_s`` windows."""
        obs = arrivals_so_far[arrivals_so_far > now - obs_window_s]
        if obs.size == 0:
            return 0.0
        edges = np.arange(now - obs_window_s, now + subwindow_s, subwindow_s)
        counts, _ = np.histogram(obs, bins=edges)
        return float(counts.max()) / subwindow_s

    def step(self, now: float, arrivals_so_far: np.ndarray
             ) -> Dict[str, int]:
        """Observe ingress arrivals up to `now`; return target replica counts.

        The caller (live cluster / real frontend) applies the deltas, adding
        REPLICA_ACTIVATION_S before a new replica serves traffic.
        """
        arr = arrivals_so_far
        target = dict(self.current)

        # ---- scale up (immediate) ----------------------------------------
        exceeded, r_max = self.detect_violation(now, arr)
        if exceeded:
            for stage, k_needed in self.scale_up_targets(r_max).items():
                if k_needed > target[stage]:
                    target[stage] = k_needed

        up = {s: k for s, k in target.items() if k > self.current[s]}
        if up:
            for stage, k in up.items():
                self.events.append((now, "up", stage, k - self.current[stage]))
                self.current[stage] = k
            self.last_change_t = now
            return dict(self.current)

        # ---- scale down (hysteresis-guarded) ------------------------------
        if now - self.last_change_t < DOWNSCALE_HYSTERESIS_S:
            return dict(self.current)
        if now < DOWNSCALE_OBS_WINDOW_S:
            # no full observation window yet — the windowed-max rate
            # would undercount and trigger a spurious scale-down
            return dict(self.current)
        lam_new = self.downscale_rate(now, arr)
        changed = False
        for stage in target:
            k_needed = self._replicas_for_rate(lam_new, stage, self.rho_p)
            if k_needed < self.current[stage]:
                self.events.append(
                    (now, "down", stage, k_needed - self.current[stage]))
                self.current[stage] = k_needed
                changed = True
        if changed:
            self.last_change_t = now
        return dict(self.current)


def run_tuner_offline(
    tuner: Tuner,
    arrivals: np.ndarray,
    t_end: Optional[float] = None,
    interval_s: float = 1.0,
    activation_delay_s: float = REPLICA_ACTIVATION_S,
) -> Dict[str, List[Tuple[float, int]]]:
    """Drive the tuner over a full trace; emit per-stage replica events.

    The Tuner's decisions depend only on the ingress arrival process (§5),
    so the full scaling schedule can be computed ahead of the pipeline
    simulation and handed to the Estimator engine as replica_schedules.
    Scale-ups take effect after `activation_delay_s`; scale-downs are
    immediate (replicas drain and retire).
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    t_end = t_end if t_end is not None else (
        float(arrivals.max()) if arrivals.size else 0.0)
    schedules: Dict[str, List[Tuple[float, int]]] = {
        s: [] for s in tuner.current
    }
    before = dict(tuner.current)
    t = interval_s
    while t <= t_end + 1e-9:
        seen = arrivals[arrivals <= t]
        after = tuner.step(t, seen)
        for stage, k in after.items():
            delta = k - before[stage]
            if delta > 0:
                schedules[stage].append((t + activation_delay_s, delta))
            elif delta < 0:
                schedules[stage].append((t, delta))
        before = after
        t += interval_s
    # scale-ups land at t + activation_delay_s while scale-downs land at
    # t, so a down issued within activation_delay_s of an up would appear
    # *before* it in emission order — the engine's _ReplicaPool.apply_events
    # assumes a time-sorted (t, +/-1) stream, so merge-sort each schedule
    for evs in schedules.values():
        evs.sort(key=lambda e: e[0])
    return schedules


# -- closed-loop controllers (repro.sim.control epoch stepping) ------------


class OpenLoopTunerController:
    """Adapter: drives the ingress-only :class:`Tuner` through the
    closed-loop runner (:class:`repro.sim.control.ControlLoopSession`).

    Feedback telemetry is ignored by construction — each epoch boundary
    calls ``tuner.step(t, arrivals <= t)`` exactly as
    :func:`run_tuner_offline` does, so the accumulated schedule is
    guaranteed identical to the precomputed one (guarded in
    ``tests/test_tuner_loop.py``). This is the bridge that lets the old
    open-loop path and new closed-loop controllers run under one driver.
    """

    def __init__(self, tuner: Tuner,
                 activation_delay_s: float = REPLICA_ACTIVATION_S):
        self.tuner = tuner
        self.activation_delay_s = activation_delay_s

    @property
    def current(self) -> Dict[str, int]:
        return dict(self.tuner.current)

    def step(self, tele: EpochTelemetry) -> List[ControlEvent]:
        now = tele.t_end
        before = dict(self.tuner.current)
        after = self.tuner.step(now, tele.ingress_prefix)
        events: List[ControlEvent] = []
        for stage, k in after.items():
            delta = k - before[stage]
            if delta > 0:
                events.append(ControlEvent(
                    now, now + self.activation_delay_s, stage, "up", delta))
            elif delta < 0:
                events.append(ControlEvent(now, now, stage, "down", delta))
        return events


class ClosedLoopTuner(Tuner):
    """Telemetry-driven Tuner: §5's envelope rules plus engine feedback.

    ``step(telemetry) -> [ControlEvent]`` consumes one
    :class:`~repro.sim.result.EpochTelemetry` record per control epoch
    and layers four feedback behaviors on the ingress-only base rules.
    The interface is the runtime-agnostic controller contract
    (:mod:`repro.control`): the same instance drives the co-simulation
    loop (:class:`repro.sim.control.ControlLoopSession`) and the real
    thread-pool executor (:class:`repro.serving.loop.LiveControlLoop`)
    unchanged — scaling real threads up *and* down is exercised by
    ``benchmarks/bench_live_loop.py``.

    * **corroborated scale-up** — the ingress-only tuner trusts the
      envelope unconditionally; because the envelope carries a 60 s
      memory while the scale-down rate window forgets in 30 s, every
      absorbed burst leaves it in a down/up oscillation (downscale,
      re-detect the stale violation, re-upscale — observed on every
      spike trace). With engine feedback a violation is only acted on
      when *something corroborates it*: live backlog, observed misses,
      or a trailing short-window ingress rate above the planned rate.
      A true onset always corroborates (the rate or the queue is up);
      a stale echo of a drained burst never does.
    * **backlog-drain boost** — queue depths are observable, so when a
      stage's backlog exceeds ``queue_grace_s`` seconds of its current
      fleet's service capacity (the regime after a spike outruns the
      activation delay), request enough extra replicas to drain it
      within ``drain_target_s`` while absorbing the current offered
      rate. The envelope rule provisions for the violating *rate* only
      and is blind to the queue already accumulated during the
      activation gap; under low-burstiness overload (r_max close to
      the sustained rate) that leaves a many-second drain during which
      every queued query misses. The boost sizes itself against the
      queue *projected at activation time* (current backlog plus the
      activation delay's worth of inflow the still-active fleet cannot
      absorb) and then holds off one activation delay before boosting
      again, so it neither fights the gap with stale numbers nor
      ladders requests against replicas that are still spinning up.
    * **telemetry-gated early scale-down** — the open-loop rule needs a
      30 s max-rate window because ingress alone cannot prove the system
      has digested a burst; observed (near-)empty queues can, so with
      backlog below ``down_backlog_grace_s`` seconds of service the
      trailing-rate window shrinks to ``down_obs_window_s``. The
      ``DOWNSCALE_HYSTERESIS_S`` guard is inherited untouched (a
      property-tested invariant).
    * **admission control** — for stages running the ``slo-drop``
      policy (``shed_stages``), sustained observed misses raise the
      shed margin to ``shed_margin_s`` (drop queries ``margin`` short
      of viability, keeping queues from poisoning viable work) and
      recovery lowers it back to 0. Shed events land immediately: no
      activation delay applies to turning work away.

    Replica invariants (property-tested): scale-up targets are monotone
    in the violating rate, the planned rate recovers the planned counts,
    no scale-down fires within ``DOWNSCALE_HYSTERESIS_S`` of any
    replica-configuration change, and counts never fall below
    ``min_replicas`` (>= 1).
    """

    def __init__(self, plan: TunerPlanInfo,
                 envelope_horizon_s: float = 60.0,
                 min_replicas: int = 1,
                 activation_delay_s: float = REPLICA_ACTIVATION_S,
                 drain_target_s: float = 5.0,
                 queue_grace_s: float = 1.0,
                 down_obs_window_s: float = 15.0,
                 down_backlog_grace_s: float = 1.0,
                 max_replicas: Optional[int] = None,
                 shed_stages: Tuple[str, ...] = (),
                 shed_margin_s: float = 0.02,
                 shed_on_miss: float = 0.05,
                 shed_off_miss: float = 0.005,
                 shed_patience: int = 3,
                 up_rate_slack: float = 1.15,
                 up_miss_floor: float = 0.01,
                 failure_recovery: bool = True):
        super().__init__(plan, envelope_horizon_s, min_replicas)
        self.activation_delay_s = activation_delay_s
        self.up_rate_slack = up_rate_slack
        self.up_miss_floor = up_miss_floor
        # sustained planned rate: the widest envelope window's rate
        self.lam_plan = (float(plan.planned_envelope.rates[-1])
                         if plan.planned_envelope.windows.size else 0.0)
        self.drain_target_s = drain_target_s
        self.queue_grace_s = queue_grace_s
        self.down_obs_window_s = down_obs_window_s
        self.down_backlog_grace_s = down_backlog_grace_s
        self.max_replicas = max_replicas
        self.shed_stages = tuple(shed_stages)
        self.shed_margin_s = shed_margin_s
        self.shed_on_miss = shed_on_miss
        self.shed_off_miss = shed_off_miss
        self.shed_patience = max(int(shed_patience), 1)
        self.shed_active = False
        self.last_boost_t = 0.0  # deployment: boosts wait one activation
        self._shed_hot = 0
        self._shed_cool = 0
        # failure-aware re-provisioning: observed capacity loss (the
        # telemetry `alive` field falling below the target) emits
        # replacement ups through the same ControlEvent path
        self.failure_recovery = failure_recovery
        # in-flight scale-ups (t_effective, n) per stage — replicas the
        # fold has already promised but telemetry cannot see yet; the
        # loss computation must not mistake them for crashes
        self._pending_ups: Dict[str, List[Tuple[float, int]]] = {}

    # -- feedback signals --------------------------------------------------
    def _backlog_seconds(self, tele: EpochTelemetry) -> float:
        """Total queued work, in seconds of current-fleet service."""
        total = 0.0
        for stage, st in tele.stages.items():
            mu = self.plan.mu[stage]
            k = max(self.current[stage], 1)
            total += st.queue_depth / (mu * k)
        return total

    def step(self, tele: EpochTelemetry) -> List[ControlEvent]:  # type: ignore[override]
        now = tele.t_end
        epoch_len = max(tele.t_end - tele.t_start, 1e-9)
        arr = tele.ingress_prefix
        events: List[ControlEvent] = []
        target = dict(self.current)

        # ---- envelope scale-up (§5 rule, telemetry-corroborated) --------
        exceeded, r_max = self.detect_violation(now, arr)
        if exceeded:
            # 2 s subwindows: wide enough that same-law sampling noise
            # stays inside the slack, narrow enough that a genuine step
            # or burst trips it within one control epoch
            r_recent = self.downscale_rate(now, arr, obs_window_s=6.0,
                                           subwindow_s=2.0)
            rate_elevated = r_recent > self.up_rate_slack * self.lam_plan
            corroborated = (
                rate_elevated
                or tele.miss_fraction > self.up_miss_floor
                or self._backlog_seconds(tele) > self.queue_grace_s)
            if corroborated:
                # distress without an elevated ingress rate means the
                # envelope's r_max is (or may be) a stale echo of an
                # already-absorbed burst: respond to the rate actually
                # observed, and let the backlog boost size the drain
                r_eff = r_max if rate_elevated else min(
                    r_max, max(r_recent, self.lam_plan))
                for stage, k in self.scale_up_targets(r_eff).items():
                    if k > target[stage]:
                        target[stage] = k

        # ---- backlog-drain boost (feedback) -----------------------------
        boosted = False
        if now >= self.last_boost_t + self.activation_delay_s:
            rate = tele.ingress / epoch_len
            for stage, st in tele.stages.items():
                mu = self.plan.mu[stage]
                active = max(st.replicas, self.min_replicas)
                if st.queue_depth <= self.queue_grace_s * mu * active:
                    continue
                inflow = rate * self.plan.scale_factors[stage]
                # queue the fleet will face when a boost activates: the
                # current backlog plus whatever the activation delay adds
                # beyond what the currently-active replicas absorb
                q_proj = st.queue_depth + max(
                    inflow - active * mu, 0.0) * self.activation_delay_s
                k_drain = math.ceil(
                    (q_proj / self.drain_target_s + inflow) / mu)
                k_drain = max(self.min_replicas, k_drain)
                if k_drain > target[stage]:
                    target[stage] = k_drain
                    boosted = True

        if self.max_replicas is not None:
            cap = max(self.max_replicas, self.min_replicas)
            for stage in target:
                target[stage] = min(target[stage], cap)

        up = {s: k for s, k in target.items() if k > self.current[s]}
        for stage, k in up.items():
            delta = k - self.current[stage]
            self.current[stage] = k
            self.events.append((now, "up", stage, delta))
            events.append(ControlEvent(
                now, now + self.activation_delay_s, stage, "up", delta))
            self._pending_ups.setdefault(stage, []).append(
                (now + self.activation_delay_s, delta))
        if up:
            self.last_change_t = now
            if boosted:
                self.last_boost_t = now

        # ---- failure recovery (capacity-loss replacement ups) -----------
        if self.failure_recovery:
            for stage, st in tele.stages.items():
                alive = getattr(st, "alive", -1)
                if alive is None or alive < 0:
                    continue        # telemetry without fault tracking
                pend = [(te, n) for (te, n)
                        in self._pending_ups.get(stage, []) if te > now]
                self._pending_ups[stage] = pend
                # current = the count the control schedule will reach
                # once every pending up activates; alive = what the
                # fleet actually carries now. The difference beyond the
                # still-activating ups is crash loss to replace.
                # Replacement ups do NOT bump self.current — the intent
                # is unchanged; the fold's schedule absorbs the deltas.
                lost = (self.current[stage] - alive
                        - sum(n for _, n in pend))
                if lost > 0:
                    t_eff = now + self.activation_delay_s
                    pend.append((t_eff, lost))
                    self.events.append((now, "up", stage, lost))
                    events.append(ControlEvent(now, t_eff, stage, "up",
                                               lost))
                    self.last_change_t = now

        # ---- admission control (slo-drop shed margin) -------------------
        if self.shed_stages:
            overloaded = tele.miss_fraction >= self.shed_on_miss
            recovered = (tele.miss_fraction <= self.shed_off_miss
                         and self._backlog_seconds(tele)
                         <= self.down_backlog_grace_s)
            self._shed_hot = self._shed_hot + 1 if overloaded else 0
            self._shed_cool = self._shed_cool + 1 if recovered else 0
            if not self.shed_active and self._shed_hot >= self.shed_patience:
                self.shed_active = True
                for stage in self.shed_stages:
                    self.events.append((now, "shed", stage,
                                        self.shed_margin_s))
                    events.append(ControlEvent(now, now, stage, "shed",
                                               self.shed_margin_s))
            elif self.shed_active and self._shed_cool >= self.shed_patience:
                self.shed_active = False
                for stage in self.shed_stages:
                    self.events.append((now, "shed", stage, 0.0))
                    events.append(ControlEvent(now, now, stage, "shed", 0.0))

        # ---- scale down (hysteresis-guarded, telemetry-gated) -----------
        if up or now - self.last_change_t < DOWNSCALE_HYSTERESIS_S:
            return events
        if now < self.down_obs_window_s:
            return events
        if self._backlog_seconds(tele) > self.down_backlog_grace_s:
            # ingress may look calm while queues still carry a burst —
            # exactly the blind spot the open-loop 30 s window papers
            # over; with telemetry we simply refuse to scale down
            return events
        lam_new = self.downscale_rate(now, arr, self.down_obs_window_s)
        changed = False
        for stage in self.current:
            # per-stage rho, not the pipeline-min rho_p: the base rule's
            # conservatism guards against imbalance ingress can't see
            # (one stage overprovisioned by design pins every OTHER
            # stage's scale-down target above its current count
            # forever); with verified-empty queues the stage's own
            # planned slack is the right target
            k_needed = self._replicas_for_rate(lam_new, stage,
                                               self.plan.rho[stage])
            if k_needed < self.current[stage]:
                delta = k_needed - self.current[stage]
                self.current[stage] = k_needed
                self.events.append((now, "down", stage, delta))
                events.append(ControlEvent(now, now, stage, "down", delta))
                changed = True
        if changed:
            self.last_change_t = now
        return events
