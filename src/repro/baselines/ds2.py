"""DS2-style autoscaler baseline (§8, Fig. 14).

DS2 [17] estimates each operator's true processing rate and jumps directly
to the optimal parallelism for all operators at once. Two properties drive
its weakness under bursty, latency-SLO-constrained serving:

1. It provisions for the *average* ingest rate — no burst slack, so
   transient spikes overload the pipeline until queues drain.
2. Re-configuration requires the streaming runtime (Flink) to halt
   processing, checkpoint, and restore: every scaling action stalls the
   pipeline, which itself causes SLO misses. We model the stall by
   retiring all replicas of every stage for ``stall_s`` around the action.

Deployed with batch size 1 as in the paper's Fig. 14 setup.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import Pipeline, PipelineConfig, StageConfig
from repro.core.profiler import ProfileStore


class DS2Tuner:
    def __init__(self, pipeline: Pipeline, profiles: ProfileStore,
                 hardware: Dict[str, str],
                 react_interval_s: float = 5.0,
                 obs_window_s: float = 10.0,
                 stall_s: float = 2.0,
                 utilization_target: float = 0.8):
        self.pipeline = pipeline
        self.profiles = profiles
        self.hardware = hardware
        self.react_interval_s = react_interval_s
        self.obs_window_s = obs_window_s
        self.stall_s = stall_s
        self.utilization_target = utilization_target
        self.scale = pipeline.scale_factors()
        # single-query processing rate per operator (batch=1 streaming)
        self.mu = {
            s: profiles.get(pipeline.stages[s].model_id)
                       .throughput(hardware[s], 1)
            for s in pipeline.stages
        }
        self.replicas: Dict[str, int] = {}

    def initial_config(self, arrivals: np.ndarray) -> PipelineConfig:
        """Provision for the sample trace's average rate (no slack)."""
        arr = np.asarray(arrivals, dtype=np.float64)
        duration = float(arr.max() - arr.min()) if arr.size > 1 else 1.0
        lam = arr.size / max(duration, 1e-9)
        cfg = {}
        for s in self.pipeline.stages:
            k = max(1, math.ceil(lam * self.scale[s]
                                 / (self.mu[s] * self.utilization_target)))
            cfg[s] = StageConfig(self.hardware[s], 1, k)
            self.replicas[s] = k
        return PipelineConfig(cfg)

    def _targets(self, rate: float) -> Dict[str, int]:
        return {
            s: max(1, math.ceil(rate * self.scale[s]
                                / (self.mu[s] * self.utilization_target)))
            for s in self.pipeline.stages
        }

    def run_offline(self, arrivals: np.ndarray,
                    t_end: Optional[float] = None
                    ) -> Dict[str, List[Tuple[float, int]]]:
        """Scaling schedule incl. halt/restore stalls at each action."""
        arrivals = np.asarray(arrivals, dtype=np.float64)
        t_end = t_end if t_end is not None else (
            float(arrivals.max()) if arrivals.size else 0.0)
        if not self.replicas:
            self.initial_config(arrivals)
        sched: Dict[str, List[Tuple[float, int]]] = {
            s: [] for s in self.pipeline.stages
        }
        # first decision only after one full observation window
        t = max(self.react_interval_s, self.obs_window_s)
        while t <= t_end + 1e-9:
            obs = arrivals[(arrivals > t - self.obs_window_s) & (arrivals <= t)]
            rate = obs.size / self.obs_window_s
            targets = self._targets(rate)
            under = any(targets[s] > self.replicas[s]
                        for s in self.pipeline.stages)
            # DS2 jumps straight to the computed optimum but (like the
            # real system) does not thrash on noise: reconfigure when any
            # stage is under-provisioned, or when the total target drops
            # far enough to be worth a halt-restore cycle.
            shrink = sum(targets.values()) <= 0.75 * sum(
                self.replicas.values())
            if under or shrink:
                # halt-checkpoint-restore: all stages offline for stall_s
                for s in self.pipeline.stages:
                    k_old, k_new = self.replicas[s], targets[s]
                    sched[s].append((t, -k_old))
                    sched[s].append((t + self.stall_s, k_new))
                self.replicas = dict(targets)
            t += self.react_interval_s
        return sched


def run_ds2(tuner: DS2Tuner, profiles: ProfileStore, arrivals: np.ndarray,
            slo: float):
    """Provision for the trace average, then serve it with DS2 scaling.

    Returns a LiveRunResult (same contract as the InferLine live runs so
    Fig. 14 can compare directly); the serve itself runs on the unified
    simulation engine via LiveClusterSim, so queue/batch/stall dynamics
    are modeled identically for DS2 and InferLine.
    """
    from repro.serving.cluster import LiveClusterSim

    arrivals = np.asarray(arrivals, dtype=np.float64)
    config = tuner.initial_config(arrivals)
    sim = LiveClusterSim(tuner.pipeline, profiles, config, slo)
    return sim.run(arrivals, schedule_fn=tuner.run_offline)
