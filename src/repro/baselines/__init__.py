from repro.baselines.coarse_grained import (  # noqa: F401
    CGPlanner,
    CGTuner,
    cg_plan,
)
from repro.baselines.ds2 import DS2Tuner  # noqa: F401
