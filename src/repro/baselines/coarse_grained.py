"""Coarse-grained baseline (§6): pipeline-as-a-black-box provisioning.

State-of-practice without InferLine: every component is deployed behind a
generic serving system and the *whole pipeline* is tuned as one unit.

Planning: profile the pipeline end-to-end to find the single maximum batch
size whose service time meets the SLO; replicate the entire pipeline as a
unit to reach the required throughput, which is either the trace mean
(CG-Mean) or the trace peak over SLO-sized sliding windows (CG-Peak).

Tuning: the AutoScale [12] reactive mechanism — scale the number of whole
pipeline units against the observed request rate, with slower reaction and
the longer provisioning time of replicating a full pipeline.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.envelope import max_queries_in_window
from repro.core.estimator import Estimator
from repro.core.pipeline import Pipeline, PipelineConfig, StageConfig
from repro.core.profiler import ProfileStore
from repro.sim import SimEngine

# Replicating a whole pipeline takes much longer than one model (§7.1).
UNIT_ACTIVATION_S = 15.0


@dataclasses.dataclass
class CGPlan:
    config: Optional[PipelineConfig]
    unit_batch: int
    unit_throughput: float          # queries/s of one pipeline unit
    unit_replicas: int
    feasible: bool

    @property
    def cost_per_hr(self) -> float:
        return self.config.cost_per_hr() if self.config else math.inf


class CGPlanner:
    def __init__(self, pipeline: Pipeline, profiles: ProfileStore,
                 estimator: Optional[Estimator] = None):
        self.pipeline = pipeline
        self.profiles = profiles
        # same unified simulation core as the InferLine planner: reuse the
        # caller's engine when an estimator is handed in, else make one
        self.engine = (estimator.engine if estimator is not None
                       else SimEngine(pipeline, profiles))

    def _best_hardware(self, stage: str) -> str:
        st = self.pipeline.stages[stage]
        prof = self.profiles.get(st.model_id)
        opts = [h for h in st.hardware_options if prof.supports(h)]
        return min(opts, key=lambda h: prof.batch_latency(h, 1))

    def _unit_config(self, batch: int, replicas: int) -> PipelineConfig:
        return PipelineConfig({
            s: StageConfig(self._best_hardware(s), batch, replicas)
            for s in self.pipeline.stages
        })

    def _service_time(self, batch: int) -> float:
        cfg = self._unit_config(batch, 1)
        return self.engine.service_time(cfg)

    def _unit_throughput(self, batch: int) -> float:
        """Black-box unit throughput: the bottleneck stage's rate."""
        scale = self.pipeline.scale_factors()
        thru = []
        for s in self.pipeline.stages:
            prof = self.profiles.get(self.pipeline.stages[s].model_id)
            mu = prof.throughput(self._best_hardware(s), batch)
            thru.append(mu / max(scale[s], 1e-9))
        return min(thru)

    def plan(self, arrivals: np.ndarray, slo: float,
             strategy: str = "peak") -> CGPlan:
        arrivals = np.asarray(arrivals, dtype=np.float64)
        # 1) max batch whose end-to-end service time fits the SLO
        batch = 0
        for b in (1, 2, 4, 8, 16, 32, 64, 128):
            if self._service_time(b) <= slo:
                batch = b
        if batch == 0:
            return CGPlan(None, 0, 0.0, 0, False)
        mu_unit = self._unit_throughput(batch)
        # 2) required throughput from the sample trace
        duration = float(arrivals.max() - arrivals.min()) if arrivals.size > 1 else 1.0
        if strategy == "mean":
            rate = arrivals.size / max(duration, 1e-9)
        elif strategy == "peak":
            q = max_queries_in_window(arrivals, max(slo, 1e-3))
            rate = q / max(slo, 1e-3)
        else:
            raise ValueError(f"unknown CG strategy {strategy!r}")
        units = max(1, math.ceil(rate / max(mu_unit, 1e-9)))
        return CGPlan(self._unit_config(batch, units), batch, mu_unit,
                      units, True)


def cg_plan(pipeline: Pipeline, profiles: ProfileStore,
            arrivals: np.ndarray, slo: float, strategy: str) -> CGPlan:
    return CGPlanner(pipeline, profiles).plan(arrivals, slo, strategy)


class CGTuner:
    """AutoScale-style reactive whole-pipeline scaling.

    Reacts to the observed mean request rate (30 s window, every 10 s) by
    adding/removing whole pipeline units; scale-down is hysteresis-guarded
    as in [12]. Compare with the InferLine Tuner's multi-timescale traffic
    envelopes and per-stage scaling.
    """

    def __init__(self, plan: CGPlan, react_interval_s: float = 10.0,
                 obs_window_s: float = 30.0,
                 hysteresis_s: float = 60.0,
                 headroom: float = 1.0):
        if not plan.feasible:
            raise ValueError("cannot tune an infeasible CG plan")
        self.plan = plan
        self.react_interval_s = react_interval_s
        self.obs_window_s = obs_window_s
        self.hysteresis_s = hysteresis_s
        self.headroom = headroom
        self.units = plan.unit_replicas
        self.last_change_t = -math.inf

    def step(self, now: float, arrivals_so_far: np.ndarray) -> int:
        obs = arrivals_so_far[arrivals_so_far > now - self.obs_window_s]
        rate = obs.size / self.obs_window_s
        needed = max(1, math.ceil(
            rate * self.headroom / max(self.plan.unit_throughput, 1e-9)))
        if needed > self.units:
            self.units = needed
            self.last_change_t = now
        elif needed < self.units and (
                now - self.last_change_t >= self.hysteresis_s):
            self.units = needed
            self.last_change_t = now
        return self.units


def run_cg_tuner_offline(
    tuner: CGTuner,
    pipeline: Pipeline,
    arrivals: np.ndarray,
    t_end: Optional[float] = None,
    activation_delay_s: float = UNIT_ACTIVATION_S,
) -> Dict[str, List[Tuple[float, int]]]:
    """Whole-unit scaling schedule -> per-stage replica events."""
    arrivals = np.asarray(arrivals, dtype=np.float64)
    t_end = t_end if t_end is not None else (
        float(arrivals.max()) if arrivals.size else 0.0)
    schedules: Dict[str, List[Tuple[float, int]]] = {
        s: [] for s in pipeline.stages
    }
    before = tuner.units
    t = tuner.react_interval_s
    while t <= t_end + 1e-9:
        after = tuner.step(t, arrivals[arrivals <= t])
        delta = after - before
        if delta > 0:
            for s in pipeline.stages:
                schedules[s].append((t + activation_delay_s, delta))
        elif delta < 0:
            for s in pipeline.stages:
                schedules[s].append((t, delta))
        before = after
        t += tuner.react_interval_s
    return schedules
