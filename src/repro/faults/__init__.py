"""Deterministic fault injection for both serving worlds.

`repro.faults` defines seedable fault schedules — replica crashes,
straggler slowdown windows, transient per-batch stage errors — plus the
recovery policy (bounded exponential-backoff retries, optional hedged
duplicates near the deadline) that both backends honor:

* the discrete-event engine folds a :class:`FaultSchedule` into its
  per-stage simulation (``repro.faults.simstage``) and into the cone
  cache keys (``TraceSession._stage_key``), exactly like replica/shed/
  policy schedules;
* the wall-clock executor (:mod:`repro.serving.executor`) kills and
  slows real worker threads on the same schedule and runs the same
  retry/hedge/requeue machinery on live requests.

Everything is deterministic under a fixed seed (per-stage substreams),
so a fault scenario replays bit-identically in simulation and lands on
the same final fleet when the closed-loop tuner re-provisions around it
(``benchmarks/bench_faults.py``).
"""

from repro.faults.schedule import (
    Fault,
    FaultSchedule,
    InjectedFault,
    RecoveryPolicy,
    StageFaults,
    crash,
    straggle,
    transient,
)

__all__ = [
    "Fault",
    "FaultSchedule",
    "InjectedFault",
    "RecoveryPolicy",
    "StageFaults",
    "crash",
    "straggle",
    "transient",
]
