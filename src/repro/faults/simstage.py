"""Scalar fault-aware stage simulation (the engine's fault path).

`simulate_stage_faults` is the discrete-event counterpart of the live
executor's crash/straggle/error handling: one centralized queue, R
servers, policy-core batch formation — extended with the fault vocabulary
of :mod:`repro.faults.schedule`:

* **crash** events kill a replica at ``t`` (idle victims first; a busy
  victim's in-flight batch aborts and its members requeue immediately —
  or fail permanently when recovery is disabled);
* **straggle** windows stretch the service time of every batch
  dispatched inside them;
* **error** windows fail whole batches with probability ``p`` (drawn in
  dispatch order from the stage's seeded substream, so a replay with the
  same seed is bit-identical); failed members requeue after the
  recovery policy's exponential backoff, with an optional hedged
  duplicate when the remaining deadline budget is below
  ``hedge_slack_s`` (resolve-once semantics keep delivery exactly-once).

A request whose retries exhaust resolves like a shed query (``inf``
completion, dropped mask set); requests stranded by a fully-crashed
pool keep the engine's unserved sentinel (``1e18``), matching the
reference kernels' starvation semantics. The no-fault configurations
never route here — the dispatcher (:func:`repro.sim.queueing
.simulate_stage`) only calls this loop for stages with a non-empty
:class:`~repro.faults.schedule.StageFaults` spec, keeping existing
outputs bit-identical.
"""

from __future__ import annotations

import heapq
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import (
    PolicySchedule,
    ShedMarginSchedule,
    effective_max_batch,
)
from repro.faults.schedule import StageFaults

_FAR_FUTURE = 1e18


class _Rep:
    """One replica: next-free instant, liveness, last dispatched batch
    (kept so a crash can abort the in-flight members). `idx` is the
    stable creation-order tie-breaker for dispatch determinism."""

    __slots__ = ("free", "alive", "batch", "idx")

    def __init__(self, idx: int, free: float = 0.0):
        self.idx = idx
        self.free = free
        self.alive = True
        self.batch: Optional[List[int]] = None


def simulate_stage_faults(
    policy: str,
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]],
    timeout_s: float,
    deadline: Optional[np.ndarray],
    shed_events: Optional[Sequence[Tuple[float, float]]],
    policy_events: Optional[Sequence[Tuple[float, str]]],
    spec: StageFaults,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One faulty stage over a sorted `ready` stream.

    Returns (completion times aligned with `ready`, per-batch sizes —
    failed batches included, matching the live executor's batch log —
    and the dropped mask: shed queries plus retry-exhausted failures).
    """
    ready = np.asarray(ready, dtype=np.float64)
    k = int(ready.shape[0])
    done = np.full(k, _FAR_FUTURE, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    batches: List[int] = []
    if k == 0:
        return done, np.asarray(batches, dtype=np.int64), dropped

    lut_l: List[float] = np.asarray(latency_lut, dtype=np.float64).tolist()
    eff_batch = effective_max_batch(latency_lut, max_batch)
    solo_lat = lut_l[1]
    pol = PolicySchedule(policy, policy_events)
    shed = ShedMarginSchedule(shed_events)
    rec = spec.recovery
    rng = spec.rng()
    have_ddl = deadline is not None
    ddl_l: List[float] = (np.asarray(deadline, dtype=np.float64).tolist()
                          if have_ddl else ready.tolist())

    # queue entries: (ready_t, seq, idx, attempt). An entry is stale —
    # a served item, a superseded attempt, or a hedged twin's leftover —
    # iff resolved[idx] or attempt != attempts[idx].
    q: List[Tuple[float, int, int, int]] = []
    seq = 0
    attempts = [1] * k
    resolved = [False] * k
    for i in range(k):
        heapq.heappush(q, (float(ready[i]), seq, i, 1))
        seq += 1
    remaining = k

    reps: List[_Rep] = [_Rep(i) for i in range(max(int(replicas), 0))]
    adds: List[Tuple[float, int]] = []
    removals: List[float] = []
    for t, d in (replica_events or ()):
        if d > 0:
            adds.append((float(t), int(d)))
        else:
            removals.extend([float(t)] * (-int(d)))
    adds.sort()
    removals.sort()
    ai = 0
    crash_ts: List[float] = []
    for t, n in spec.crashes():
        crash_ts.extend([float(t)] * n)
    crash_ts.sort()
    ci = 0

    def _retry(i: int, t_base: float, with_backoff: bool) -> int:
        """Requeue item `i` after a failure observed at `t_base`.
        Returns the change in `remaining` (-1 when retries exhaust)."""
        nonlocal seq
        attempts[i] += 1
        if (not rec.enabled) or attempts[i] > int(rec.max_attempts):
            done[i] = np.inf
            dropped[i] = True
            resolved[i] = True
            return -1
        t_ready = t_base + (rec.backoff(attempts[i] - 1)
                            if with_backoff else 0.0)
        heapq.heappush(q, (t_ready, seq, i, attempts[i]))
        seq += 1
        if (rec.hedge_slack_s > 0.0 and have_ddl
                and ddl_l[i] - t_ready < rec.hedge_slack_s):
            # hedged duplicate: same attempt number, resolve-once dedup
            heapq.heappush(q, (t_ready, seq, i, attempts[i]))
            seq += 1
        return 0

    def _apply_crash(tc: float) -> int:
        """Kill one replica at `tc`; abort+requeue its in-flight batch.
        Returns the change in `remaining`."""
        victim: Optional[_Rep] = None
        for r in reps:                      # idle victims first
            if r.alive and r.free <= tc:
                victim = r
                break
        if victim is None:
            for r in reps:
                if r.alive:
                    victim = r
                    break
        if victim is None:
            return 0
        victim.alive = False
        delta = 0
        if victim.batch is not None and victim.free > tc:
            # in-flight batch dies with the replica: members un-resolve
            # and requeue at the crash instant (no backoff — the work
            # never failed, the server did)
            for i in victim.batch:
                resolved[i] = False
                done[i] = _FAR_FUTURE
                delta += 1
                delta += _retry(i, tc, with_backoff=False)
        victim.batch = None
        return delta

    # iteration guard: each loop either resolves work, processes one
    # event batch, or advances a formation hold — all finite
    max_iters = 64 * (k * int(rec.max_attempts) + len(adds)
                      + len(removals) + len(crash_ts) + 8)
    iters = 0
    start_floor = 0.0

    while remaining > 0:
        iters += 1
        if iters > max_iters:
            raise RuntimeError(
                f"simulate_stage_faults failed to converge on stage "
                f"{spec.stage!r} ({remaining} unresolved after "
                f"{max_iters} iterations)")
        # drop stale heap heads
        while q and (resolved[q[0][2]] or q[0][3] != attempts[q[0][2]]):
            heapq.heappop(q)
        if not q:
            break                           # every live item is resolved
        alive = [r for r in reps if r.alive]
        if not alive:
            if ai < len(adds):
                # fast-forward to the next scale-up
                t_add, n_add = adds[ai]
                ai += 1
                for _ in range(n_add):
                    reps.append(_Rep(len(reps), t_add))
                continue
            break                # starved: leftovers keep _FAR_FUTURE
        f = min(r.free for r in alive)
        head_ready = q[0][0]
        start = max(f, head_ready, start_floor)
        # land control adds / crashes at or before this dispatch instant
        t_ev = math.inf
        if ai < len(adds):
            t_ev = min(t_ev, adds[ai][0])
        if ci < len(crash_ts):
            t_ev = min(t_ev, crash_ts[ci])
        if t_ev <= start:
            while ai < len(adds) and adds[ai][0] <= t_ev:
                t_add, n_add = adds[ai]
                ai += 1
                for _ in range(n_add):
                    reps.append(_Rep(len(reps), t_add))
            while ci < len(crash_ts) and crash_ts[ci] <= t_ev:
                remaining += _apply_crash(crash_ts[ci])
                ci += 1
            continue                        # recompute with the new pool
        # drain-retire: the replica about to dispatch absorbs a pending
        # removal instead (ReplicaPool.retire_if_pending semantics)
        chosen = min(alive, key=lambda r: (r.free, r.idx))
        if removals and removals[0] <= start:
            removals.pop(0)
            chosen.alive = False
            continue

        p = pol.policy_at(start)
        if p == "slo-drop" and not have_ddl:
            p = "fifo"
        # batch formation over the heap (policy-core semantics)
        take: List[int] = []
        popped: List[Tuple[float, int, int, int]] = []
        while q and len(take) < eff_batch:
            entry = q[0]
            t_r, _, i, att = entry
            if resolved[i] or att != attempts[i]:
                heapq.heappop(q)
                continue
            if t_r > start:
                break
            heapq.heappop(q)
            popped.append(entry)
            if i in take:
                continue                    # hedged twin of a taken item
            if p == "slo-drop":
                floor = start + solo_lat + shed.margin(start)
                if ddl_l[i] < floor:
                    done[i] = np.inf
                    dropped[i] = True
                    resolved[i] = True
                    remaining -= 1
                    continue
            take.append(i)
        if p == "edf" and take:
            # deadline order among the ready set; overflow re-queues
            take.sort(key=lambda i: (ddl_l[i], i))
            for i in take[eff_batch:]:
                heapq.heappush(q, (start, seq, i, attempts[i]))
                seq += 1
            take = take[:eff_batch]
        if not take:
            start_floor = 0.0
            continue                        # everything scanned was shed
        if (p == "fifo" and timeout_s > 0.0 and len(take) < eff_batch):
            # fifo formation hold: wait for the batch to fill or for
            # `timeout_s` past the head-of-line ready instant
            head = min(popped[0][0], *(float(ready[i]) for i in take))
            hold_until = head + timeout_s
            if hold_until > start:
                need = eff_batch - len(take)
                future = sorted(
                    t_r for t_r, _, i, att in q
                    if not resolved[i] and att == attempts[i]
                    and i not in take)
                fill_t = future[need - 1] if len(future) >= need else math.inf
                t_hold = min(hold_until, fill_t)
                if t_hold > start:
                    for entry in popped:
                        heapq.heappush(q, entry)
                    start_floor = t_hold
                    continue
        start_floor = 0.0

        b = len(take)
        lat = lut_l[b] * max(1.0, spec.slowdown_at(start))
        end = start + lat
        batches.append(b)
        chosen.free = end
        p_err = spec.error_p(start)
        failed = p_err > 0.0 and bool(rng.random() < p_err)
        if failed:
            # the whole batch fails at completion: the replica burned
            # the service time, the members retry after backoff
            chosen.batch = None
            for i in take:
                remaining += _retry(i, end, with_backoff=True)
        else:
            chosen.batch = list(take)
            for i in take:
                done[i] = end
                resolved[i] = True
            remaining -= b

    return done, np.asarray(batches, dtype=np.int64), dropped
