"""Fault schedules + recovery policy (the shared fault vocabulary).

A :class:`FaultSchedule` is a deterministic, seedable description of
capacity disruptions, normalized to per-stage sorted event streams of
uniform ``(kind, t0, t1, value)`` tuples:

* ``crash``    — ``value`` replicas of the stage die at ``t0`` (point
  event, ``t1 == t0``). A crashed replica's in-flight batch is lost;
  the recovery policy decides whether its requests requeue or fail.
* ``straggle`` — service on the stage runs ``value``x slower for every
  batch dispatched inside ``[t0, t1)``.
* ``error``    — a batch dispatched inside ``[t0, t1)`` fails with
  probability ``value`` (drawn from the stage's seeded substream);
  failed work is retried under the recovery policy.

The per-stage tuple streams are what both backends consume and what the
engine folds into its cone cache keys (see ``_fault_key`` in
:mod:`repro.sim.engine` and the KEY01 analysis rule) — a schedule
component that never reaches the key would let two different fault
scenarios collide on one cached stage outcome.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

FAULT_KINDS: Tuple[str, ...] = ("crash", "straggle", "error")


class InjectedFault(Exception):
    """A deliberately injected transient stage error (distinguishable
    from a real model failure in logs and tests)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One fault event; use :func:`crash` / :func:`straggle` /
    :func:`transient` rather than constructing directly."""

    kind: str
    stage: str
    t0: float
    t1: float
    value: float

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; have {FAULT_KINDS}")
        if self.t0 < 0.0 or self.t1 < self.t0:
            raise ValueError(
                f"fault window [{self.t0}, {self.t1}] is not a valid "
                f"non-negative interval")
        if self.kind == "crash":
            if self.t1 != self.t0:
                raise ValueError("crash is a point event (t1 must equal t0)")
            if int(self.value) < 1:
                raise ValueError("crash must kill >= 1 replica")
        elif self.kind == "straggle":
            if self.value < 1.0:
                raise ValueError(
                    f"straggle factor must be >= 1 (got {self.value})")
        elif not (0.0 <= self.value <= 1.0):
            raise ValueError(
                f"error probability must be in [0, 1] (got {self.value})")


def crash(stage: str, t: float, n: int = 1) -> Fault:
    """`n` replicas of `stage` die at time `t`."""
    return Fault("crash", stage, float(t), float(t), float(int(n)))


def straggle(stage: str, t0: float, t1: float, factor: float) -> Fault:
    """Service on `stage` runs `factor`x slower over ``[t0, t1)``."""
    return Fault("straggle", stage, float(t0), float(t1), float(factor))


def transient(stage: str, t0: float, t1: float, p: float) -> Fault:
    """Batches on `stage` dispatched in ``[t0, t1)`` fail w.p. `p`."""
    return Fault("error", stage, float(t0), float(t1), float(p))


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """How failed deliveries are retried (both backends honor this).

    A request's first delivery attempt is attempt 1; a failure triggers
    retry attempts up to ``max_attempts`` total, the i-th retry delayed
    by ``backoff(i) = backoff_s * backoff_mult**(i-1)`` (monotone
    non-decreasing — property-tested). With ``hedge_slack_s > 0`` a
    retry whose remaining deadline budget is below the slack enqueues a
    duplicate copy; delivery stays exactly-once via resolve-once dedup
    on request identity. ``enabled=False`` turns every failure into a
    permanent drop (the recovery-off baseline in ``bench_faults``)."""

    enabled: bool = True
    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    hedge_slack_s: float = 0.0

    def __post_init__(self):
        if int(self.max_attempts) < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_s < 0.0:
            raise ValueError("backoff_s must be non-negative")
        if self.backoff_mult < 1.0:
            raise ValueError("backoff_mult must be >= 1 (monotone backoff)")
        if self.hedge_slack_s < 0.0:
            raise ValueError("hedge_slack_s must be non-negative")

    def backoff(self, retry_index: int) -> float:
        """Delay before the `retry_index`-th retry (1-based)."""
        if retry_index < 1:
            raise ValueError("retry_index is 1-based")
        return self.backoff_s * self.backoff_mult ** (retry_index - 1)

    def key(self) -> Tuple:
        return (bool(self.enabled), int(self.max_attempts),
                float(self.backoff_s), float(self.backoff_mult),
                float(self.hedge_slack_s))


@dataclasses.dataclass(frozen=True)
class StageFaults:
    """One stage's view of a schedule: its sorted ``(kind, t0, t1,
    value)`` events plus the shared seed and recovery policy."""

    stage: str
    events: Tuple[Tuple[str, float, float, float], ...]
    seed: int
    recovery: RecoveryPolicy

    def crashes(self) -> List[Tuple[float, int]]:
        """Sorted ``(t, n_replicas)`` crash points."""
        return [(t0, int(v)) for kind, t0, t1, v in self.events
                if kind == "crash"]

    def slowdown_at(self, t: float) -> float:
        """Service-time multiplier for a batch dispatched at `t` (max
        over covering straggle windows; 1.0 outside any window)."""
        factor = 1.0
        for kind, t0, t1, v in self.events:
            if kind == "straggle" and t0 <= t < t1 and v > factor:
                factor = v
        return factor

    def error_p(self, t: float) -> float:
        """Per-batch failure probability at dispatch instant `t`."""
        p = 0.0
        for kind, t0, t1, v in self.events:
            if kind == "error" and t0 <= t < t1 and v > p:
                p = v
        return p

    def rng(self) -> np.random.Generator:
        """The stage's seeded substream (shared seeding convention with
        the live executor: ``[seed, crc32(stage)]``)."""
        return np.random.default_rng(
            [int(self.seed), zlib.crc32(self.stage.encode())])


class FaultSchedule:
    """A full fault scenario: events over any stages + seed + recovery.

    Normalizes the event list into per-stage sorted streams of uniform
    4-tuples (``(kind, t0, t1, value)``) — the representation both the
    engine's cone keys and the live fault driver consume. Falsy when it
    carries no events, so ``faults or None`` composes like the other
    schedule kinds.
    """

    def __init__(self, faults: Iterable[Fault] = (), seed: int = 0,
                 recovery: Optional[RecoveryPolicy] = None):
        self.seed = int(seed)
        self.recovery = recovery if recovery is not None else RecoveryPolicy()
        per: Dict[str, List[Fault]] = {}
        for f in faults:
            if not isinstance(f, Fault):
                raise TypeError(f"expected Fault, got {type(f).__name__}")
            per.setdefault(f.stage, []).append(f)
        self._by_stage: Dict[str, StageFaults] = {}
        for s, fs in per.items():
            raw = [(f.kind, f.t0, f.t1, f.value) for f in fs]
            evs = tuple(sorted(
                (str(k), float(a), float(b), float(v))
                for k, a, b, v in raw))
            self._by_stage[s] = StageFaults(s, evs, self.seed, self.recovery)

    def stage(self, name: str) -> Optional[StageFaults]:
        return self._by_stage.get(name)

    def stages(self) -> Tuple[str, ...]:
        return tuple(sorted(self._by_stage))

    def __bool__(self) -> bool:
        return bool(self._by_stage)

    def key(self) -> Tuple:
        """Hashable scenario identity (seed, recovery, per-stage events)."""
        return (self.seed, self.recovery.key(), tuple(
            (s, self._by_stage[s].events) for s in self.stages()))
