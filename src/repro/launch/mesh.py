"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state. Single pod: 256 chips as (data=16, model=16). Multi-pod:
2 pods x 256 chips as (pod=2, data=16, model=16) — the pod axis carries
pure data parallelism (gradient all-reduce over DCI), `model` carries
tensor/expert parallelism inside a pod's ICI domain.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke/examples (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
