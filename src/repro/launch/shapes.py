"""Assigned input shapes and per-(arch x shape) ShapeDtypeStruct specs.

``input_specs`` returns shape-only stand-ins for every model input (no
device allocation) plus the step kind, following the assignment:

  train_4k     seq=4096    global_batch=256   (train_step)
  prefill_32k  seq=32768   global_batch=32    (prefill_step)
  decode_32k   seq=32768   global_batch=128   (decode_step: ONE new token
                                               against a seq-long cache)
  long_500k    seq=524288  global_batch=1     (decode_step; sub-quadratic
                                               archs only — DESIGN.md §4)

Family adjustments (DESIGN.md §4): whisper splits train_4k between
encoder frames and decoder tokens and decodes against its fixed 1500
frame encoder context; pixtral prepends its 1024 stub patch embeddings
inside the sequence budget.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.kvcache import init_cache

AUDIO_FEAT_DIM = 128
IMAGE_FEAT_DIM = 1024


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether this (arch, shape) combination runs (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, ("full-attention architecture without a sliding-"
                       "window variant: long_500k decode skipped")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for train/prefill batches."""
    b, s = shape.batch, shape.seq
    if cfg.is_encoder_decoder:
        if shape.kind == "train":
            frames, toks = s // 2, s // 2
        else:
            frames, toks = cfg.encoder_max_frames, s
        return {
            "tokens": _sds((b, toks), jnp.int32),
            "frames": _sds((b, frames, AUDIO_FEAT_DIM), cfg.cdtype),
        }
    if cfg.num_image_tokens:
        toks = max(s - cfg.num_image_tokens, 8)
        return {
            "tokens": _sds((b, toks), jnp.int32),
            "image_feats": _sds((b, cfg.num_image_tokens, IMAGE_FEAT_DIM),
                                cfg.cdtype),
        }
    return {"tokens": _sds((b, s), jnp.int32)}


def cache_specs(cfg: ArchConfig, shape: ShapeSpec) -> Any:
    """ShapeDtypeStructs for the decode cache (eval_shape, no alloc)."""
    smax = shape.seq
    if cfg.is_encoder_decoder:
        # decoder KV of seq length; encoder context fixed at max frames
        pass
    if cfg.num_image_tokens:
        smax = shape.seq  # image prefix counted inside the budget
    return jax.eval_shape(lambda: init_cache(cfg, shape.batch, smax))


def decode_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    return {
        "token": _sds((shape.batch, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cache": cache_specs(cfg, shape),
    }


def dryrun_config(cfg: ArchConfig, shape: ShapeSpec,
                  mesh_data_size: int) -> ArchConfig:
    """Numerics/memory policy for production lowering: bf16 params and
    compute, remat for large training graphs, bf16 optimizer moments for
    the >100B configs, EP group-limited routing aligned with the data
    axes."""
    big = cfg.param_count() > 20e9
    groups = mesh_data_size if cfg.num_experts else 1
    t = shape.batch * shape.seq
    if groups > 1 and t % groups != 0:
        groups = 1
    # pad odd vocabularies (whisper 51865, granite-moe 49155) to the next
    # multiple of the model axis so the embedding/unembedding and the CE
    # logits shard instead of replicating + all-reducing (§Perf iter. 7)
    model_size = 16
    vocab = -(-cfg.vocab_size // model_size) * model_size
    return dataclasses.replace(
        cfg,
        vocab_size=vocab,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        # remat every training config: saved per-layer activations at
        # global batch 256 x 4k dominate HBM even for small d_model
        # (whisper-small: 110 GiB/device without remat).
        remat=(shape.kind == "train"),
        moe_groups=groups,
        # big-model serving keeps the bf16 cache; ssm states stay fp32
    ), big
