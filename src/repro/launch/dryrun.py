import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # While-loop invariant code motion hoists per-iteration bf16->f32
    # converts of scanned weight/cache stacks OUT of the loop,
    # materializing full fp32 copies of every stacked buffer (measured:
    # +9 GiB/device on qwen2-72b decode_32k, +8 GiB on llama3.2-1b
    # train_4k). Disabling it trades a per-iteration convert for the
    # memory (§Perf iteration 5).
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input shape) combination against
the production meshes — 16x16 single pod and 2x16x16 multi-pod — with
ShapeDtypeStruct inputs (no allocation), records memory_analysis() /
cost_analysis(), and derives the §Roofline terms from the compiled HLO.

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init), which is why this module sets it at the top.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh both --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, get_arch  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shapes import (  # noqa: E402
    SHAPES,
    ShapeSpec,
    applicable,
    batch_specs,
    decode_specs,
    dryrun_config,
)
from repro.models import build_model  # noqa: E402
from repro.models.sharding import (  # noqa: E402
    batch_pspec,
    cache_pspec,
    param_pspec,
)
from repro.roofline.analysis import (  # noqa: E402
    model_flops_estimate,
    roofline_terms,
)
from repro.train.optimizer import AdamW  # noqa: E402

# long_500k runs for these archs only (DESIGN.md §4); the -sw variant
# substitutes for llama3.2-1b on that shape.
LONG_CONTEXT_SUBSTITUTE = {"llama3.2-1b": "llama3.2-1b-sw"}


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _with_sharding(shape_tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                             sharding=sh),
        shape_tree, sharding_tree)


def _analytic_bytes_per_device(cfg, shape, chips: int, data_size: int,
                               big: bool) -> float:
    """Per-device HBM-traffic floor for one step (roofline memory term).

    XLA's cost_analysis counts scanned layer bodies once, so its "bytes
    accessed" undercounts by ~num_layers; this analytic floor restores a
    sound lower bound: every resident parameter (and optimizer moment for
    training) is touched at least once per step, and decode reads the
    whole KV cache.
    """
    from repro.models.kvcache import cache_bytes

    n = cfg.param_count()
    p_bytes = 2.0 * n                      # bf16 params
    if shape.kind == "train":
        m_item = 2 if big else 4
        # fwd read + bwd read + update write, grads, 2 moments r/w
        traffic = (3 * p_bytes + p_bytes + 2 * 2 * m_item * n) / chips
        # activations: residual stream per layer, fwd+bwd
        toks_pd = shape.batch * shape.seq / data_size
        traffic += 2 * 2 * toks_pd * cfg.d_model * cfg.num_layers
        return traffic
    # serving: params once + cache (decode reads+writes it; prefill
    # writes it)
    cb = cache_bytes(cfg, shape.batch, shape.seq) / data_size
    factor = 2.0 if shape.kind == "decode" else 1.0
    return p_bytes / chips + factor * cb


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one combination; returns the artifact dict."""
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and arch in LONG_CONTEXT_SUBSTITUTE:
        arch_eff = LONG_CONTEXT_SUBSTITUTE[arch]
    else:
        arch_eff = arch
    base_cfg = get_arch(arch_eff)
    ok, why = applicable(base_cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    data_size = mesh.shape["data"] * mesh.shape.get("pod", 1)
    cfg, big = dryrun_config(base_cfg, shape, data_size)
    model = build_model(cfg)

    t0 = time.time()
    params_sds = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_spec = param_pspec(params_sds, mesh)
    p_shard = _named(mesh, p_spec)
    params_in = _with_sharding(params_sds, p_shard)

    tokens_total = shape.batch * shape.seq

    if shape.kind == "train":
        opt = AdamW(moment_dtype="bfloat16" if big else None)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        # moments mirror the param tree; reuse param specs for mu/nu
        opt_spec = type(opt_sds)(step=P(), mu=p_spec, nu=p_spec)
        opt_in = _with_sharding(opt_sds, _named(mesh, opt_spec))
        batch_sds = batch_specs(cfg, shape)
        b_spec = batch_pspec(batch_sds, mesh)
        batch_in = _with_sharding(batch_sds, _named(mesh, b_spec))

        from repro.train.trainer import make_train_step
        # Gradient accumulation for the very large configs: activations
        # of a full 256 x 4k batch cannot fit HBM next to >300B of
        # sharded training state (§Perf iteration 9). The microbatch
        # count targets ONE sequence per device per pass and must keep
        # each microbatch divisible by the data-axis size (256/16 = 16
        # single pod, 256/32 = 8 multi-pod) or the batch constraint is
        # skipped and activations replicate across pods.
        micro = max(1, shape.batch // data_size) if big else 1
        step = make_train_step(model, opt, microbatches=micro,
                               accum_dtype="bfloat16" if big else None)
        jitted = jax.jit(step, donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(params_in, opt_in, batch_in)
    elif shape.kind == "prefill":
        batch_sds = batch_specs(cfg, shape)
        b_spec = batch_pspec(batch_sds, mesh)
        batch_in = _with_sharding(batch_sds, _named(mesh, b_spec))
        smax = shape.seq + (cfg.num_image_tokens or 0)

        def prefill_step(params, batch):
            return model.prefill(params, batch, smax=smax)

        jitted = jax.jit(prefill_step)
        with mesh:
            lowered = jitted.lower(params_in, batch_in)
    else:  # decode
        specs = decode_specs(cfg, shape)
        shard_seq = shape.batch == 1
        c_spec = cache_pspec(specs["cache"], mesh, shard_seq=shard_seq)
        cache_in = _with_sharding(specs["cache"], _named(mesh, c_spec))
        tok_spec = batch_pspec({"tokens": specs["token"]}, mesh)["tokens"]
        tok_in = jax.ShapeDtypeStruct(
            specs["token"].shape, specs["token"].dtype,
            sharding=NamedSharding(mesh, tok_spec))
        pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P()))
        tokens_total = shape.batch  # one token per sequence

        def decode_step(params, token, pos, cache):
            return model.decode_step(params, token, pos, cache)

        jitted = jax.jit(decode_step, donate_argnums=(3,))
        with mesh:
            lowered = jitted.lower(params_in, tok_in, pos_in, cache_in)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    n_active = cfg.active_param_count()
    mf = model_flops_estimate(n_active, tokens_total, shape.kind)
    ab = _analytic_bytes_per_device(cfg, shape, chips, data_size, big)
    report = roofline_terms(
        arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        cost_analysis=cost, hlo_text=hlo, model_flops=mf,
        peak_mem=getattr(mem, "temp_size_in_bytes", None),
        analytic_bytes=ab)

    art = {
        "arch": arch, "arch_effective": arch_eff, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "roofline": report.to_json(),
    }
    if verbose:
        r = art["roofline"]
        print(f"[{arch} x {shape_name} x {art['mesh']}] compile "
              f"{t_compile:.1f}s  flops={r['hlo_flops']:.3e} "
              f"coll={r['collective_bytes']:.3e}B "
              f"bottleneck={r['bottleneck']}", flush=True)
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS,
                    help="single architecture (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[{tag}] cached", flush=True)
                            n_ok += 1
                            continue
                try:
                    art = lower_one(arch, shape, multi)
                    if art["status"] == "ok":
                        n_ok += 1
                    else:
                        n_skip += 1
                        print(f"[{tag}] SKIP: {art['reason']}", flush=True)
                except Exception as e:  # noqa: BLE001
                    n_fail += 1
                    art = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "fail", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"[{tag}] FAIL: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(art, f, indent=1)
    print(f"dry-run complete: ok={n_ok} skipped={n_skip} failed={n_fail}",
          flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
