"""Per-query simulation outcomes shared by every engine consumer.

``SimResult`` is the single result type produced by the unified engine
(:mod:`repro.sim.engine`) and consumed by the Estimator façade, the live
cluster simulation, the baselines, and the benchmark drivers.

Beyond the seed estimator's result it carries an optional per-query
``dropped`` mask for SLO-aware load-shedding policies
(:mod:`repro.sim.queueing`): shed queries have ``latency = +inf`` and
``dropped[q] = True``, and count as SLO misses.

For mixed per-query SLO workloads (:mod:`repro.workload.slo_classes`)
it additionally carries per-query ``class_ids`` / ``slo_s`` tags, and
:meth:`per_class` reports the latency/miss/drop breakdown each class
sees — the multi-class planner objective and the SLO-class benchmark
both consume it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.envelope import TrafficEnvelope


@dataclasses.dataclass
class SimResult:
    """Per-query outcome of one simulation run."""

    arrival: np.ndarray            # (n,) arrival time of each query
    latency: np.ndarray            # (n,) end-to-end latency (s); +inf if shed
    per_stage_batches: Dict[str, np.ndarray]  # stage -> batch sizes formed
    dropped: Optional[np.ndarray] = None      # (n,) bool; None = no shedding
    class_ids: Optional[np.ndarray] = None    # (n,) int SLO-class tags
    class_names: Optional[Tuple[str, ...]] = None  # id -> display name
    slo_s: Optional[np.ndarray] = None        # (n,) per-query SLO (s)

    @property
    def num_queries(self) -> int:
        return int(self.arrival.shape[0])

    @property
    def num_dropped(self) -> int:
        return int(self.dropped.sum()) if self.dropped is not None else 0

    @property
    def drop_rate(self) -> float:
        n = self.num_queries
        return self.num_dropped / n if n else 0.0

    def _miss_mask(self, slo: float) -> np.ndarray:
        miss = self.latency > slo
        if self.dropped is not None:
            miss = miss | self.dropped
        return miss

    def percentile(self, p: float) -> float:
        """Latency percentile over ALL queries (shed queries are +inf, so
        tail percentiles correctly blow up under shedding)."""
        return float(np.percentile(self.latency, p)) if self.latency.size else 0.0

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean(self) -> float:
        """Mean latency over served (non-shed) queries."""
        if not self.latency.size:
            return 0.0
        if self.dropped is not None and self.dropped.any():
            served = self.latency[~self.dropped]
            return float(served.mean()) if served.size else 0.0
        return float(self.latency.mean())

    def slo_miss_rate(self, slo: float) -> float:
        if not self.latency.size:
            return 0.0
        return float(self._miss_mask(slo).mean())

    def slo_attainment(self, slo: float) -> float:
        return 1.0 - self.slo_miss_rate(slo)

    # -- per-query / per-class SLO accounting -----------------------------
    def per_query_miss_mask(self) -> np.ndarray:
        """Miss mask against each query's OWN SLO (requires ``slo_s``)."""
        if self.slo_s is None:
            raise ValueError("result carries no per-query slo_s")
        miss = self.latency > self.slo_s
        if self.dropped is not None:
            miss = miss | self.dropped
        return miss

    def per_query_miss_rate(self) -> float:
        if not self.latency.size:
            return 0.0
        return float(self.per_query_miss_mask().mean())

    def class_mask(self, cls) -> np.ndarray:
        """Bool mask for one class, by id or (if names were set) name."""
        if self.class_ids is None:
            raise ValueError("result carries no class_ids")
        if isinstance(cls, str):
            if self.class_names is None:
                raise ValueError("result carries no class_names")
            cls = self.class_names.index(cls)
        return self.class_ids == int(cls)

    def per_class(self) -> Dict[str, Dict[str, float]]:
        """Latency/miss/drop breakdown per SLO class.

        Returns ``{class_name: {n, slo_s, p50, p99, p99_served,
        mean_served, miss_rate, drop_rate}}``; miss rate is against the
        class's own SLO (misses include drops). When ``class_names`` is
        set, every named class gets an entry — a class with no queries
        in the trace reports ``n=0`` and zero latencies rather than
        vanishing from the breakdown.
        """
        if self.class_ids is None:
            raise ValueError("result carries no class_ids")
        ids = (range(len(self.class_names)) if self.class_names
               else np.unique(self.class_ids))
        out: Dict[str, Dict[str, float]] = {}
        for cid in ids:
            sel = self.class_ids == cid
            name = (self.class_names[int(cid)] if self.class_names
                    else str(int(cid)))
            if not sel.any():
                out[name] = {"n": 0, "p50": 0.0, "p99": 0.0,
                             "p99_served": 0.0, "mean_served": 0.0,
                             "drop_rate": 0.0}
                if self.slo_s is not None:
                    out[name]["slo_s"] = float("nan")
                    out[name]["miss_rate"] = 0.0
                continue
            lat = self.latency[sel]
            dropped = self.dropped[sel] if self.dropped is not None else \
                np.zeros(lat.shape[0], dtype=bool)
            served = lat[~dropped]
            # under heavy shedding the all-queries percentiles interpolate
            # between +infs (nan); that is meaningful ("tail is shed"),
            # p99_served carries the finite tail — just mute the warning
            with np.errstate(invalid="ignore"):
                p50 = float(np.percentile(lat, 50.0))
                p99 = float(np.percentile(lat, 99.0))
            stats = {
                "n": int(lat.shape[0]),
                "p50": p50,
                "p99": p99,
                "p99_served": (float(np.percentile(served, 99.0))
                               if served.size else 0.0),
                "mean_served": float(served.mean()) if served.size else 0.0,
                "drop_rate": float(dropped.mean()) if lat.size else 0.0,
            }
            if self.slo_s is not None:
                slo = self.slo_s[sel]
                stats["slo_s"] = float(slo[0]) if slo.size else float("nan")
                stats["miss_rate"] = float(
                    ((lat > slo) | dropped).mean()) if lat.size else 0.0
            out[name] = stats
        return out

    def telemetry_summary(self) -> Dict[str, float]:
        """Scalar roll-up used by closed-loop benchmark records."""
        out = {"n": float(self.num_queries), "p99": self.p99,
               "mean": self.mean, "drop_rate": self.drop_rate}
        if self.slo_s is not None:
            out["miss_rate"] = self.per_query_miss_rate()
        return out

    def windowed_miss_rate(self, slo: float, window_s: float = 5.0
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """(window_start_times, miss_rate per window) for time-series plots.

        Vectorized: one ``np.bincount`` pass over the trace instead of the
        seed's O(windows x n) Python loop — fig6/fig7 call this per window
        configuration over hour-long traces.
        """
        if not self.latency.size:
            return np.zeros(0), np.zeros(0)
        t_end = float(self.arrival.max())
        edges = np.arange(0.0, t_end + window_s, window_s)
        idx = np.clip(np.digitize(self.arrival, edges) - 1, 0, len(edges) - 1)
        miss = self._miss_mask(slo).astype(np.float64)
        counts = np.bincount(idx, minlength=len(edges)).astype(np.float64)
        missed = np.bincount(idx, weights=miss, minlength=len(edges))
        rates = np.full(len(edges), np.nan)
        nz = counts > 0
        rates[nz] = missed[nz] / counts[nz]
        return edges, rates


# -- closed-loop co-simulation telemetry (repro.sim.control) ---------------
#
# One EpochTelemetry per control epoch: the engine advances to the epoch
# boundary, samples each stage's queue, and the Tuner consumes the record
# to decide scale / admission-control events. Everything here is CAUSAL —
# computed only from batches whose start time is at or before the epoch
# boundary, which future control events (landing strictly later) can
# never alter, so the record a controller sees mid-run is exactly the
# record a full-trace re-simulation with the final schedule reproduces.


@dataclasses.dataclass
class StageTelemetry:
    """One stage's queue view over one control epoch (t_start, t_end]."""

    stage: str
    arrived: int          # queries whose input became ready in the window
    completed: int        # finite completions in the window
    dropped: int          # shed queries whose deadline fell in the window
    queue_depth: int      # ready <= t_end, neither completed nor shed yet
    in_flight: int        # queue_depth subset completing within one batch
    #                       service time of t_end (= currently in service,
    #                       up to the batch-latency bound)
    replicas: int         # configured replica target effective at t_end
    alive: int = -1       # replicas minus observed crash losses at t_end;
    #                       -1 = no fault tracking (legacy constructors),
    #                       which controllers treat as "assume healthy"


@dataclasses.dataclass
class EpochTelemetry:
    """Everything the engine tells the Tuner at one epoch boundary."""

    epoch: int
    t_start: float
    t_end: float
    ingress: int                      # ingress arrivals in the window
    ingress_prefix: np.ndarray        # all ingress arrivals <= t_end
    observed_envelope: TrafficEnvelope  # incremental envelope over prefix
    stages: Dict[str, StageTelemetry]
    completed: int                    # pipeline completions in the window
    missed: int                       # window completions over their SLO
    overdue: int                      # uncompleted queries whose deadline
    #                                   newly passed in the window (a miss
    #                                   observable before completion)
    drops: int                        # shed, deadline in the window
    p99_s: float                      # window-completion p99 (nan if none)

    @property
    def misses(self) -> int:
        """SLO misses observed this epoch (late completions + newly
        overdue in-flight/shed queries)."""
        return self.missed + self.overdue

    @property
    def queue_depth_total(self) -> int:
        return sum(s.queue_depth for s in self.stages.values())

    @property
    def miss_fraction(self) -> float:
        """Misses over queries resolved or newly overdue this epoch."""
        return self.misses / max(self.completed + self.overdue, 1)
