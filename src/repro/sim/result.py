"""Per-query simulation outcomes shared by every engine consumer.

``SimResult`` is the single result type produced by the unified engine
(:mod:`repro.sim.engine`) and consumed by the Estimator façade, the live
cluster simulation, the baselines, and the benchmark drivers.

Beyond the seed estimator's result it carries an optional per-query
``dropped`` mask for SLO-aware load-shedding policies
(:mod:`repro.sim.queueing`): shed queries have ``latency = +inf`` and
``dropped[q] = True``, and count as SLO misses.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SimResult:
    """Per-query outcome of one simulation run."""

    arrival: np.ndarray            # (n,) arrival time of each query
    latency: np.ndarray            # (n,) end-to-end latency (s); +inf if shed
    per_stage_batches: Dict[str, np.ndarray]  # stage -> batch sizes formed
    dropped: Optional[np.ndarray] = None      # (n,) bool; None = no shedding

    @property
    def num_queries(self) -> int:
        return int(self.arrival.shape[0])

    @property
    def num_dropped(self) -> int:
        return int(self.dropped.sum()) if self.dropped is not None else 0

    @property
    def drop_rate(self) -> float:
        n = self.num_queries
        return self.num_dropped / n if n else 0.0

    def _miss_mask(self, slo: float) -> np.ndarray:
        miss = self.latency > slo
        if self.dropped is not None:
            miss = miss | self.dropped
        return miss

    def percentile(self, p: float) -> float:
        """Latency percentile over ALL queries (shed queries are +inf, so
        tail percentiles correctly blow up under shedding)."""
        return float(np.percentile(self.latency, p)) if self.latency.size else 0.0

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean(self) -> float:
        """Mean latency over served (non-shed) queries."""
        if not self.latency.size:
            return 0.0
        if self.dropped is not None and self.dropped.any():
            served = self.latency[~self.dropped]
            return float(served.mean()) if served.size else 0.0
        return float(self.latency.mean())

    def slo_miss_rate(self, slo: float) -> float:
        if not self.latency.size:
            return 0.0
        return float(self._miss_mask(slo).mean())

    def slo_attainment(self, slo: float) -> float:
        return 1.0 - self.slo_miss_rate(slo)

    def windowed_miss_rate(self, slo: float, window_s: float = 5.0
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """(window_start_times, miss_rate per window) for time-series plots.

        Vectorized: one ``np.bincount`` pass over the trace instead of the
        seed's O(windows x n) Python loop — fig6/fig7 call this per window
        configuration over hour-long traces.
        """
        if not self.latency.size:
            return np.zeros(0), np.zeros(0)
        t_end = float(self.arrival.max())
        edges = np.arange(0.0, t_end + window_s, window_s)
        idx = np.clip(np.digitize(self.arrival, edges) - 1, 0, len(edges) - 1)
        miss = self._miss_mask(slo).astype(np.float64)
        counts = np.bincount(idx, minlength=len(edges)).astype(np.float64)
        missed = np.bincount(idx, weights=miss, minlength=len(edges))
        rates = np.full(len(edges), np.nan)
        nz = counts > 0
        rates[nz] = missed[nz] / counts[nz]
        return edges, rates
