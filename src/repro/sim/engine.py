"""Unified incremental discrete-event engine (the one simulation core).

Every consumer — the Estimator façade (:mod:`repro.core.estimator`), the
Planner/AnnealedPlanner search, the live-cluster simulation
(:mod:`repro.serving.cluster`), and both baselines — drives this engine.

Engine design (recorded in EXPERIMENTS.md §Perf): the paper implements a
global event heap over the whole pipeline. Because (a) routing is
feed-forward (DAG) and (b) the centralized batched queue at a stage
depends only on that stage's input arrival times and its own replica
schedule, we simulate *stage-by-stage in topological order*; each stage
is one single-queue / R-server / batch-service system handled by a
pluggable queueing policy (:mod:`repro.sim.queueing`).

Incremental re-simulation: a :class:`TraceSession` binds the engine to
one arrival trace and memoizes per-stage outcomes keyed on the stage's
*configuration cone* — the (hardware, batch, replicas, timeout, policy,
schedule) of the stage and every ancestor. A planner action that mutates
one stage therefore re-simulates only that stage's downstream cone; all
sibling branches and upstream stages are cache hits. Combined with the
LUT/routing-draw caches this is what makes thousands of candidate
evaluations per plan cheap (the ≥5x plan wall-clock win in
``BENCH_engine.json``), while remaining bit-identical to full
re-simulation.
"""

from __future__ import annotations

import collections
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.pipeline import SOURCE, Pipeline, PipelineConfig
from repro.core.policy import effective_max_batch as _effective_max_batch
from repro.core.profiler import ProfileStore
from repro.sim.queueing import simulate_stage
from repro.sim.result import SimResult

# Per-hop RPC/serialization delay. The frontend adapters (Fig. 13) override
# this: the "tfs"-style frontend carries extra serialization overhead.
DEFAULT_RPC_DELAY_S = 0.0005

Schedule = Sequence[Tuple[float, int]]
Schedules = Dict[str, Schedule]
# piecewise-constant shed-margin schedules for slo-drop stages
# (see repro.sim.queueing module docstring / repro.sim.control)
ShedSchedule = Sequence[Tuple[float, float]]
ShedSchedules = Dict[str, ShedSchedule]
# piecewise queueing-policy switch schedules (repro.core.policy): a stage
# with a non-empty schedule simulates through the policy-core scalar
# path (repro.sim.queueing.switched) instead of its dedicated kernel
PolicySchedule = Sequence[Tuple[float, str]]
PolicySchedules = Dict[str, PolicySchedule]


def _sched_key(sched: Optional[Schedule]) -> Tuple:
    return tuple((float(t), int(d)) for t, d in sched) if sched else ()


def _shed_key(sched: Optional[ShedSchedule]) -> Tuple:
    return tuple((float(t), float(m)) for t, m in sched) if sched else ()


def _policy_key(sched: Optional[PolicySchedule]) -> Tuple:
    return tuple((float(t), str(p)) for t, p in sched) if sched else ()


def _fault_key(spec) -> Tuple:
    """Cache-key component for one stage's fault spec
    (:class:`repro.faults.schedule.StageFaults`); faults change stage
    outcomes just like replica/shed/policy schedules, so they must
    reach the cone keys (KEY01)."""
    if spec is None:
        return ()
    return (int(spec.seed), spec.recovery.key(), tuple(
        (str(kind), float(t0), float(t1), float(v))
        for kind, t0, t1, v in spec.events))


class SimEngine:
    """Stateless pipeline simulator + shared caches (LUTs, routing draws).

    Use :meth:`simulate` for one-shot runs, or open a :meth:`session` on a
    trace to get incremental re-simulation across many configurations.
    """

    def __init__(
        self,
        pipeline: Pipeline,
        profiles: ProfileStore,
        rpc_delay_s: float = DEFAULT_RPC_DELAY_S,
        seed: int = 0,
    ):
        self.pipeline = pipeline
        self.profiles = profiles
        self.rpc_delay_s = rpc_delay_s
        self.seed = seed
        self._topo = pipeline.toposort()
        self._edges_in: Dict[str, List] = {
            s: [e for e in pipeline.edges if e.dst == s] for s in self._topo
        }
        # ancestors incl. self (topo-ordered) — the memoization cone
        anc_sets: Dict[str, set] = {}
        for s in self._topo:
            ups: set = {s}
            for e in self._edges_in[s]:
                if e.src != SOURCE:
                    ups |= anc_sets[e.src]
            anc_sets[s] = ups
        topo_idx = {s: i for i, s in enumerate(self._topo)}
        self._cone: Dict[str, Tuple[str, ...]] = {
            s: tuple(sorted(anc_sets[s], key=topo_idx.__getitem__))
            for s in self._topo
        }
        self._descendants: Dict[str, Tuple[str, ...]] = {
            s: tuple(t for t in self._topo if s in anc_sets[t])
            for s in self._topo
        }
        self._longest_path = pipeline.longest_path_stages()
        self._lut_cache: Dict[Tuple[str, str, int], np.ndarray] = {}
        self._draw_cache: Dict[int, Dict[Tuple[str, str], np.ndarray]] = {}
        self._service_time_cache: Dict[Tuple, float] = {}

    # -- shared caches ------------------------------------------------------
    def latency_lut(self, stage: str, hardware: str, max_batch: int
                    ) -> np.ndarray:
        model_id = self.pipeline.stages[stage].model_id
        key = (model_id, hardware, max_batch)
        lut = self._lut_cache.get(key)
        if lut is None:
            prof = self.profiles.get(model_id)
            lut = prof.latency_lut(hardware, max_batch)
            self._lut_cache[key] = lut
        return lut

    def edge_draws(self, n: int) -> Dict[Tuple[str, str], np.ndarray]:
        """Pre-sampled Bernoulli outcomes per (edge, query).

        Fixed seed => identical routing across candidate configurations
        (the paper reuses one sample trace across the whole search), and
        across repeat calls, so draws are cached per trace length.
        """
        draws = self._draw_cache.get(n)
        if draws is None:
            rng = np.random.default_rng(self.seed)
            draws = {}
            for e in self.pipeline.edges:
                if e.probability >= 1.0:
                    draws[(e.src, e.dst)] = np.ones(n, dtype=bool)
                else:
                    draws[(e.src, e.dst)] = rng.random(n) < e.probability
            self._draw_cache[n] = draws
        return draws

    # -- public API ---------------------------------------------------------
    def session(self, arrivals: np.ndarray,
                slo_s: Optional[Union[float, np.ndarray]] = None,
                class_ids: Optional[np.ndarray] = None,
                class_names: Optional[Sequence[str]] = None,
                max_cache_entries: int = 512,
                max_cache_bytes: Optional[int] = None,
                max_accum_bytes: Optional[int] = None,
                backend: str = "numpy") -> "TraceSession":
        """Bind the engine to one trace for incremental re-simulation.

        ``slo_s`` may be a scalar (uniform SLO, the paper's setting) or a
        per-query vector for mixed SLO classes; ``class_ids`` /
        ``class_names`` tag queries for per-class ``SimResult``
        breakdowns (see :mod:`repro.workload.slo_classes`).
        ``max_accum_bytes=0`` disables the prefix-accumulator cache
        (the pre-batching assembly behavior; benchmarks use it as the
        honest "loop path" baseline).

        ``backend="jax"`` selects the device fill kernel
        (:mod:`repro.sim.jax_backend`): single-stage simulations fall
        back to numpy below the kernel's crossover, and
        :meth:`TraceSession.percentile_many` additionally routes
        eligible single-stage candidate grids through one vmapped
        device program. Bit-identical either way; degrades to numpy
        when jax is not importable.
        """
        return TraceSession(self, arrivals, slo_s=slo_s,
                            class_ids=class_ids, class_names=class_names,
                            max_cache_entries=max_cache_entries,
                            max_cache_bytes=max_cache_bytes,
                            max_accum_bytes=max_accum_bytes,
                            backend=backend)

    def simulate(
        self,
        config: PipelineConfig,
        arrivals: np.ndarray,
        replica_schedules: Optional[Schedules] = None,
        slo_s: Optional[Union[float, np.ndarray]] = None,
        class_ids: Optional[np.ndarray] = None,
        class_names: Optional[Sequence[str]] = None,
        shed_schedules: Optional[ShedSchedules] = None,
        policy_schedules: Optional[PolicySchedules] = None,
        fault_schedules=None,
    ) -> SimResult:
        """One-shot simulation (fresh session; no cross-call memoization)."""
        return self.session(arrivals, slo_s=slo_s, class_ids=class_ids,
                            class_names=class_names).simulate(
            config, replica_schedules=replica_schedules,
            shed_schedules=shed_schedules,
            policy_schedules=policy_schedules,
            fault_schedules=fault_schedules)

    def service_time(self, config: PipelineConfig) -> float:
        """Sum of batch-size-configured latencies along the longest path
        (queueing excluded) — Alg. 1's `ServiceTime`. Memoized on the
        path's (hw, batch) assignment."""
        key = tuple((s, config[s].hardware, config[s].batch_size)
                    for s in self._longest_path)
        cached = self._service_time_cache.get(key)
        if cached is None:
            total = 0.0
            for stage in self._longest_path:
                cfg = config[stage]
                prof = self.profiles.get(self.pipeline.stages[stage].model_id)
                total += prof.batch_latency(cfg.hardware, cfg.batch_size)
                total += self.rpc_delay_s
            cached = total + self.rpc_delay_s
            self._service_time_cache[key] = cached
        return cached

    def descendants(self, stage: str) -> Tuple[str, ...]:
        """`stage` plus everything downstream of it (the re-sim cone)."""
        return self._descendants[stage]


class StageState:
    """Per-query view of one stage's queue for control-loop telemetry.

    All arrays are aligned to the query index of the bound trace:
    ``visited`` marks queries that reach the stage, ``ready`` their
    input-queue arrival instants (0 where not visited), ``completion``
    their stage completion (-inf not visited, +inf shed), ``dropped``
    the stage's shed mask (or None).
    """

    __slots__ = ("visited", "ready", "completion", "dropped")

    def __init__(self, visited, ready, completion, dropped):
        self.visited = visited
        self.ready = ready
        self.completion = completion
        self.dropped = dropped


class _StageEntry:
    __slots__ = ("visited", "completion", "batches", "dropped", "nbytes")

    def __init__(self, visited, completion, batches, dropped):
        self.visited = visited
        self.completion = completion
        self.batches = batches
        self.dropped = dropped        # None or full-length bool mask
        self.nbytes = (visited.nbytes + completion.nbytes + batches.nbytes
                       + (dropped.nbytes if dropped is not None else 0))


class TraceSession:
    """The engine bound to one arrival trace, with per-stage memoization.

    ``simulate`` / ``simulate_delta`` / ``simulate_many`` share one
    cache: evaluating a candidate that differs from any previously-seen
    configuration in one stage re-simulates only that stage's downstream
    cone. ``stats`` counts actual stage simulations vs cache hits so
    callers (and tests) can verify incrementality.
    """

    # stage-cache byte budget: entries hold full-trace-length arrays, so
    # a pure entry-count cap would scale memory with trace length
    # (512 entries x an hour-long trace ~ GBs); evict to stay under this
    DEFAULT_CACHE_BYTES = 256 * 1024 * 1024
    # accumulator (prefix) cache: one last_done array per distinct
    # stage-key prefix — smaller entries, tighter budget
    DEFAULT_ACCUM_BYTES = 64 * 1024 * 1024

    def __init__(self, engine: SimEngine, arrivals: np.ndarray,
                 slo_s: Optional[Union[float, np.ndarray]] = None,
                 class_ids: Optional[np.ndarray] = None,
                 class_names: Optional[Sequence[str]] = None,
                 max_cache_entries: int = 512,
                 max_cache_bytes: Optional[int] = None,
                 max_accum_bytes: Optional[int] = None,
                 backend: str = "numpy"):
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}; "
                             f"have ('numpy', 'jax')")
        self.backend = backend
        self.engine = engine
        self.arrivals = np.asarray(arrivals, dtype=np.float64)
        self.n = int(self.arrivals.shape[0])
        self.slo_s = slo_s
        # scalar slo_s = uniform deadline (seed semantics, bit-identical:
        # arrivals + scalar and arrivals + broadcast vector are the same
        # float64 adds); a (n,) vector carries mixed per-query SLO classes
        if slo_s is None:
            self.slo_per_query: Optional[np.ndarray] = None
            self.deadline: Optional[np.ndarray] = None
        else:
            slo_arr = np.asarray(slo_s, dtype=np.float64)
            if slo_arr.ndim == 0:
                slo_arr = np.full(self.n, float(slo_arr))
            elif slo_arr.shape != (self.n,):
                raise ValueError(
                    f"slo_s must be a scalar or shape ({self.n},) vector, "
                    f"got shape {slo_arr.shape}")
            self.slo_per_query = slo_arr
            self.deadline = self.arrivals + slo_arr
        if class_ids is None:
            self.class_ids: Optional[np.ndarray] = None
        else:
            self.class_ids = np.asarray(class_ids, dtype=np.int64)
            if self.class_ids.shape != (self.n,):
                raise ValueError(
                    f"class_ids must have shape ({self.n},), got "
                    f"{self.class_ids.shape}")
        self.class_names = tuple(class_names) if class_names else None
        self.draws = engine.edge_draws(self.n)
        self.max_cache_entries = max_cache_entries
        self.max_cache_bytes = (max_cache_bytes if max_cache_bytes is not None
                                else self.DEFAULT_CACHE_BYTES)
        self._cache_bytes = 0
        self._stage_cache: "collections.OrderedDict[Tuple, _StageEntry]" = \
            collections.OrderedDict()
        # scalar percentile memo; capped too (keys are full config tuples,
        # and long annealing sessions evaluate thousands of configs)
        self._pctl_cache: "collections.OrderedDict[Tuple, float]" = \
            collections.OrderedDict()
        self._max_pctl_entries = max(4096, 8 * max_cache_entries)
        # prefix-accumulator cache: (last_done, dropped) keyed on the
        # topo-ordered tuple of stage keys up to a stage. Candidates that
        # share a configuration prefix (the planner's probe grids differ
        # in one stage) skip the shared part of result assembly, not just
        # the shared stage simulations. 0 bytes disables it (the
        # pre-batching "loop" behavior, kept honest for benchmarks).
        self.max_accum_bytes = (max_accum_bytes if max_accum_bytes is not None
                                else self.DEFAULT_ACCUM_BYTES)
        self._accum_cache: "collections.OrderedDict[Tuple, Tuple]" = \
            collections.OrderedDict()
        self._accum_bytes = 0
        self.stats = {"full_sims": 0, "stage_sims": 0, "stage_hits": 0,
                      "accum_hits": 0}

    # -- cache keys ---------------------------------------------------------
    def _stage_key(self, stage: str, config: PipelineConfig,
                   schedules: Optional[Schedules],
                   shed_schedules: Optional[ShedSchedules] = None,
                   policy_schedules: Optional[PolicySchedules] = None,
                   fault_schedules=None) -> Tuple:
        # StageConfig.key() is the single source of truth for config
        # identity — new StageConfig knobs invalidate these caches
        # automatically instead of silently colliding. The backend token
        # keeps device- and host-computed entries apart (they are
        # bit-identical by contract, but a parity regression must not be
        # maskable by a cache hit from the other backend).
        sched = schedules or {}
        shed = shed_schedules or {}
        pols = policy_schedules or {}
        faults = fault_schedules
        return (stage, self.backend, tuple(
            (s, config[s].key(), _sched_key(sched.get(s)),
             _shed_key(shed.get(s)), _policy_key(pols.get(s)),
             _fault_key(faults.stage(s) if faults else None))
            for s in self.engine._cone[stage]
        ))

    @staticmethod
    def config_key(config: PipelineConfig,
                   schedules: Optional[Schedules] = None,
                   shed_schedules: Optional[ShedSchedules] = None,
                   policy_schedules: Optional[PolicySchedules] = None
                   ) -> Tuple:
        if not schedules and not shed_schedules and not policy_schedules:
            return config.cache_key()
        return (config.cache_key(), tuple(sorted(
            (s, _sched_key(sch)) for s, sch in (schedules or {}).items())),
            tuple(sorted((s, _shed_key(sch))
                         for s, sch in (shed_schedules or {}).items())),
            tuple(sorted((s, _policy_key(sch))
                         for s, sch in (policy_schedules or {}).items())))

    # -- simulation ---------------------------------------------------------
    def _stage_ready(
        self,
        stage: str,
        visited: Dict[str, np.ndarray],
        completion: Dict[str, np.ndarray],
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(visited mask, ready times) of a stage's input queue, from its
        parents' outcomes. Factored out of the stage simulation so the
        control-loop telemetry (:meth:`stage_states`) reconstructs the
        exact same queue the policy saw."""
        engine = self.engine
        n = self.n
        vis = np.zeros(n, dtype=bool)
        ready = np.zeros(n, dtype=np.float64)
        for e in engine._edges_in[stage]:
            deliver = completion[e.src] + engine.rpc_delay_s
            active = visited[e.src] & self.draws[(e.src, e.dst)]
            # shed queries complete at +inf and never reach children
            # (-inf = not visited, already excluded by the visited mask)
            active &= np.isfinite(completion[e.src])
            # AND-join over active parents
            ready = np.where(active, np.maximum(ready, deliver), ready)
            vis |= active
        return vis, ready

    def _simulate_stage_entry(
        self,
        stage: str,
        config: PipelineConfig,
        schedules: Optional[Schedules],
        visited: Dict[str, np.ndarray],
        completion: Dict[str, np.ndarray],
        shed_schedules: Optional[ShedSchedules] = None,
        policy_schedules: Optional[PolicySchedules] = None,
        fault_schedules=None,
    ) -> _StageEntry:
        engine = self.engine
        n = self.n
        vis, ready = self._stage_ready(stage, visited, completion)
        k = int(vis.sum())
        if k == 0:
            return _StageEntry(vis, np.full(n, -np.inf),
                               np.zeros(0, dtype=np.int64), None)
        cfg = config[stage]
        lut = engine.latency_lut(stage, cfg.hardware, cfg.batch_size)
        idx = np.nonzero(vis)[0]
        order = idx[np.argsort(ready[idx], kind="stable")]
        sorted_ready = ready[order]
        sorted_deadline = (self.deadline[order]
                           if self.deadline is not None else None)
        # a stage with a policy-switch schedule routes through the
        # policy-core scalar path; everything else hits its dedicated
        # (vectorized/hoisted) kernel as before
        done_sorted, batches, dropped_sorted = simulate_stage(
            getattr(cfg, "policy", "fifo"),
            sorted_ready, lut, cfg.batch_size, cfg.replicas,
            (schedules or {}).get(stage),
            getattr(cfg, "timeout_s", 0.0), sorted_deadline,
            (shed_schedules or {}).get(stage),
            (policy_schedules or {}).get(stage),
            backend=self.backend,
            fault_spec=(fault_schedules.stage(stage)
                        if fault_schedules else None),
        )
        comp = np.full(n, -np.inf)
        comp[order] = done_sorted
        drop_mask = None
        if dropped_sorted.any():
            drop_mask = np.zeros(n, dtype=bool)
            drop_mask[order] = dropped_sorted
        return _StageEntry(vis, comp, batches, drop_mask)

    def simulate(
        self,
        config: PipelineConfig,
        replica_schedules: Optional[Schedules] = None,
        shed_schedules: Optional[ShedSchedules] = None,
        policy_schedules: Optional[PolicySchedules] = None,
        fault_schedules=None,
    ) -> SimResult:
        """Run the trace through the configured pipeline.

        Per-stage results are memoized on the stage's configuration cone,
        so repeat calls with partially-overlapping configurations only
        simulate the stages whose cone actually changed.

        ``fault_schedules`` (a :class:`repro.faults.FaultSchedule`) adds
        deterministic crash/straggle/error disruptions; its per-stage
        components are part of the cone cache keys.
        """
        engine = self.engine
        n = self.n
        self.stats["full_sims"] += 1
        visited: Dict[str, np.ndarray] = {SOURCE: np.ones(n, dtype=bool)}
        completion: Dict[str, np.ndarray] = {SOURCE: self.arrivals}
        # ingress counts as t0; np.where below never mutates, so the
        # arrivals array itself is a safe accumulator base
        last_done = self.arrivals
        per_stage_batches: Dict[str, np.ndarray] = {}
        dropped: Optional[np.ndarray] = None
        accum_on = self.max_accum_bytes > 0
        acc_key: Tuple = ()

        for stage in engine._topo:
            skey = self._stage_key(stage, config, replica_schedules,
                                   shed_schedules, policy_schedules,
                                   fault_schedules)
            ent = self._stage_cache.get(skey)
            if ent is None:
                ent = self._simulate_stage_entry(
                    stage, config, replica_schedules, visited, completion,
                    shed_schedules, policy_schedules, fault_schedules)
                self._stage_cache[skey] = ent
                self._cache_bytes += ent.nbytes
                self.stats["stage_sims"] += 1
                while self._stage_cache and (
                        len(self._stage_cache) > self.max_cache_entries
                        or self._cache_bytes > self.max_cache_bytes):
                    _, old = self._stage_cache.popitem(last=False)
                    self._cache_bytes -= old.nbytes
            else:
                self._stage_cache.move_to_end(skey)
                self.stats["stage_hits"] += 1
            visited[stage] = ent.visited
            completion[stage] = ent.completion
            per_stage_batches[stage] = ent.batches
            if accum_on:
                acc_key = acc_key + (skey,)
                cached = self._accum_cache.get(acc_key)
                if cached is not None:
                    self._accum_cache.move_to_end(acc_key)
                    self.stats["accum_hits"] += 1
                    last_done, dropped = cached
                    continue
            vis = ent.visited
            if vis.any():
                last_done = np.where(
                    vis, np.maximum(last_done, ent.completion), last_done)
            if ent.dropped is not None:
                dropped = (ent.dropped if dropped is None
                           else dropped | ent.dropped)
            if accum_on:
                self._accum_store(acc_key, last_done, dropped)

        latency = last_done - self.arrivals + engine.rpc_delay_s  # reply hop
        return SimResult(self.arrivals, latency, per_stage_batches, dropped,
                         class_ids=self.class_ids,
                         class_names=self.class_names,
                         slo_s=self.slo_per_query)

    def stage_states(
        self,
        config: PipelineConfig,
        replica_schedules: Optional[Schedules] = None,
        shed_schedules: Optional[ShedSchedules] = None,
        policy_schedules: Optional[PolicySchedules] = None,
        fault_schedules=None,
    ) -> Dict[str, StageState]:
        """Per-stage queue views for the configured simulation — what the
        closed-loop telemetry (:mod:`repro.sim.control`) samples at epoch
        boundaries. Runs (or replays from the stage cache) the same
        simulation as :meth:`simulate`; the ready times are reconstructed
        with the identical :meth:`_stage_ready` computation, so queue
        depths derived from them match what the queueing policy saw."""
        engine = self.engine
        n = self.n
        visited: Dict[str, np.ndarray] = {SOURCE: np.ones(n, dtype=bool)}
        completion: Dict[str, np.ndarray] = {SOURCE: self.arrivals}
        out: Dict[str, StageState] = {}
        for stage in engine._topo:
            skey = self._stage_key(stage, config, replica_schedules,
                                   shed_schedules, policy_schedules,
                                   fault_schedules)
            ent = self._stage_cache.get(skey)
            if ent is None:
                ent = self._simulate_stage_entry(
                    stage, config, replica_schedules, visited, completion,
                    shed_schedules, policy_schedules, fault_schedules)
                self._stage_cache[skey] = ent
                self._cache_bytes += ent.nbytes
                self.stats["stage_sims"] += 1
                while self._stage_cache and (
                        len(self._stage_cache) > self.max_cache_entries
                        or self._cache_bytes > self.max_cache_bytes):
                    _, old = self._stage_cache.popitem(last=False)
                    self._cache_bytes -= old.nbytes
            else:
                self._stage_cache.move_to_end(skey)
            vis, ready = self._stage_ready(stage, visited, completion)
            visited[stage] = ent.visited
            completion[stage] = ent.completion
            out[stage] = StageState(vis, ready, ent.completion, ent.dropped)
        return out

    def _accum_store(self, acc_key: Tuple, last_done: np.ndarray,
                     dropped: Optional[np.ndarray]) -> None:
        nb = last_done.nbytes + (dropped.nbytes if dropped is not None else 0)
        self._accum_cache[acc_key] = (last_done, dropped)
        self._accum_bytes += nb
        while self._accum_cache and self._accum_bytes > self.max_accum_bytes:
            _, (old_ld, old_dr) = self._accum_cache.popitem(last=False)
            self._accum_bytes -= old_ld.nbytes + (
                old_dr.nbytes if old_dr is not None else 0)

    def simulate_delta(
        self,
        config: PipelineConfig,
        changed_stage: Optional[str] = None,
    ) -> SimResult:
        """Re-simulate after mutating ``changed_stage`` of a previously
        simulated configuration: only the changed stage's downstream cone
        is recomputed (everything else hits the per-stage cache).

        ``changed_stage`` is a documentation/verification hint — the cone
        cache keys make the incrementality automatic either way.
        """
        return self.simulate(config)

    def simulate_many(
        self,
        configs: Iterable[PipelineConfig],
        replica_schedules: Optional[Schedules] = None,
        shed_schedules: Optional[ShedSchedules] = None,
    ) -> List[SimResult]:
        """Batched candidate evaluation (the planner's scoring surface).

        The candidate set is grouped by shared cone keys implicitly:
        every distinct stage entry is simulated exactly once (stage
        cache), result assembly is shared across candidates with common
        configuration prefixes (accumulator cache), and duplicate
        candidates collapse to one evaluation. Element-wise equal to
        ``[self.simulate(c) for c in configs]`` — property-tested in
        ``tests/test_sim_engine.py``.
        """
        seen: Dict[Tuple, SimResult] = {}
        out: List[SimResult] = []
        for config in configs:
            ck = self.config_key(config, replica_schedules, shed_schedules)
            res = seen.get(ck)
            if res is None:
                res = self.simulate(config, replica_schedules,
                                    shed_schedules)
                seen[ck] = res
            out.append(res)
        return out

    def percentile_many(
        self,
        configs: Sequence[PipelineConfig],
        p: float,
        replica_schedules: Optional[Schedules] = None,
    ) -> List[float]:
        """Percentile scoring for a candidate set — what the planner's
        probe grids and binary searches consume. One scalar per
        candidate; each miss simulates through the same shared machinery
        as ``simulate_many`` (stage entries computed once per distinct
        cone, assembly shared across common prefixes, results memoized
        in the percentile cache).

        With ``backend="jax"`` a candidate set that varies exactly one
        *sink* FIFO stage — the shape of every planner probe grid and
        lockstep replica search — is additionally scored as ONE vmapped
        device program (:func:`repro.sim.jax_backend
        .grid_stage_percentiles`): the fixed stages simulate once on
        host, the varied stage's (lut, batch, replicas, timeout) grid
        fills and reduces to percentiles on device. Bit-identical to the
        host loop (property-tested); ineligible sets fall through to it.
        """
        configs = list(configs)
        if self.backend == "jax" and not replica_schedules:
            out = self._grid_percentile_many(configs, p)
            if out is not None:
                return out
        return [self.percentile(c, p, replica_schedules) for c in configs]

    def _grid_percentile_many(self, configs: List[PipelineConfig],
                              p: float) -> Optional[List[float]]:
        """Device-grid scoring of an eligible candidate set, or None.

        Eligible: jax importable; enough uncached distinct candidates
        and a long enough trace to beat per-shape compile + dispatch;
        the candidates differ in exactly one stage; that stage is a sink
        (no descendants), so every other stage's entry is candidate-
        invariant and the accumulated completion maximum over the rest
        of the pipeline is a single shared array; the varied stage runs
        plain FIFO with a static pool and non-negative profiled
        latencies (the sorted-buffer scan's contract).
        """
        from repro.sim import jax_backend

        if not jax_backend.available():
            return None
        uncached: Dict[Tuple, PipelineConfig] = {}
        for c in configs:
            ck = self.config_key(c)
            if (self.backend, ck, p) not in self._pctl_cache:
                uncached.setdefault(ck, c)
        if len(uncached) < jax_backend._GRID_MIN_CANDIDATES:
            return None
        cands = list(uncached.values())
        pivot = cands[0]
        engine = self.engine
        varied = [s for s in engine._topo
                  if any(c[s].key() != pivot[s].key() for c in cands[1:])]
        if len(varied) != 1:
            return None
        s = varied[0]
        if engine._descendants[s] != (s,):
            return None
        luts: List[np.ndarray] = []
        effs: List[int] = []
        reps: List[int] = []
        touts: List[float] = []
        for c in cands:
            cfg = c[s]
            if (getattr(cfg, "policy", "fifo") != "fifo"
                    or cfg.replicas < 1):
                return None
            lut = engine.latency_lut(s, cfg.hardware, cfg.batch_size)
            eff = _effective_max_batch(lut, cfg.batch_size)
            if float(np.min(lut[1:eff + 1])) < 0.0:
                return None
            luts.append(lut)
            effs.append(eff)
            reps.append(int(cfg.replicas))
            touts.append(float(getattr(cfg, "timeout_s", 0.0)))
        # host pass over the candidate-invariant stages: populate/reuse
        # their cache entries and accumulate the completion maximum.
        # Skipping the sink is exact — `last_done` is an element-wise
        # max, so folding the sink's completions in on device commutes.
        n = self.n
        visited: Dict[str, np.ndarray] = {SOURCE: np.ones(n, dtype=bool)}
        completion: Dict[str, np.ndarray] = {SOURCE: self.arrivals}
        base_last = self.arrivals
        for stage in engine._topo:
            if stage == s:
                continue
            skey = self._stage_key(stage, pivot, None)
            ent = self._stage_cache.get(skey)
            if ent is None:
                ent = self._simulate_stage_entry(stage, pivot, None,
                                                 visited, completion)
                self._stage_cache[skey] = ent
                self._cache_bytes += ent.nbytes
                self.stats["stage_sims"] += 1
                while self._stage_cache and (
                        len(self._stage_cache) > self.max_cache_entries
                        or self._cache_bytes > self.max_cache_bytes):
                    _, old = self._stage_cache.popitem(last=False)
                    self._cache_bytes -= old.nbytes
            else:
                self._stage_cache.move_to_end(skey)
                self.stats["stage_hits"] += 1
            visited[stage] = ent.visited
            completion[stage] = ent.completion
            if ent.visited.any():
                base_last = np.where(
                    ent.visited, np.maximum(base_last, ent.completion),
                    base_last)
        vis, ready = self._stage_ready(s, visited, completion)
        k = int(vis.sum())
        if k < jax_backend._GRID_MIN_QUERIES:
            return None
        idx = np.nonzero(vis)[0]
        order = idx[np.argsort(ready[idx], kind="stable")]
        vals = jax_backend.grid_stage_percentiles(
            ready[order], order, base_last, self.arrivals,
            engine.rpc_delay_s, luts, effs, reps, touts, p)
        self.stats["full_sims"] += len(cands)
        self.stats["stage_sims"] += len(cands)
        for ck, v in zip(uncached, vals):
            self._pctl_cache[(self.backend, ck, p)] = float(v)
        while len(self._pctl_cache) > self._max_pctl_entries:
            self._pctl_cache.popitem(last=False)
        return [self.percentile(c, p) for c in configs]

    def percentile(self, config: PipelineConfig, p: float,
                   replica_schedules: Optional[Schedules] = None,
                   shed_schedules: Optional[ShedSchedules] = None) -> float:
        """Memoized latency percentile per full configuration (the scalar
        the planner's feasibility checks consume — subsumes the seed
        planner's whole-config ``_cache``)."""
        key = (self.backend,
               self.config_key(config, replica_schedules, shed_schedules), p)
        val = self._pctl_cache.get(key)
        if val is None:
            val = self.simulate(config, replica_schedules,
                                shed_schedules).percentile(p)
            self._pctl_cache[key] = val
            if len(self._pctl_cache) > self._max_pctl_entries:
                self._pctl_cache.popitem(last=False)
        else:
            self._pctl_cache.move_to_end(key)
        return val

    def class_percentile(self, config: PipelineConfig, p: float,
                         class_id: int,
                         replica_schedules: Optional[Schedules] = None
                         ) -> float:
        """Memoized latency percentile over one class's queries — the
        scalar the multi-class planner objective consumes. One cache miss
        simulates once and fills the entry for EVERY class (the planner
        always probes all classes per candidate), so the per-candidate
        cost stays one simulation regardless of class count. A class with
        no queries reports 0.0 (trivially feasible)."""
        if self.class_ids is None:
            raise ValueError("session has no class_ids; open the session "
                             "with class tags for per-class percentiles")
        cfg_key = (self.backend, self.config_key(config, replica_schedules))
        key = (cfg_key, p, ("class", int(class_id)))
        val = self._pctl_cache.get(key)
        if val is None:
            res = self.simulate(config, replica_schedules)
            for cid in np.unique(self.class_ids):
                sel = res.latency[self.class_ids == cid]
                v = float(np.percentile(sel, p)) if sel.size else 0.0
                self._pctl_cache[(cfg_key, p, ("class", int(cid)))] = v
            while len(self._pctl_cache) > self._max_pctl_entries:
                self._pctl_cache.popitem(last=False)
            val = self._pctl_cache.get(key)
            if val is None:          # class absent from the trace
                val = 0.0
                self._pctl_cache[key] = val
        else:
            self._pctl_cache.move_to_end(key)
        return val
