"""JAX (XLA) backend for the FIFO fill recurrence + device planner grids.

Two execution surfaces, both bit-identical to the numpy kernels in
:mod:`repro.sim.queueing` (float64 end to end; guarded by the parity
property suite in ``tests/test_jax_backend.py``):

* :func:`fifo_fill` — one stage's FIFO fill as a single ``jax.lax.scan``
  over batch boundaries, for static AND dynamic replica pools. The
  replica heap is carried as a *sorted buffer* (head = pool minimum;
  insertion is a compare-mask shift, no argmin/scatter), which is what
  makes the scan step cheap enough on CPU XLA — the heap's pop sequence
  depends only on the value multiset, so a sorted buffer with identical
  contents pops identical values and the outputs match the heap-driven
  numpy fill bit for bit.
* :func:`grid_stage_percentiles` — the accelerator-resident planner
  sweep: ``jax.vmap`` of the fill over a whole (hw, batch, replica,
  timeout) candidate grid (padded/masked per-candidate LUTs and replica
  pools), launched in ``REPRO_JAX_GRID_SEGMENTS`` segments so lanes
  that exhaust their queries early (large effective batches drain in
  ``k / batch`` steps) stop paying for the stragglers. Chunks are
  ordered by an expected-step-count heuristic so similarly-loaded lanes
  share a launch, and the cheap O(n) tail — batch expansion, scatter
  into arrival order, latency assembly, ``np.partition`` selection and
  the exact ``np.percentile`` lerp — runs on the host, where it is the
  *same* numpy ops the reference path uses (device sort/top_k of the
  full (C, n) latency block measured ~2x slower than the fills
  themselves on CPU XLA). :meth:`repro.sim.TraceSession.percentile_many`
  routes eligible candidate grids here when the session's ``backend``
  is ``"jax"``.

Float64 discipline: the repo's model/kernel stack runs jax in its f32
default; this module scopes ``jax.experimental.enable_x64`` around every
trace and call instead of flipping the global flag, so simulator math is
IEEE-double (matching numpy) without disturbing the model zoo.

Auto-selection: single fills fall back to numpy below
``REPRO_JAX_FILL_THRESHOLD`` queries. ``benchmarks/bench_planner_scale.py
--backend jax`` measures the crossover; on the 1-core CPU hosts this
repo targets the scan never beats the blocked numpy kernel for a
*single* fill (XLA's per-step dispatch is load-invariant but ~10x the
numpy per-batch cost), so the default threshold is effectively "off" and
the win comes from grid width — hundreds of candidates amortized into
one launch. Set the env var lower to force the scan (the parity suite
does), or if a real accelerator is attached.
"""

from __future__ import annotations

import functools
import math
import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

try:  # jax is an install-time dependency, but stay importable without it
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64

    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only on jax-less hosts
    jax = None
    _HAVE_JAX = False

_FAR_FUTURE = 1e18

# numpy-vs-jax crossover for a SINGLE fill (measured by
# bench_planner_scale --backend jax): on 1-core CPU hosts numpy wins at
# every trace length, so the default keeps single fills on numpy; the
# device path is for candidate GRIDS. Env-overridable for forcing.
_JAX_FILL_THRESHOLD = int(
    os.environ.get("REPRO_JAX_FILL_THRESHOLD", 1 << 62))
# device grid gating: fewer uncached candidates than this (or shorter
# fills) are cheaper through the host loop's shared caches
_GRID_MIN_CANDIDATES = int(os.environ.get("REPRO_JAX_GRID_MIN", 48))
_GRID_MIN_QUERIES = int(os.environ.get("REPRO_JAX_GRID_KMIN", 2048))
# candidates per compiled launch; grids pad up to a multiple so one
# grid shape compiles once per (k, Bmax, Rcap) bucket
_GRID_CHUNK = int(os.environ.get("REPRO_JAX_GRID_CHUNK", 256))
# the fill scan runs in ceil(k / _GRID_SEGMENTS)-step segments with a
# host early-exit between them: a lane forming full batches advances
# ~eff_batch queries per step, so backlogged chunks retire after k/b
# steps instead of burning the worst-case k (see grid_stage_percentiles)
_GRID_SEGMENTS = int(os.environ.get("REPRO_JAX_GRID_SEGMENTS", 8))


def available() -> bool:
    """True when jax is importable (the backend can be selected)."""
    return _HAVE_JAX


def _pow2_at_least(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


# ---------------------------------------------------------------------------
# static-pool fill: one lax.scan over batch boundaries
# ---------------------------------------------------------------------------


def _static_fill_core(k: int, L: int, Bmax: int, Rcap: int,
                      with_timeout: bool):
    """Fill SEGMENT for one (trace length, segment length, batch pad,
    pool pad) shape: ``L`` scan steps from an explicit ``(ptr, free)``
    carry, so callers can chain segments and stop as soon as every lane
    has consumed its queue (a lane forming full batches needs only
    ~k/eff_batch steps; the worst case — singleton batches — still
    terminates after k total).

    The scan step mirrors the scalar recurrence in
    ``_FifoFill.run_static`` exactly: pop the pool minimum, form the
    batch at ``start = max(head arrival, free)``, apply the optional
    formation-timeout hold, complete at ``start + lut[b]``, push the
    completion back. ``ready_pad`` carries ``Bmax`` trailing ``+inf``
    entries so the fill window never reads out of bounds; the
    ``ptr + idx < k`` mask keeps padding (and any ``+inf`` arrivals
    from upstream starvation) out of the batch count exactly like the
    numpy kernel's ``limit = min(ptr + B, k)`` bound. ``with_timeout``
    is a compile-time flag: the planner's hot grids are timeout-free,
    and dropping the hold branch removes a second windowed count and a
    gather from every step.
    """
    idx_b = jnp.arange(Bmax)
    idx_r = jnp.arange(Rcap)

    def fill_seg(ready_pad, lut, eff_b, timeout_s, ptr0, free0):
        def step(carry, _):
            ptr, free = carry
            active = ptr < k
            f = free[0]
            r0 = ready_pad[ptr]
            start0 = jnp.maximum(r0, f)
            window = lax.dynamic_slice(ready_pad, (ptr,), (Bmax,))
            in_b = (idx_b < eff_b) & (ptr + idx_b < k)
            b0 = jnp.sum((window <= start0) & in_b).astype(jnp.int64)
            if with_timeout:
                # formation timeout (beyond-paper hold): only a batch
                # that cannot fill right now waits, until it fills or
                # expires
                limit_b = jnp.minimum(eff_b, k - ptr)
                hold_until = r0 + timeout_s
                fill_idx = ptr + eff_b - 1
                fill_t = jnp.where(fill_idx < k, ready_pad[fill_idx],
                                   _FAR_FUTURE)
                start1 = jnp.minimum(jnp.maximum(start0, fill_t),
                                     hold_until)
                need_hold = ((timeout_s > 0.0) & (b0 < limit_b)
                             & (hold_until > start0))
                start = jnp.where(need_hold, start1, start0)
                b = jnp.where(
                    need_hold,
                    jnp.sum((window <= start1) & in_b).astype(jnp.int64),
                    b0)
            else:
                start, b = start0, b0
            end = start + lut[b]
            b_out = jnp.where(active, b, 0)
            # sorted-buffer heap replacement: drop the head, insert the
            # completion at its rank (value multiset == the numpy heap's
            # at every step, so pops — and therefore outputs — match)
            shifted = jnp.concatenate([free[1:], free[-1:]])
            p = (jnp.sum(free < end) - 1).astype(jnp.int64)
            newfree = jnp.where(idx_r < p, shifted,
                                jnp.where(idx_r == p, end, free))
            free = jnp.where(active, newfree, free)
            return (ptr + b_out, free), (end, b_out)

        (ptr1, free1), (ends, counts) = lax.scan(
            step, (ptr0, free0), None, length=L)
        return ptr1, free1, ends, counts

    return fill_seg


@functools.lru_cache(maxsize=64)
def _static_fill_fn(k: int, L: int, Bmax: int, Rcap: int,
                    with_timeout: bool):
    """Jitted single-lane fill segment (the whole fill when L == k)."""
    return jax.jit(_static_fill_core(k, L, Bmax, Rcap, with_timeout))


@functools.lru_cache(maxsize=32)
def _grid_seg_fn(k: int, L: int, Bmax: int, Rcap: int, with_timeout: bool):
    """Jitted vmapped fill segment: one launch advances a whole chunk of
    candidates by up to L batch formations; the trace is broadcast, every
    per-candidate input (LUT, batch, timeout, carry) is mapped."""
    core = _static_fill_core(k, L, Bmax, Rcap, with_timeout)
    return jax.jit(jax.vmap(core, in_axes=(None, 0, 0, 0, 0, 0)))


def _static_pool(replicas: int, Rcap: int) -> np.ndarray:
    free0 = np.full(Rcap, np.inf)
    free0[:replicas] = 0.0
    return free0


def fill_static(ready: np.ndarray, lut: np.ndarray, eff_batch: int,
                replicas: int, timeout_s: float
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Static-pool FIFO fill on device; (done, batch sizes) aligned like
    the numpy kernel's outputs. Caller guarantees k >= 1, replicas >= 1,
    and a non-negative LUT over [1, eff_batch]."""
    k = int(ready.shape[0])
    Bmax = _pow2_at_least(eff_batch)
    Rcap = _pow2_at_least(replicas)
    ready_pad = np.concatenate([ready, np.full(Bmax, np.inf)])
    lut_pad = np.zeros(Bmax + 1)
    lut_pad[:eff_batch + 1] = lut[:eff_batch + 1]
    with enable_x64():
        fn = _static_fill_fn(k, k, Bmax, Rcap, bool(timeout_s > 0.0))
        _, _, ends, counts = fn(
            jnp.asarray(ready_pad), jnp.asarray(lut_pad), eff_batch,
            float(timeout_s), jnp.zeros((), dtype=jnp.int64),
            jnp.asarray(_static_pool(replicas, Rcap)))
        ends = np.asarray(ends)
        counts = np.asarray(counts)
    done = np.repeat(ends, counts)        # sum(counts) == k exactly
    return done, counts[counts > 0]


# ---------------------------------------------------------------------------
# dynamic-pool fill: scan with in-step event application
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _dynamic_fill_fn(k: int, Bmax: int, Rcap: int, M: int, Mr: int, T: int):
    """Compiled dynamic-pool fill (``(t, +1/-1)`` replica scale events).

    Carries the sorted free buffer plus event cursors; each scan step is
    exactly one iteration of ``_FifoFill.run_dynamic``'s scalar loop:
    fast-forward to the next event when the pool is empty, or pop the
    minimum, apply events up to the dispatch instant, retire the popped
    replica if a scale-down is pending, else serve one batch. Removals
    retire in FIFO order of their event times (``rem_t``), matching
    ``ReplicaPool.pending_removals``. The trip count ``T`` upper-bounds
    serves + retires + fast-forwards + the starvation tail.
    """
    idx_b = jnp.arange(Bmax)
    idx_r = jnp.arange(Rcap)

    def insert_sorted(free, t):
        # shift-right insert at t's rank; the dropped tail slot is +inf
        # (the buffer is sized for the maximum possible pool)
        p = jnp.sum(free < t).astype(jnp.int64)
        shifted = jnp.concatenate([free[:1], free[:-1]])
        return jnp.where(idx_r < p, free,
                         jnp.where(idx_r == p, t, shifted))

    def fill_one(ready_pad, lut, eff_b, free0, n_free0, ev_t, ev_d, rem_t,
                 timeout_s):
        def apply_events(free, n_free, ev_i, rem_app, bound):
            # ReplicaPool.apply_events: push adds free at their t, queue
            # removals; the while_loop no-ops when bound precedes events
            def cond(s):
                _, _, i, _ = s
                return (i < M) & (ev_t[jnp.minimum(i, M - 1)] <= bound)

            def body(s):
                fr, nf, i, ra = s
                is_add = ev_d[i] > 0
                fr = jnp.where(is_add, insert_sorted(fr, ev_t[i]), fr)
                nf = nf + jnp.where(is_add, 1, 0)
                ra = ra + jnp.where(is_add, 0, 1)
                return fr, nf, i + 1, ra

            return lax.while_loop(cond, body, (free, n_free, ev_i, rem_app))

        def step(carry, _):
            ptr, free, n_free, ev_i, rem_app, rem_ret, starved = carry
            done_f = (ptr >= k) | starved
            empty = n_free == 0
            has_ev = ev_i < M
            is_ffwd = ~done_f & empty & has_ev
            is_starve = ~done_f & empty & ~has_ev
            is_pop = ~done_f & ~empty

            f = free[0]
            popped = jnp.concatenate([free[1:],
                                      jnp.full((1,), jnp.inf)])
            r0 = ready_pad[ptr]
            start = jnp.maximum(r0, f)
            # one bound drives all cases: the next event time for a
            # fast-forward, the dispatch instant for a serve, -inf
            # (no-op) otherwise
            bound = jnp.where(
                is_ffwd, ev_t[jnp.minimum(ev_i, M - 1)],
                jnp.where(is_pop, start, -jnp.inf))
            base_free = jnp.where(is_pop, popped, free)
            base_n = jnp.where(is_pop, n_free - 1, n_free)
            free2, n2, ev_i2, rem_app2 = apply_events(
                base_free, base_n, ev_i, rem_app, bound)

            pending = rem_ret < rem_app2
            retire = is_pop & pending & (
                rem_t[jnp.minimum(rem_ret, Mr - 1)] <= start)
            serve = is_pop & ~retire

            # batch formation (identical to the static step)
            window = lax.dynamic_slice(ready_pad, (ptr,), (Bmax,))
            in_b = (idx_b < eff_b) & (ptr + idx_b < k)
            b0 = jnp.sum((window <= start) & in_b).astype(jnp.int64)
            limit_b = jnp.minimum(eff_b, k - ptr)
            hold_until = r0 + timeout_s
            fill_idx = ptr + eff_b - 1
            fill_t = jnp.where(fill_idx < k, ready_pad[fill_idx],
                               _FAR_FUTURE)
            start1 = jnp.minimum(jnp.maximum(start, fill_t), hold_until)
            need_hold = ((timeout_s > 0.0) & (b0 < limit_b)
                         & (hold_until > start))
            bstart = jnp.where(need_hold, start1, start)
            b = jnp.where(
                need_hold,
                jnp.sum((window <= start1) & in_b).astype(jnp.int64), b0)
            end = bstart + lut[b]

            free3 = jnp.where(serve, insert_sorted(free2, end), free2)
            n3 = n2 + jnp.where(serve, 1, 0)
            cnt = jnp.where(serve, b, jnp.where(is_starve, k - ptr, 0))
            end_out = jnp.where(is_starve, _FAR_FUTURE, end)
            carry = (ptr + cnt, free3, n3, ev_i2, rem_app2,
                     rem_ret + jnp.where(retire, 1, 0),
                     starved | is_starve)
            return carry, (end_out, cnt, serve)

        init = (jnp.zeros((), dtype=jnp.int64), free0,
                n_free0.astype(jnp.int64), jnp.zeros((), dtype=jnp.int64),
                jnp.zeros((), dtype=jnp.int64),
                jnp.zeros((), dtype=jnp.int64), jnp.zeros((), dtype=bool))
        _, (ends, counts, is_batch) = lax.scan(step, init, None, length=T)
        done = jnp.repeat(ends, counts, total_repeat_length=k)
        return done, ends, counts, is_batch

    return jax.jit(fill_one)


def fill_dynamic(ready: np.ndarray, lut: np.ndarray, eff_batch: int,
                 replicas: int, replica_events: Sequence[Tuple[float, int]],
                 timeout_s: float) -> Tuple[np.ndarray, np.ndarray]:
    """Dynamic-pool FIFO fill on device (parity surface; the planner's
    hot grids are static-pool). Events are unit-expanded so each scan
    iteration applies at most one replica delta."""
    k = int(ready.shape[0])
    ev_t: List[float] = []
    ev_d: List[int] = []
    for t, d in replica_events:
        for _ in range(abs(int(d))):
            ev_t.append(float(t))
            ev_d.append(1 if d > 0 else -1)
    rem_t = [t for t, d in zip(ev_t, ev_d) if d < 0]
    M, Mr = len(ev_t), len(rem_t)
    adds = M - Mr
    Rcap = _pow2_at_least(max(replicas + adds, 1))
    Bmax = _pow2_at_least(eff_batch)
    T = k + M + Mr + 2
    ready_pad = np.concatenate([ready, np.full(Bmax, np.inf)])
    lut_pad = np.zeros(Bmax + 1)
    lut_pad[:eff_batch + 1] = lut[:eff_batch + 1]
    with enable_x64():
        fn = _dynamic_fill_fn(k, Bmax, Rcap, M, max(Mr, 1), T)
        done, ends, counts, is_batch = fn(
            jnp.asarray(ready_pad), jnp.asarray(lut_pad), eff_batch,
            jnp.asarray(_static_pool(replicas, Rcap)),
            jnp.asarray(np.int64(replicas)),
            jnp.asarray(np.asarray(ev_t if M else [0.0])),
            jnp.asarray(np.asarray(ev_d if M else [0], dtype=np.int64)),
            jnp.asarray(np.asarray(rem_t if Mr else [_FAR_FUTURE])),
            float(timeout_s))
        done = np.asarray(done)
        counts = np.asarray(counts)
        is_batch = np.asarray(is_batch)
    return done, counts[(counts > 0) & is_batch]


# ---------------------------------------------------------------------------
# the queueing-kernel entry point
# ---------------------------------------------------------------------------


def fifo_fill(ready: np.ndarray, latency_lut: np.ndarray, eff_batch: int,
              replicas: int,
              replica_events: Optional[Sequence[Tuple[float, int]]],
              timeout_s: float
              ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Device FIFO fill, or None when the numpy kernel should run
    instead (jax missing, fill below the crossover threshold, or a
    negative profiled latency — the sorted-buffer insert assumes
    completions never precede starts, like the numpy blocked kernel)."""
    if not _HAVE_JAX:
        return None
    k = int(ready.shape[0])
    if k < _JAX_FILL_THRESHOLD or k == 0:
        return None
    if float(np.min(latency_lut[1:eff_batch + 1])) < 0.0:
        return None
    if replica_events:
        return fill_dynamic(ready, latency_lut, eff_batch, replicas,
                            replica_events, timeout_s)
    if replicas <= 0:
        return None
    return fill_static(ready, latency_lut, eff_batch, replicas, timeout_s)


# ---------------------------------------------------------------------------
# exact np.percentile (linear interpolation) on device
# ---------------------------------------------------------------------------


def _quantile_params(n: int, p: float) -> Tuple[int, int, float]:
    """(prev_index, next_index, gamma) exactly as np.percentile computes
    them — same expression, same IEEE-754 doubles — so the device lerp
    reproduces the host value bit for bit."""
    # numpy's "linear" method computes the virtual index as
    # ``(n - 1) * q`` directly (NOT the generic alpha/beta formula, which
    # rounds differently in the last ulp — numpy's source carries a
    # comment to that effect).
    q = float(np.true_divide(p, 100))
    virt = (n - 1) * q
    if virt < 0.0:
        return 0, 0, 0.0
    if virt >= n - 1:
        return n - 1, n - 1, 0.0
    prev = int(math.floor(virt))
    return prev, prev + 1, virt - prev


def _host_lerp(a: np.ndarray, b: np.ndarray, t: float) -> np.ndarray:
    """numpy's ``_lerp`` verbatim (the t >= 0.5 branch computes from b).

    Runs on HOST floats: XLA contracts ``a + diff * t`` into an FMA,
    which is one ulp off np.percentile — so the device computes only the
    sort + two order-statistic gathers and the final interpolation stays
    in IEEE-faithful host arithmetic."""
    diff = b - a
    res = a + diff * t
    if t >= 0.5:
        res = b - diff * (1.0 - t)
    return res


def percentile_1d(values: np.ndarray, p: float) -> float:
    """np.percentile(values, p) with the sort on device — bit-identical
    (parity-tested, including +inf/FAR_FUTURE tails)."""
    n = int(values.shape[0])
    if n == 0:
        return 0.0
    prev, nxt, gamma = _quantile_params(n, p)
    with enable_x64():
        s = jnp.sort(jnp.asarray(values))
        a, b = float(s[prev]), float(s[nxt])
    return float(_host_lerp(np.float64(a), np.float64(b), gamma))


# ---------------------------------------------------------------------------
# the vmapped (hw, batch, replica) candidate grid
# ---------------------------------------------------------------------------


def _expected_steps(k: float, lam: float, lut: np.ndarray, eff: int,
                    r: int) -> float:
    """Rough scan-step count for one lane: k / expected batch size.

    Expected fullness ~ arrivals per replica-service-time, capped at the
    effective batch. Heuristic only — used to group lanes whose fills
    retire after a similar number of steps so the segmented scan's
    early-exit actually fires (one underloaded singleton-batch lane
    would otherwise pin its whole chunk at the worst-case k steps)."""
    service = float(lut[eff])
    if service <= 0.0 or r <= 0:
        return k
    fullness = min(float(eff), max(1.0, lam * service / r))
    return k / fullness


def grid_stage_percentiles(
    sorted_ready: np.ndarray,
    order: np.ndarray,
    base_last: np.ndarray,
    arrivals: np.ndarray,
    rpc_delay_s: float,
    luts: Sequence[np.ndarray],
    eff_batches: Sequence[int],
    replicas: Sequence[int],
    timeouts: Sequence[float],
    p: float,
) -> np.ndarray:
    """Score a candidate grid that varies ONE sink stage, on device.

    ``sorted_ready``/``order`` are the varied stage's (fixed) input
    queue; ``base_last`` is the accumulated completion maximum over
    every *other* stage (they are candidate-invariant because the varied
    stage has no descendants). Per candidate: LUT, effective batch,
    replica count, formation timeout. Returns one ``np.percentile``-
    bit-identical latency percentile per candidate.

    Division of labor (1-core CPU measurements drove this split): the
    device runs ONLY the vmapped fill scan — in ceil(k/_GRID_SEGMENTS)-
    step segments, chunks ordered by expected step count, stopping as
    soon as every lane in a chunk has drained — while batch-boundary
    expansion, latency assembly, and the percentile *selection*
    (``np.partition``, O(n) vs a device sort's O(n log n)) run on host.
    Host assembly is also what makes bit-identity trivial here: it is
    numpy arithmetic, the same ops in the same order as the reference
    session path.
    """
    C = len(luts)
    k = int(sorted_ready.shape[0])
    n = int(arrivals.shape[0])
    Bmax = _pow2_at_least(max(eff_batches))
    Rcap = _pow2_at_least(max(replicas))
    prev, nxt, gamma = _quantile_params(n, p)
    ready_pad = np.concatenate([sorted_ready, np.full(Bmax, np.inf)])
    chunk = min(_GRID_CHUNK, max(_pow2_at_least(C) // 2, 32))
    L = max(1, -(-k // _GRID_SEGMENTS))
    luts_pad = np.zeros((C, Bmax + 1))
    for i, lut in enumerate(luts):
        e = int(eff_batches[i])
        luts_pad[i, :e + 1] = lut[:e + 1]
    eff_arr = np.asarray(eff_batches, dtype=np.int64)
    tmo_arr = np.asarray(timeouts, dtype=np.float64)
    free0 = np.full((C, Rcap), np.inf)
    for i, r in enumerate(replicas):
        free0[i, :int(r)] = 0.0
    span = float(sorted_ready[-1] - sorted_ready[0]) if k > 1 else 1.0
    lam = k / max(span, 1e-12)
    perm = np.argsort([
        _expected_steps(k, lam, luts_pad[i], int(eff_arr[i]),
                        int(replicas[i]))
        for i in range(C)
    ], kind="stable")
    out = np.empty(C)
    kth = (prev, nxt) if nxt > prev else (prev,)
    with enable_x64():
        ready_j = jnp.asarray(ready_pad)
        for s in range(0, C, chunk):
            lanes = perm[s:s + chunk]
            v = len(lanes)
            pad = chunk - v
            lu = np.pad(luts_pad[lanes], ((0, pad), (0, 0)))
            eb = np.pad(eff_arr[lanes], (0, pad), constant_values=1)
            tm = np.pad(tmo_arr[lanes], (0, pad))
            fr = np.pad(free0[lanes], ((0, pad), (0, 0)),
                        constant_values=np.inf)
            if pad:
                fr[v:, 0] = 0.0           # keep padded lanes well-formed
            fn = _grid_seg_fn(k, L, Bmax, Rcap,
                              bool(np.any(tm > 0.0)))
            ptr = np.zeros(chunk, dtype=np.int64)
            ptr[v:] = k                   # padded lanes start drained
            ptr_j = jnp.asarray(ptr)
            fr_j = jnp.asarray(fr)
            lu_j, eb_j, tm_j = (jnp.asarray(lu), jnp.asarray(eb),
                                jnp.asarray(tm))
            ends_parts, counts_parts = [], []
            while True:
                ptr_j, fr_j, ends, counts = fn(ready_j, lu_j, eb_j, tm_j,
                                               ptr_j, fr_j)
                ends_parts.append(np.asarray(ends))
                counts_parts.append(np.asarray(counts))
                if bool(np.all(np.asarray(ptr_j) >= k)):
                    break
            ends_all = np.concatenate(ends_parts, axis=1)
            counts_all = np.concatenate(counts_parts, axis=1)
            for j in range(v):
                done = np.repeat(ends_all[j], counts_all[j])
                comp = np.full(n, -np.inf)
                comp[order] = done
                last = np.maximum(base_last, comp)
                lat = last - arrivals + rpc_delay_s
                part = np.partition(lat, kth)
                out[lanes[j]] = _host_lerp(part[prev], part[nxt], gamma)
    return out
