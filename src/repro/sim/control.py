"""Closed-loop Tuner x SimEngine co-simulation (epoch stepping).

The paper's high-frequency Tuner (§5) is a pure function of ingress, so
the live-cluster path could precompute its whole scaling schedule before
simulating (``run_tuner_offline``). This module closes the loop instead:
the engine advances in fixed control epochs (default 1 s), samples
per-stage telemetry at each boundary (:class:`repro.sim.result.
EpochTelemetry` — queue depth, in-flight, windowed p99/miss/drop counts,
the observed ingress envelope), and a controller turns each record into
:class:`ControlEvent` s — replica scale-ups/downs and admission-control
(slo-drop shed-margin) changes — that land after an activation delay.

Epoch stepping rides the cone-memoized :class:`~repro.sim.engine.
TraceSession` rather than re-running a one-shot simulation per epoch:
each boundary replays the bound trace against the schedule accumulated
so far, which is a pure per-stage cache hit in every epoch where no new
event was issued and re-simulates only the touched stage's downstream
cone otherwise. Reading the boundary's telemetry off a full-trace replay
is *causal*: a control event decided now lands strictly later, and a
batch whose start time is at or before the boundary can never be altered
by pool/shed events after it — so the telemetry a controller saw mid-run
is bit-identical to what the final schedule's one-shot simulation shows,
and a run with no controller events IS the one-shot simulation
(golden-guarded in ``tests/test_sim_engine.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.control import (  # noqa: F401 — re-exported for compatibility
    ControlEvent,
    Controller,
    CostAccounting,
    NoOpController,
    ScheduleController,
    fold_control_event,
    integrate_cost,
    replica_cost_timeline,
)
from repro.core.envelope import IncrementalEnvelope
from repro.core.pipeline import Pipeline, PipelineConfig
from repro.core.profiler import ProfileStore
from repro.sim.engine import (
    DEFAULT_RPC_DELAY_S,
    SimEngine,
)
from repro.sim.result import EpochTelemetry, SimResult, StageTelemetry

# Activation delays are the CONTROLLER's concern: a controller stamps
# each event's t_effective itself (e.g. the Tuner's REPLICA_ACTIVATION_S
# for scale-ups); the loop driver only refuses acausal ones.
DEFAULT_EPOCH_S = 1.0


@dataclasses.dataclass
class ClosedLoopResult(CostAccounting):
    """Outcome of one closed-loop run: the per-query simulation under the
    controller's final schedule, plus the control-plane artifacts."""

    sim: SimResult
    slo: float
    telemetry: List[EpochTelemetry]
    events: List[ControlEvent]
    replica_schedules: Dict[str, List[Tuple[float, int]]]
    shed_schedules: Dict[str, List[Tuple[float, float]]]
    cost_times: np.ndarray
    cost_per_hr: np.ndarray
    replica_timeline: Dict[str, List[Tuple[float, int]]]
    policy_schedules: Dict[str, List[Tuple[float, str]]] = \
        dataclasses.field(default_factory=dict)

    @property
    def miss_rate(self) -> float:
        return self.sim.slo_miss_rate(self.slo)

    @property
    def attainment(self) -> float:
        return 1.0 - self.miss_rate

    def _cost_t_end_default(self) -> float:
        return float(self.sim.arrival.max()) if self.sim.arrival.size else 0.0


class ControlLoopSession:
    """Epoch-stepped co-simulation of one pipeline + one controller.

    ``run(arrivals, controller)`` advances the engine one control epoch
    at a time; the controller's ``step(EpochTelemetry) -> [ControlEvent]``
    is invoked at every boundary and its events are folded into the
    replica/shed schedules the remaining epochs (and the final result)
    simulate under.
    """

    def __init__(
        self,
        pipeline: Pipeline,
        profiles: ProfileStore,
        config: PipelineConfig,
        slo: float,
        epoch_s: float = DEFAULT_EPOCH_S,
        rpc_delay_s: float = DEFAULT_RPC_DELAY_S,
        seed: int = 0,
        engine: Optional[SimEngine] = None,
        envelope_max_window_s: float = 60.0,
    ):
        if epoch_s <= 0:
            raise ValueError(f"epoch_s must be positive, got {epoch_s}")
        self.pipeline = pipeline
        self.profiles = profiles
        self.config = config
        self.slo = slo
        self.epoch_s = float(epoch_s)
        self.engine = engine if engine is not None else SimEngine(
            pipeline, profiles, rpc_delay_s=rpc_delay_s, seed=seed)
        self.envelope_max_window_s = envelope_max_window_s
        # per-stage single-batch service latency: the in-flight bound
        self._batch_lat = {}
        for s in pipeline.stages:
            cfg = config[s]
            lut = self.engine.latency_lut(s, cfg.hardware, cfg.batch_size)
            self._batch_lat[s] = float(lut[min(cfg.batch_size,
                                               lut.shape[0] - 1)])

    # -- one epoch's telemetry --------------------------------------------
    def _telemetry(
        self,
        epoch: int,
        t0: float,
        t1: float,
        arr: np.ndarray,
        res: SimResult,
        states,
        sched: Dict[str, List[Tuple[float, int]]],
        env: IncrementalEnvelope,
        faults=None,
    ) -> EpochTelemetry:
        # the first epoch's window is closed at BOTH ends ([0, t1], not
        # (0, t1]) so an arrival at exactly t=0 is counted somewhere —
        # the per-epoch records must partition the run exactly
        t_lo = -np.inf if epoch == 1 else t0
        hi = int(np.searchsorted(arr, t1, side="right"))
        lo = 0 if epoch == 1 else int(np.searchsorted(arr, t0,
                                                      side="right"))
        prefix = arr[:hi]
        env.extend(arr[env.n:hi])
        deadline = arr + self.slo

        stages: Dict[str, StageTelemetry] = {}
        for s in self.engine._topo:
            st = states[s]
            vis = st.visited
            comp = st.completion
            fin = np.isfinite(comp) & vis
            arrived = int((vis & (st.ready > t_lo) & (st.ready <= t1)).sum())
            completed = int((fin & (comp > t_lo) & (comp <= t1)).sum())
            if st.dropped is not None:
                dmask = st.dropped
                dropped = int((dmask & (deadline > t_lo)
                               & (deadline <= t1)).sum())
            else:
                dmask = None
                dropped = 0
            # queued or in service: input ready, outcome still pending.
            # A shed query's shed instant isn't tracked per query; treat
            # it as queued until its deadline (slo-drop sheds at dequeue,
            # which its deadline bounds).
            backlog = vis & (st.ready <= t1) & (comp > t1)
            if dmask is not None:
                backlog &= ~(dmask & (deadline <= t1))
            in_flight = int((backlog & (comp <= t1 + self._batch_lat[s]))
                            .sum())
            replicas = self.config[s].replicas + sum(
                d for (t, d) in sched.get(s, ()) if t <= t1)
            # alive mirrors the live loop's fault_deltas accounting:
            # replica target minus crash losses observed by t1, floored
            # at 0 — a schedule can ask for more kills than exist, and
            # a negative value would read as the "untracked" sentinel
            sf = faults.stage(s) if faults else None
            alive = max(0, replicas - (sum(n for (t, n) in sf.crashes()
                                           if t <= t1) if sf else 0))
            stages[s] = StageTelemetry(
                stage=s, arrived=arrived, completed=completed,
                dropped=dropped, queue_depth=int(backlog.sum()),
                in_flight=in_flight, replicas=replicas, alive=alive)

        # pipeline-level windowed accounting (causal: completions and
        # deadline passages inside this window only — each missing query
        # is counted in exactly one epoch, the one its deadline ends in)
        comp_t = arr + res.latency       # +inf for shed queries
        fin = np.isfinite(comp_t)
        in_win = fin & (comp_t > t_lo) & (comp_t <= t1)
        completed = int(in_win.sum())
        ddl_in_win = (deadline > t_lo) & (deadline <= t1)
        missed = int((in_win & ddl_in_win & (res.latency > self.slo)).sum())
        overdue = int((ddl_in_win & ((~fin) | (comp_t > t1))).sum())
        if res.dropped is not None:
            drops = int((res.dropped & ddl_in_win).sum())
        else:
            drops = 0
        p99 = (float(np.percentile(res.latency[in_win], 99.0))
               if completed else float("nan"))
        return EpochTelemetry(
            epoch=epoch, t_start=t0, t_end=t1, ingress=hi - lo,
            ingress_prefix=prefix, observed_envelope=env.snapshot(),
            stages=stages, completed=completed, missed=missed,
            overdue=overdue, drops=drops, p99_s=p99)

    # -- the loop ----------------------------------------------------------
    def run(self, arrivals: np.ndarray, controller,
            t_end: Optional[float] = None,
            faults=None) -> ClosedLoopResult:
        arr = np.asarray(arrivals, dtype=np.float64)
        if arr.size > 1 and np.any(np.diff(arr) < 0):
            # the engine tolerates unsorted traces (it sorts per stage)
            # but every telemetry window here is a searchsorted slice
            raise ValueError("arrivals must be sorted ascending")
        t_stop = t_end if t_end is not None else (
            float(arr.max()) if arr.size else 0.0)
        session = self.engine.session(arr, slo_s=self.slo)
        sched: Dict[str, List[Tuple[float, int]]] = {
            s: [] for s in self.pipeline.stages}
        shed: Dict[str, List[Tuple[float, float]]] = {}
        pols: Dict[str, List[Tuple[float, str]]] = {}
        telemetry: List[EpochTelemetry] = []
        events: List[ControlEvent] = []
        env = IncrementalEnvelope(
            self.engine.service_time(self.config),
            self.envelope_max_window_s)

        epoch = 0
        t0 = 0.0
        t = self.epoch_s
        while t <= t_stop + 1e-9:
            epoch += 1
            res = session.simulate(self.config, sched, shed or None,
                                   pols or None, faults)
            states = session.stage_states(self.config, sched, shed or None,
                                          pols or None, faults)
            tele = self._telemetry(epoch, t0, t, arr, res, states, sched,
                                   env, faults)
            telemetry.append(tele)
            for ev in controller.step(tele) or ():
                # shared validation + schedule folding (repro.control):
                # the live loop driver enforces the identical contract
                fold_control_event(ev, self.pipeline.stages, t, sched,
                                   shed, pols)
                events.append(ev)
            t0 = t
            t += self.epoch_s

        res = session.simulate(self.config, sched, shed or None, pols or None,
                               faults)
        times, costs, timeline = replica_cost_timeline(
            self.pipeline, self.config, sched, t_stop)
        return ClosedLoopResult(
            sim=res, slo=self.slo, telemetry=telemetry, events=events,
            replica_schedules=sched, shed_schedules=shed,
            cost_times=times, cost_per_hr=costs,
            replica_timeline=timeline, policy_schedules=pols)
