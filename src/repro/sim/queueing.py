"""Per-stage queueing policies: one centralized batched queue, R servers.

Each policy simulates ONE stage — a single queue feeding ``replicas``
batch-servers whose batch latency is given by a lookup table — and is
selected per stage via ``StageConfig.policy``:

* ``fifo``      — the paper's greedy arrival-order batching, plus the
  beyond-paper batch-formation timeout (``StageConfig.timeout_s``). This
  is the seed estimator's exact semantics, bit-identical, but the fill
  loop is a *blocked, vectorized batch-boundary scan* (see below) so
  long stretches of steady-state traffic cost a handful of numpy calls,
  not one Python iteration per batch.
* ``edf``       — earliest-deadline-first: among the queries ready at
  dispatch time, serve the ``batch`` with the earliest deadlines.
  Deadline scheduling lets late-but-urgent queries (e.g. a query delayed
  on a slow sibling branch) jump the queue at join stages.
* ``slo-drop``  — FIFO with SLO-aware load shedding (admission control at
  dequeue): a query that can no longer meet its deadline even if served
  alone right now is dropped instead of poisoning the batch behind it.
  Dropped queries complete at ``+inf`` and are flagged in the returned
  drop mask.

Vectorized FIFO fill (EXPERIMENTS.md §Perf)
-------------------------------------------
The FIFO recurrence is sequential in general (each batch's start depends
on the replica freed by earlier batches), but almost every batch falls
into one of two regimes with closed vectorized forms:

* **underload** (a replica is free when the head-of-line query arrives):
  the batch start equals the head arrival, so batch boundaries are the
  run-length decomposition of tied ready times capped at the max batch —
  computable for a whole block with one ``np.repeat``/``arange``
  expansion. The replica pool never delays these batches; validity is
  checked per batch with an order-statistic count (``searchsorted`` +
  ``bincount`` + ``cumsum``) over the pool's free times and the block's
  own completions.
* **backlog with full batches** (every query of a max-size batch is
  already waiting when a replica frees): service times are all equal, so
  the pop sequence of the replica heap is the sorted merge of R
  arithmetic progressions — generated exactly with a per-lane
  ``np.cumsum`` (sequential adds, bit-identical to repeated scalar
  addition) and one ``argsort``.

Each block is evaluated optimistically and committed up to the first
batch that violates its regime; mixed stretches fall back to a scalar
burst with exponential backoff so churny stages never pay block setup
per batch. The scalar step itself is leaner than the seed loop: with no
timeout, batch boundaries come from a precomputed run-length table
instead of a per-query walk. All paths are bit-identical to the frozen
seed oracle (``repro.sim.golden``) — guarded by the golden-equivalence
suite and the kernel property tests.

All policies share the dynamic replica-pool semantics of the seed engine:
``replica_events`` is a sorted list of ``(t, +1/-1)`` scale events; ``+1``
adds a replica free at ``t``, ``-1`` retires the next replica to go idle
at/after ``t``.

Admission control (closed-loop Tuner, :mod:`repro.sim.control`): the
``slo-drop`` policy additionally accepts ``shed_events`` — a sorted list
of ``(t, margin_s)`` pairs defining a piecewise-constant shed margin
``m(t)``. A query is shed at dequeue iff
``deadline < batch_start + lut[1] + m(batch_start)``; the margin before
the first event is 0 (the policy's historical behavior), ``m > 0`` sheds
proactively (queries that would poison the batch behind them), and
``m = -inf`` disables shedding entirely. ``fifo`` and ``edf`` ignore
``shed_events``.

Defensive LUT clamp: the effective max batch is clamped to the profiled
range (``len(lut) - 1``), so a configured ``batch_size`` above the
profile's largest batch can never silently extrapolate a bogus latency
(the seed scaled ``lut[-1] * b / (len - 1)``, i.e. linear-through-origin,
which can be wildly wrong for constant-latency stages).

Policy core (:mod:`repro.core.policy`): the batch-formation *semantics*
— the scalar selection loops, the shed-margin schedule, the replica
pool — live in the runtime-agnostic policy core shared with the
wall-clock executor (:mod:`repro.serving.executor`); this module is the
simulator's optimized driver over those primitives. The core's scalar
reference simulator (:func:`repro.core.policy.simulate_stage_ref`) is
property-tested bit-identical to every policy here
(``tests/test_policy_core.py``) and carries the piecewise
policy-switching path (:func:`switched`).
"""

from __future__ import annotations

import heapq
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import (
    ReplicaPool as _ReplicaPool,
    ShedMarginSchedule,
    edf_select,
    effective_max_batch as _effective_max_batch,
    simulate_stage_ref,
    slo_drop_select,
)

_FAR_FUTURE = 1e18
_INF = float("inf")

# (completion times, batch sizes formed, dropped mask) — all aligned with
# the sorted `ready` input except `batches`, which is per batch formed.
StageOutcome = Tuple[np.ndarray, np.ndarray, np.ndarray]


# Linear walks beat np.searchsorted's per-call overhead for short fills;
# wide fills (large batches) cross over to the O(log k) boundary search.
_SCAN_CROSSOVER = 64

# Blocked-fill tuning: the attempt size doubles while blocks commit in
# full and halves when they come up short; a block that commits fewer
# than _MIN_COMMIT batches triggers a scalar burst whose length doubles
# on repeated failures (and halves again on success), so stages that
# interleave regimes every few batches converge to pure scalar stepping
# and never pay block setup per batch.
_BLOCK_MIN = 128
_BLOCK_MAX = 8192
_MIN_COMMIT = 96
_BURST_MIN = 64
_BURST_MAX = 8192
# below this many queries a fill never attempts blocks: numpy call
# overhead cannot amortize against the lean scalar loop on short fills
# (planner probe traces are ~10k queries; hour-scale traces are >100k).
# Tunable via env for machines whose crossover differs (the default is
# the measured crossover on the benchmark host, see EXPERIMENTS.md §Perf)
_BLOCK_THRESHOLD = int(os.environ.get("REPRO_BLOCK_FILL_THRESHOLD", 32768))


def fifo(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
    shed_events: Optional[Sequence[Tuple[float, float]]] = None,
    backend: str = "numpy",
) -> StageOutcome:
    """Arrival-order batching (the paper's policy). `deadline` and
    `shed_events` are ignored.

    Bit-identical to the seed estimator's ``_simulate_stage``; the fill
    runs through the blocked vectorized kernel (module docstring), or —
    with ``backend="jax"`` — through the ``lax.scan`` device kernel
    (:mod:`repro.sim.jax_backend`), which auto-falls-back to numpy for
    fills below its crossover threshold.
    """
    k = ready.shape[0]
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return np.empty(0, dtype=np.float64), np.zeros(0, dtype=np.int64), \
            dropped
    eff_batch = _effective_max_batch(latency_lut, max_batch)
    if backend == "jax":
        from repro.sim import jax_backend
        out = jax_backend.fifo_fill(ready, latency_lut, eff_batch,
                                    replicas, replica_events, timeout_s)
        if out is not None:
            done, batches = out
            return done, batches, dropped
    if not replica_events:
        if replicas <= 0:
            return (np.full(k, _FAR_FUTURE), np.zeros(0, dtype=np.int64),
                    dropped)
        if eff_batch == 1:
            done, batches = _fifo_batch1_static(ready, latency_lut,
                                                replicas)
            return done, batches, dropped
        pool = None
    else:
        pool = _ReplicaPool(replicas, replica_events)
    fill = _FifoFill(ready, latency_lut, eff_batch, timeout_s)
    if pool is None:
        done, batches = fill.run_static(replicas)
    else:
        done, batches = fill.run_dynamic(pool)
    return done, batches, dropped


def _fifo_batch1_static(ready: np.ndarray, latency_lut: np.ndarray,
                        replicas: int) -> Tuple[np.ndarray, np.ndarray]:
    """batch=1, fixed pool: the fill scan is vacuous (every batch is one
    query, so the timeout hold never applies) and the loop is a scalar
    recurrence. With R identical servers the replica-pool minimum at
    step i is exactly the completion of query i-R (services are equal,
    so completions leave the pool in insertion order): the heap reduces
    to ``done[i-R]``, bit-identical and allocation-free — cheaper per
    query than the blocked kernel's scalar step, and the planner's
    batch=1 probes are exactly this shape."""
    ready_l = ready.tolist()
    lat1 = latency_lut.tolist()[1]
    k = len(ready_l)
    ends: List[float] = []
    if replicas == 1:
        f = 0.0
        for r in ready_l:
            f = (r if r > f else f) + lat1
            ends.append(f)
    else:
        R = replicas
        for i, r in enumerate(ready_l):
            f = ends[i - R] if i >= R else 0.0
            ends.append((r if r > f else f) + lat1)
    return (np.asarray(ends, dtype=np.float64), np.ones(k, dtype=np.int64))


def _fill_boundary(ready: np.ndarray, ready_l: List[float],
                   ptr: int, limit: int, t: float) -> int:
    """First index in [ptr, limit) whose ready time exceeds `t`.

    `ready_l[ptr] <= t` always holds at call sites, so the right-bisection
    over the full array equals the seed's linear walk from `ptr`.
    """
    if limit - ptr <= _SCAN_CROSSOVER:
        hi = ptr + 1
        while hi < limit and ready_l[hi] <= t:
            hi += 1
        return hi
    hi = int(ready.searchsorted(t, side="right"))
    return hi if hi < limit else limit


class _FifoFill:
    """One FIFO fill: blocked vectorized fast paths + exact scalar steps.

    Completions are accumulated as run-length segments (a list of
    (batch-end, batch-size) array pairs) and materialized once at the
    end with ``np.repeat`` — identical to the seed's per-batch writes.
    """

    def __init__(self, ready: np.ndarray, latency_lut: np.ndarray,
                 eff_batch: int, timeout_s: float):
        self.ready = ready
        self.ready_l: List[float] = ready.tolist()
        self.lut = latency_lut
        self.lut_l: List[float] = latency_lut.tolist()
        self.B = eff_batch
        self.k = ready.shape[0]
        self.timeout_s = timeout_s
        self.ptr = 0
        self.block_batches = _BLOCK_MIN
        # (ends, counts) alternating scalar lists and committed block arrays
        self._seg_ends: List[np.ndarray] = []
        self._seg_counts: List[np.ndarray] = []
        self._sc_ends: List[float] = []
        self._sc_counts: List[int] = []
        # blocks assume completions never precede starts (lut >= 0); a
        # negative "latency" would break the order-statistic argument.
        # Short fills skip blocks outright (see _BLOCK_THRESHOLD).
        self._blocks_ok = (self.k >= _BLOCK_THRESHOLD
                           and min(self.lut_l[1:eff_batch + 1]) >= 0.0)
        self._runs_built = False
        self._nb_l: Optional[List[int]] = None

    # -- run-length precomputation ---------------------------------------
    def _build_runs(self) -> None:
        ready, k = self.ready, self.k
        newrun = np.empty(k, dtype=bool)
        newrun[0] = True
        np.not_equal(ready[1:], ready[:-1], out=newrun[1:])
        self._run_idx = np.cumsum(newrun) - 1
        self._run_starts = np.nonzero(newrun)[0]
        self._run_ends = np.append(self._run_starts[1:], k)
        self._runs_built = True

    def _nb(self) -> List[int]:
        """nb[p]: boundary of an underload batch headed at p (timeout=0) —
        min(p + B, end of p's tie run). One vectorized table replaces the
        seed's per-query fill walk in the scalar path."""
        if self._nb_l is None:
            if not self._runs_built:
                self._build_runs()
            nb = np.minimum(np.arange(self.k) + self.B,
                            self._run_ends[self._run_idx])
            self._nb_l = nb.tolist()
        return self._nb_l

    # -- segment bookkeeping ----------------------------------------------
    def _flush_scalar(self) -> None:
        if self._sc_ends:
            self._seg_ends.append(np.asarray(self._sc_ends, dtype=np.float64))
            self._seg_counts.append(
                np.asarray(self._sc_counts, dtype=np.int64))
            # clear in place: the drivers hold bound .append methods
            self._sc_ends.clear()
            self._sc_counts.clear()

    def _commit_block(self, ends: np.ndarray, counts: np.ndarray) -> None:
        self._flush_scalar()
        self._seg_ends.append(ends)
        self._seg_counts.append(counts)

    def _finish(self) -> Tuple[np.ndarray, np.ndarray]:
        self._flush_scalar()
        if not self._seg_ends:
            return (np.empty(0, dtype=np.float64),
                    np.zeros(0, dtype=np.int64))
        ends = (self._seg_ends[0] if len(self._seg_ends) == 1
                else np.concatenate(self._seg_ends))
        counts = (self._seg_counts[0] if len(self._seg_counts) == 1
                  else np.concatenate(self._seg_counts))
        return np.repeat(ends, counts), counts

    # -- vectorized blocks -------------------------------------------------
    def _under_block(self, free: List[float], t_gate: float) -> int:
        """Underload block: batches are tie runs of `ready` capped at B,
        started at their head arrival. Valid while the replica pool has a
        server free by each head arrival — checked en masse by counting,
        per batch j, pool free times and earlier block completions at or
        below the head arrival: the (j+1)-th smallest such value is the
        server that would be popped. Commits the valid prefix; returns
        the number of batches committed."""
        if not self._runs_built:
            self._build_runs()
        ptr, B = self.ptr, self.B
        cap = self.block_batches
        r0i = int(self._run_idx[ptr])
        nruns = self._run_starts.shape[0]
        # each run yields >= 1 batch, so `cap` runs suffice
        hi_run = min(r0i + cap, nruns)
        starts = self._run_starts[r0i:hi_run].copy()
        starts[0] = ptr
        rends = self._run_ends[r0i:hi_run]
        cnts = -((starts - rends) // B)          # ceil((end - start) / B)
        ccum = np.cumsum(cnts)
        need = int(np.searchsorted(ccum, cap, side="left")) + 1
        if need < starts.shape[0]:
            starts, rends = starts[:need], rends[:need]
            cnts, ccum = cnts[:need], ccum[:need]
        total = int(ccum[-1])
        # expand runs -> batch head positions and sizes
        offs = np.repeat(ccum - cnts, cnts)
        within = np.arange(total) - offs
        bs = np.repeat(starts, cnts) + B * within
        sizes = np.minimum(np.repeat(rends, cnts) - bs, B)
        if total > cap:
            bs, sizes = bs[:cap], sizes[:cap]
            total = cap
        r0v = self.ready[bs]
        ends = r0v + self.lut[sizes]
        # validity: batch j is served at its head arrival iff >= j+1 of
        # {pool free times} ∪ {block completions 0..j-1} are <= r0v[j]
        h = np.sort(np.asarray(free, dtype=np.float64))
        avail = np.searchsorted(h, r0v, side="right")
        t_m = np.searchsorted(r0v, ends, side="left")
        pos = np.maximum(t_m, np.arange(1, total + 1))
        np.minimum(pos, total, out=pos)
        avail += np.cumsum(np.bincount(pos, minlength=total + 1))[:total]
        valid = avail >= np.arange(1, total + 1)
        if t_gate != _INF:
            valid &= r0v < t_gate
        j = int(np.argmin(valid)) if not valid.all() else total
        if j == 0:
            return 0
        ends_c, sizes_c = ends[:j], sizes[:j]
        self._commit_block(ends_c, sizes_c)
        self.ptr = int(bs[j - 1]) + int(sizes_c[j - 1])
        merged = np.sort(np.concatenate([h, ends_c]))
        free[:] = merged[j:].tolist()            # sorted list is a heap
        return j

    def _over_block(self, free: List[float], t_gate: float) -> int:
        """Backlog block: consecutive full-size batches. All services
        equal lut[B], so the heap's pop sequence is the sorted merge of
        one arithmetic progression per server (exact via per-lane cumsum,
        which accumulates sequentially like the scalar loop). Valid while
        each batch's last query arrived by its server's free time."""
        ptr, B, k = self.ptr, self.B, self.k
        L = self.lut_l[B]
        if L <= 0.0:                  # degenerate: progressions collapse
            return 0
        total = min((k - ptr) // B, self.block_batches)
        if total <= 0:
            return 0
        R = len(free)
        nterms = (total + R - 1) // R + 2
        mat = np.empty((R, nterms), dtype=np.float64)
        mat[:, 0] = np.sort(np.asarray(free, dtype=np.float64))
        mat[:, 1:] = L
        np.cumsum(mat, axis=1, out=mat)
        flat = mat.ravel()
        order = np.argsort(flat, kind="stable")[:total]
        f = flat[order]
        # last query of batch j must be waiting when its server frees
        lasts = self.ready[ptr + B - 1: ptr + total * B: B]
        valid = lasts <= f
        # beyond min(lane tails) the merge may miss ungenerated elements
        valid &= f <= mat[:, -1].min()
        if t_gate != _INF:
            valid &= f < t_gate
        j = int(np.argmin(valid)) if not valid.all() else total
        if j == 0:
            return 0
        ends_c = f[:j] + L
        self._commit_block(ends_c, np.full(j, B, dtype=np.int64))
        self.ptr = ptr + j * B
        popped = np.bincount(order[:j] // nterms, minlength=R)
        exhausted = popped >= nterms              # == only; advance by +L
        lane_next = mat[np.arange(R), np.minimum(popped, nterms - 1)]
        lane_next = np.where(exhausted, lane_next + L, lane_next)
        free[:] = np.sort(lane_next).tolist()
        return j

    def _try_block(self, free: List[float], t_gate: float) -> int:
        """One block attempt; adapts the attempt size to the commit rate
        so steadily-committing fills grow their blocks and churny fills
        shrink them."""
        if not self._blocks_ok or not free:
            return 0
        if self.ready_l[self.ptr] >= free[0]:     # heap min: regime probe
            if self.timeout_s > 0.0:
                got = 0       # underload + timeout: holds alter boundaries
            else:
                got = self._under_block(free, t_gate)
        else:
            got = self._over_block(free, t_gate)
        if got >= self.block_batches:
            self.block_batches = min(self.block_batches * 2, _BLOCK_MAX)
        elif got < _MIN_COMMIT:
            # failed attempt: restart small so churny stretches pay the
            # cheapest possible setup on the next try
            self.block_batches = _BLOCK_MIN
        elif got < self.block_batches // 4:
            self.block_batches = max(self.block_batches // 2, _BLOCK_MIN)
        return got

    # -- drivers -----------------------------------------------------------
    def run_static(self, replicas: int) -> Tuple[np.ndarray, np.ndarray]:
        """Static replica pool (the planner's hot path). Scalar stepping
        is inlined with local bindings: per batch it is one heap pop, a
        boundary lookup (precomputed run table when there is no timeout),
        one add, and a heap push — the seed's per-query fill walk and all
        numpy scalar indexing are gone."""
        free = [0.0] * replicas
        heapq.heapify(free)
        pop, push = heapq.heappop, heapq.heappush
        ready, ready_l, lut_l = self.ready, self.ready_l, self.lut_l
        k, B = self.k, self.B
        timeout_s = self.timeout_s
        end_app = self._sc_ends.append
        cnt_app = self._sc_counts.append
        nb_l: Optional[List[int]] = None
        ptr = 0
        burst, backoff = 0, _BURST_MIN
        while ptr < k:
            if burst == 0:
                self.ptr = ptr
                got = self._try_block(free, _INF)
                ptr = self.ptr
                if got >= _MIN_COMMIT:
                    backoff = max(backoff // 2, _BURST_MIN)
                    continue
                burst = backoff
                backoff = min(backoff * 2, _BURST_MAX)
                if ptr >= k:
                    break
                if nb_l is None and timeout_s == 0.0:
                    nb_l = self._nb()
            f = pop(free)
            r0 = ready_l[ptr]
            if nb_l is not None and r0 >= f:
                # underload, no timeout: boundary from the run table; the
                # start value is r0 whether the seed's max picked r0
                # (r0 > f) or the tied f (r0 == f)
                hi = nb_l[ptr]
                b = hi - ptr
                end = r0 + lut_l[b]
            else:
                start = r0 if r0 > f else f
                full_limit = ptr + B
                limit = full_limit if full_limit < k else k
                hi = _fill_boundary(ready, ready_l, ptr, limit, start)
                if timeout_s > 0.0 and hi < limit:
                    # timeout batching (beyond-paper): hold the batch open
                    # until either max_batch queries are ready or
                    # `timeout_s` elapses from the head-of-line arrival
                    hold_until = r0 + timeout_s
                    if hold_until > start:
                        # a batch that can never fill waits out the timeout
                        fill_t = ready_l[full_limit - 1] \
                            if full_limit - 1 < k else _FAR_FUTURE
                        start = min(max(start, fill_t), hold_until)
                        hi = _fill_boundary(ready, ready_l, ptr, limit,
                                            start)
                b = hi - ptr
                end = start + lut_l[b]
            end_app(end)
            cnt_app(b)
            ptr = hi
            push(free, end)
            burst -= 1
        self.ptr = ptr
        return self._finish()

    def run_dynamic(self, pool: _ReplicaPool) -> Tuple[np.ndarray, np.ndarray]:
        ready, ready_l, lut_l = self.ready, self.ready_l, self.lut_l
        k, B = self.k, self.B
        starved = False
        burst, backoff = 0, _BURST_MIN
        while self.ptr < k:
            if not pool.free:
                if pool.has_future_adds():
                    pool.fast_forward()
                    continue
                self._sc_ends.append(_FAR_FUTURE)  # no capacity ever again
                self._sc_counts.append(k - self.ptr)
                starved = True
                break
            if burst == 0:
                # blocks must not cross a scale event or a pending
                # retirement — both mutate the pool mid-fill
                if not pool.pending_removals:
                    t_gate = (pool.events[pool.ev_i][0]
                              if pool.ev_i < len(pool.events) else _INF)
                    got = self._try_block(pool.free, t_gate)
                    if got >= _MIN_COMMIT:
                        backoff = max(backoff // 2, _BURST_MIN)
                        continue
                burst = backoff
                backoff = min(backoff * 2, _BURST_MAX)
                if self.ptr >= k:
                    break
            ptr = self.ptr
            f = heapq.heappop(pool.free)
            r0 = ready_l[ptr]
            start = r0 if r0 > f else f
            pool.apply_events(start)
            if pool.retire_if_pending(start):
                burst -= 1
                continue
            full_limit = ptr + B
            limit = full_limit if full_limit < k else k
            hi = _fill_boundary(ready, ready_l, ptr, limit, start)
            if self.timeout_s > 0.0 and hi < limit:
                hold_until = r0 + self.timeout_s
                if hold_until > start:
                    fill_t = ready_l[full_limit - 1] if full_limit - 1 < k \
                        else _FAR_FUTURE
                    start = min(max(start, fill_t), hold_until)
                    hi = _fill_boundary(ready, ready_l, ptr, limit, start)
            b = hi - ptr
            end = start + lut_l[b]
            self._sc_ends.append(end)
            self._sc_counts.append(b)
            self.ptr = hi
            heapq.heappush(pool.free, end)
            burst -= 1
        done, counts = self._finish()
        # the capacity-exhausted tail is a run, not a served batch
        return done, (counts[:-1] if starved else counts)


def edf(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
    shed_events: Optional[Sequence[Tuple[float, float]]] = None,
    backend: str = "numpy",
) -> StageOutcome:
    """Earliest-deadline-first batching. ``shed_events`` and ``backend``
    are ignored (the scalar deadline-heap loop has no device analogue).

    At each dispatch, the batch is the (up to) ``max_batch`` queries with
    the earliest deadlines among those ready. Without deadlines this
    degrades to ordering by ready time (= FIFO). ``timeout_s`` is ignored:
    EDF already trades head latency explicitly via the deadline order.

    The pending set is a (deadline, index) heap, so sustained backlog —
    exactly the regime EDF targets — costs O(n log n), not O(n^2). A
    popped entry that is not yet ready at this dispatch instant (possible
    because dispatch times are not monotone across replicas) is deferred
    and re-pushed; deferrals only arise after idle-jump admissions and
    stay rare.
    """
    k = ready.shape[0]
    done = np.full(k, _FAR_FUTURE, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return done, np.zeros(0, dtype=np.int64), dropped
    eff_batch = _effective_max_batch(latency_lut, max_batch)
    pool = _ReplicaPool(replicas, replica_events)
    batches: List[int] = []
    ready_l = ready.tolist()
    lut_l = latency_lut.tolist()
    key_l = deadline.tolist() if deadline is not None else ready_l

    pending: List[Tuple[float, int]] = []   # heap of (deadline, idx)
    ai = 0                         # next un-admitted index (ready-sorted)
    served = 0
    while served < k:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            break                   # unserved queries keep _FAR_FUTURE
        f = heapq.heappop(pool.free)
        start = f
        take: List[int] = []
        retired = False
        while True:
            if pool.events:
                pool.apply_events(start)
                if pool.retire_if_pending(start):
                    retired = True
                    break
            while ai < k and ready_l[ai] <= start:
                heapq.heappush(pending, (key_l[ai], ai))
                ai += 1
            take = edf_select(pending, ready_l, start, eff_batch)
            if take:
                break
            # nothing serviceable at `start`: the replica idles until the
            # earliest instant any unserved query becomes ready
            t_next = min((ready_l[i] for _, i in pending), default=np.inf)
            if ai < k and ready_l[ai] < t_next:
                t_next = ready_l[ai]
            start = t_next          # finite: served < k => queries remain
        if retired:
            continue
        b = len(take)
        end = start + lut_l[b]
        for i in take:
            done[i] = end
        batches.append(b)
        served += b
        heapq.heappush(pool.free, end)
    return done, np.asarray(batches, dtype=np.int64), dropped


def slo_drop(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
    shed_events: Optional[Sequence[Tuple[float, float]]] = None,
    backend: str = "numpy",
) -> StageOutcome:
    """FIFO with SLO-aware shedding at dequeue (admission control).

    When a batch is formed at time ``start``, any candidate query whose
    deadline cannot be met even by a batch-1 dispatch right now
    (``deadline < start + lut[1] + m(start)``) is dropped rather than
    served: it completes at ``+inf`` and is flagged in the drop mask.
    Under overload this keeps the queue from collapsing — the paper's
    feasibility-only planner has no answer once the offered load exceeds
    capacity. The shed margin ``m(t)`` defaults to 0 and is piecewise
    reprogrammable via ``shed_events`` (module docstring) — the
    closed-loop Tuner's admission-control knob.

    ``timeout_s`` is ignored (as in ``edf``) — holding a batch open is
    at odds with shedding already-late work — and it is ignored
    consistently whether or not deadlines are supplied, so a stage
    config means the same system with and without an ``slo_s``.
    Without deadlines there is nothing to shed against and the policy
    reduces to greedy-batching ``fifo``.

    Hot-loop engineering: like ``fifo``, all per-query numpy scalar
    indexing (``ready[ptr]``, ``deadline[i]``, the LUT) is hoisted to
    native lists — exact same IEEE-754 values, regression-tested against
    the original loop in ``tests/test_fill_kernel.py``.
    """
    if deadline is None:
        return fifo(ready, latency_lut, max_batch, replicas,
                    replica_events, timeout_s=0.0, backend=backend)
    k = ready.shape[0]
    done = np.empty(k, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return done, np.zeros(0, dtype=np.int64), dropped
    eff_batch = _effective_max_batch(latency_lut, max_batch)
    ready_l = ready.tolist()
    deadline_l = deadline.tolist()
    lut_l = latency_lut.tolist()
    solo_lat = lut_l[1]
    pool = _ReplicaPool(replicas, replica_events)
    batches: List[int] = []
    # piecewise-constant shed margin (policy core): batch starts are not
    # monotone under dynamic pools (a replica added at an earlier t can
    # pop below the previous start), so each batch bisects the schedule
    shed = ShedMarginSchedule(shed_events)

    ptr = 0
    while ptr < k:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            done[ptr:] = _FAR_FUTURE
            break
        f = heapq.heappop(pool.free)
        r0 = ready_l[ptr]
        start = r0 if r0 > f else f
        pool.apply_events(start)
        if pool.retire_if_pending(start):
            continue
        # form the batch in arrival order, shedding hopeless queries
        floor = start + solo_lat + shed.margin(start)
        take, shed_idx, ptr = slo_drop_select(
            ready_l, deadline_l, None, ptr, k, start, floor, eff_batch)
        for i in shed_idx:
            dropped[i] = True
            done[i] = np.inf
        if not take:                 # everything scanned was shed
            heapq.heappush(pool.free, f)
            continue
        b = len(take)
        end = start + lut_l[b]
        done[take] = end
        batches.append(b)
        heapq.heappush(pool.free, end)
    return done, np.asarray(batches, dtype=np.int64), dropped


PolicyFn = Callable[..., StageOutcome]

QUEUE_POLICIES: Dict[str, PolicyFn] = {
    "fifo": fifo,
    "edf": edf,
    "slo-drop": slo_drop,
}


def get_policy(name: str) -> PolicyFn:
    try:
        return QUEUE_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown queueing policy {name!r}; "
            f"have {sorted(QUEUE_POLICIES)}") from None


def simulate_stage(
    policy: str,
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
    shed_events: Optional[Sequence[Tuple[float, float]]] = None,
    policy_events: Optional[Sequence[Tuple[float, str]]] = None,
    backend: str = "numpy",
    fault_spec=None,
) -> StageOutcome:
    """Dispatch to a named policy. `ready` must be sorted ascending.

    A non-empty ``policy_events`` (sorted ``(t, policy_name)`` switch
    points) routes through :func:`switched` instead — the policy-core
    scalar path that re-evaluates the policy at every batch dispatch.

    ``backend`` selects the fill kernel implementation for policies that
    have one (currently ``fifo``): ``"numpy"`` (default) or ``"jax"``
    (:mod:`repro.sim.jax_backend`). Both are bit-identical; jax pays a
    per-shape compile, so it only wins on batched candidate grids — the
    engine routes those through ``grid_stage_percentiles`` directly.

    A non-empty ``fault_spec`` (:class:`repro.faults.schedule
    .StageFaults`) routes through the scalar fault-aware event loop
    (:func:`repro.faults.simstage.simulate_stage_faults`) which handles
    crashes/stragglers/transient errors plus retry/hedge recovery and
    folds ``policy_events`` itself; ``None`` or empty specs take the
    existing paths untouched (bit-identical no-fault guarantee).
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; "
                         f"have ('numpy', 'jax')")
    if fault_spec is not None and fault_spec.events:
        from repro.faults.simstage import simulate_stage_faults

        return simulate_stage_faults(
            policy, ready, latency_lut, max_batch, replicas,
            replica_events, timeout_s, deadline, shed_events,
            policy_events, fault_spec)
    if policy_events:
        return switched(ready, latency_lut, max_batch, replicas,
                        replica_events, timeout_s, deadline, shed_events,
                        policy, policy_events)
    return get_policy(policy)(ready, latency_lut, max_batch, replicas,
                              replica_events, timeout_s, deadline,
                              shed_events, backend=backend)


def switched(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
    shed_events: Optional[Sequence[Tuple[float, float]]] = None,
    policy: str = "fifo",
    policy_events: Optional[Sequence[Tuple[float, str]]] = None,
) -> StageOutcome:
    """Piecewise policy schedule: serve with ``policy`` until the first
    ``(t, name)`` switch event, re-evaluating the in-force policy at each
    batch's dispatch instant (see :class:`repro.core.policy
    .PolicySchedule`). With no switch events this is bit-identical to the
    dedicated policy (property-tested); the scalar policy-core stepping
    trades the vectorized FIFO fill for full mid-run reprogrammability —
    the closed-loop Tuner's schedulable fifo->edf control events
    (:mod:`repro.sim.control`) land here.
    """
    get_policy(policy)            # validate the base name eagerly
    return simulate_stage_ref(ready, latency_lut, max_batch, replicas,
                              replica_events, timeout_s, deadline,
                              shed_events, policy, policy_events)
