"""Per-stage queueing policies: one centralized batched queue, R servers.

Each policy simulates ONE stage — a single queue feeding ``replicas``
batch-servers whose batch latency is given by a lookup table — and is
selected per stage via ``StageConfig.policy``:

* ``fifo``      — the paper's greedy arrival-order batching, plus the
  beyond-paper batch-formation timeout (``StageConfig.timeout_s``). This
  is the seed estimator's exact semantics, bit-identical, but the inner
  per-query fill loop is replaced with a numpy batch-boundary scan
  (``np.searchsorted`` per batch) so cost scales with the number of
  batches formed, not queries scanned.
* ``edf``       — earliest-deadline-first: among the queries ready at
  dispatch time, serve the ``batch`` with the earliest deadlines.
  Deadline scheduling lets late-but-urgent queries (e.g. a query delayed
  on a slow sibling branch) jump the queue at join stages.
* ``slo-drop``  — FIFO with SLO-aware load shedding (admission control at
  dequeue): a query that can no longer meet its deadline even if served
  alone right now is dropped instead of poisoning the batch behind it.
  Dropped queries complete at ``+inf`` and are flagged in the returned
  drop mask.

All policies share the dynamic replica-pool semantics of the seed engine:
``replica_events`` is a sorted list of ``(t, +1/-1)`` scale events; ``+1``
adds a replica free at ``t``, ``-1`` retires the next replica to go idle
at/after ``t``.

Defensive LUT clamp: the effective max batch is clamped to the profiled
range (``len(lut) - 1``), so a configured ``batch_size`` above the
profile's largest batch can never silently extrapolate a bogus latency
(the seed scaled ``lut[-1] * b / (len - 1)``, i.e. linear-through-origin,
which can be wildly wrong for constant-latency stages).
"""

from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

_FAR_FUTURE = 1e18

# (completion times, batch sizes formed, dropped mask) — all aligned with
# the sorted `ready` input except `batches`, which is per batch formed.
StageOutcome = Tuple[np.ndarray, np.ndarray, np.ndarray]


# Linear walks beat np.searchsorted's per-call overhead for short fills;
# wide fills (large batches) cross over to the O(log k) boundary search.
_SCAN_CROSSOVER = 64


def _effective_max_batch(latency_lut: np.ndarray, max_batch: int) -> int:
    lat_len = int(latency_lut.shape[0])
    if lat_len < 2:
        raise ValueError(
            f"latency LUT must cover at least batch=1 (got {lat_len} entries)")
    return min(int(max_batch), lat_len - 1)


class _ReplicaPool:
    """Heap of replica free-times plus the (t, +/-1) dynamic scale events."""

    def __init__(self, replicas: int,
                 events: Optional[Sequence[Tuple[float, int]]]):
        self.free: List[float] = [0.0] * max(replicas, 0)
        heapq.heapify(self.free)
        self.events = list(events or [])
        self.ev_i = 0
        self.pending_removals: List[float] = []

    def apply_events(self, now: float) -> None:
        while self.ev_i < len(self.events) and self.events[self.ev_i][0] <= now:
            t, delta = self.events[self.ev_i]
            self.ev_i += 1
            if delta > 0:
                for _ in range(delta):
                    heapq.heappush(self.free, t)
            else:
                for _ in range(-delta):
                    self.pending_removals.append(t)

    def has_future_adds(self) -> bool:
        return self.ev_i < len(self.events)

    def fast_forward(self) -> None:
        self.apply_events(self.events[self.ev_i][0])

    def retire_if_pending(self, now: float) -> bool:
        """True if the just-popped replica is retired by a pending removal."""
        if self.pending_removals and self.pending_removals[0] <= now:
            self.pending_removals.pop(0)
            return True
        return False


def fifo(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
) -> StageOutcome:
    """Arrival-order batching (the paper's policy). `deadline` is ignored.

    Bit-identical to the seed estimator's ``_simulate_stage``. Hot-loop
    engineering (EXPERIMENTS.md §Perf): all per-query numpy scalar work
    is hoisted out of the loop — ready times and the LUT become native
    floats (exact same IEEE-754 values), batch boundaries come from an
    inline walk or an ``np.searchsorted`` scan past the crossover, and
    per-query completions are materialized with one ``np.repeat`` over
    the (batch end, batch size) run-lengths instead of a slice write per
    batch. Static schedules (no replica events) take a specialized path;
    batch=1 stages reduce to a pure scalar recurrence.
    """
    k = ready.shape[0]
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return np.empty(0, dtype=np.float64), np.zeros(0, dtype=np.int64), \
            dropped
    eff_batch = _effective_max_batch(latency_lut, max_batch)
    ready_l = ready.tolist()
    lut_l = latency_lut.tolist()
    if not replica_events:
        done, batches = _fifo_static(ready, ready_l, lut_l, eff_batch,
                                     replicas, timeout_s)
    else:
        done, batches = _fifo_dynamic(ready, ready_l, lut_l, eff_batch,
                                      replicas, replica_events, timeout_s)
    return done, batches, dropped


def _fill_boundary(ready: np.ndarray, ready_l: List[float],
                   ptr: int, limit: int, t: float) -> int:
    """First index in [ptr, limit) whose ready time exceeds `t`.

    `ready_l[ptr] <= t` always holds at call sites, so the right-bisection
    over the full array equals the seed's linear walk from `ptr`.
    """
    if limit - ptr <= _SCAN_CROSSOVER:
        hi = ptr + 1
        while hi < limit and ready_l[hi] <= t:
            hi += 1
        return hi
    hi = int(ready.searchsorted(t, side="right"))
    return hi if hi < limit else limit


def _fifo_static(
    ready: np.ndarray,
    ready_l: List[float],
    lut_l: List[float],
    eff_batch: int,
    replicas: int,
    timeout_s: float,
) -> Tuple[np.ndarray, np.ndarray]:
    """FIFO with a fixed replica pool — the planner's hot path."""
    k = len(ready_l)
    if replicas <= 0:
        return np.full(k, _FAR_FUTURE), np.zeros(0, dtype=np.int64)

    if eff_batch == 1:
        # batch=1: the fill scan is vacuous (hi == ptr+1 always, so the
        # timeout hold never applies) and the loop is a scalar recurrence.
        # With R identical servers the replica-pool minimum at step i is
        # exactly the completion of query i-R (service times are equal,
        # so completions leave the pool in insertion order): the heap
        # reduces to `done[i-R]`, bit-identical and allocation-free.
        lat1 = lut_l[1]
        ends: List[float] = []
        if replicas == 1:
            f = 0.0
            for r in ready_l:
                f = (r if r > f else f) + lat1
                ends.append(f)
        else:
            R = replicas
            for i, r in enumerate(ready_l):
                f = ends[i - R] if i >= R else 0.0
                ends.append((r if r > f else f) + lat1)
        return (np.asarray(ends, dtype=np.float64),
                np.ones(k, dtype=np.int64))

    free = [0.0] * replicas
    heapq.heapify(free)
    pop, push = heapq.heappop, heapq.heappush
    ends, counts = [], []          # run-length encoded completions
    ptr = 0
    while ptr < k:
        f = pop(free)
        r0 = ready_l[ptr]
        start = r0 if r0 > f else f
        full_limit = ptr + eff_batch       # where a full batch would end
        limit = full_limit if full_limit < k else k
        hi = _fill_boundary(ready, ready_l, ptr, limit, start)
        if timeout_s > 0.0 and hi < limit:
            # timeout batching (beyond-paper): hold the batch open until
            # either max_batch queries are ready or `timeout_s` elapses
            # from the head-of-line query's arrival
            hold_until = r0 + timeout_s
            if hold_until > start:
                # a batch that can never fill waits out the full timeout
                fill_t = ready_l[full_limit - 1] if full_limit - 1 < k \
                    else _FAR_FUTURE
                start = min(max(start, fill_t), hold_until)
                hi = _fill_boundary(ready, ready_l, ptr, limit, start)
        b = hi - ptr
        ends.append(start + lut_l[b])
        counts.append(b)
        ptr = hi
        push(free, ends[-1])
    batches = np.asarray(counts, dtype=np.int64)
    done = np.repeat(np.asarray(ends, dtype=np.float64), batches)
    return done, batches


def _fifo_dynamic(
    ready: np.ndarray,
    ready_l: List[float],
    lut_l: List[float],
    eff_batch: int,
    replicas: int,
    replica_events: Sequence[Tuple[float, int]],
    timeout_s: float,
) -> Tuple[np.ndarray, np.ndarray]:
    """FIFO under a (t, +/-1) replica schedule (live-cluster runs)."""
    k = len(ready_l)
    pool = _ReplicaPool(replicas, replica_events)
    ends: List[float] = []
    counts: List[int] = []
    starved = False
    ptr = 0
    while ptr < k:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            ends.append(_FAR_FUTURE)       # no capacity ever again
            counts.append(k - ptr)
            starved = True
            break
        f = heapq.heappop(pool.free)
        r0 = ready_l[ptr]
        start = r0 if r0 > f else f
        pool.apply_events(start)
        if pool.retire_if_pending(start):
            continue
        full_limit = ptr + eff_batch
        limit = full_limit if full_limit < k else k
        hi = _fill_boundary(ready, ready_l, ptr, limit, start)
        if timeout_s > 0.0 and hi < limit:
            hold_until = r0 + timeout_s
            if hold_until > start:
                fill_t = ready_l[full_limit - 1] if full_limit - 1 < k \
                    else _FAR_FUTURE
                start = min(max(start, fill_t), hold_until)
                hi = _fill_boundary(ready, ready_l, ptr, limit, start)
        b = hi - ptr
        ends.append(start + lut_l[b])
        counts.append(b)
        ptr = hi
        heapq.heappush(pool.free, ends[-1])
    run_lengths = np.asarray(counts, dtype=np.int64)
    done = np.repeat(np.asarray(ends, dtype=np.float64), run_lengths)
    # the capacity-exhausted tail is a run, not a served batch
    return done, (run_lengths[:-1] if starved else run_lengths)


def edf(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
) -> StageOutcome:
    """Earliest-deadline-first batching.

    At each dispatch, the batch is the (up to) ``max_batch`` queries with
    the earliest deadlines among those ready. Without deadlines this
    degrades to ordering by ready time (= FIFO). ``timeout_s`` is ignored:
    EDF already trades head latency explicitly via the deadline order.

    The pending set is a (deadline, index) heap, so sustained backlog —
    exactly the regime EDF targets — costs O(n log n), not O(n^2). A
    popped entry that is not yet ready at this dispatch instant (possible
    because dispatch times are not monotone across replicas) is deferred
    and re-pushed; deferrals only arise after idle-jump admissions and
    stay rare.
    """
    k = ready.shape[0]
    done = np.full(k, _FAR_FUTURE, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return done, np.zeros(0, dtype=np.int64), dropped
    eff_batch = _effective_max_batch(latency_lut, max_batch)
    pool = _ReplicaPool(replicas, replica_events)
    batches: List[int] = []
    ready_l = ready.tolist()
    lut_l = latency_lut.tolist()
    key_l = deadline.tolist() if deadline is not None else ready_l

    pending: List[Tuple[float, int]] = []   # heap of (deadline, idx)
    ai = 0                         # next un-admitted index (ready-sorted)
    served = 0
    while served < k:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            break                   # unserved queries keep _FAR_FUTURE
        f = heapq.heappop(pool.free)
        start = f
        take: List[int] = []
        retired = False
        while True:
            if pool.events:
                pool.apply_events(start)
                if pool.retire_if_pending(start):
                    retired = True
                    break
            while ai < k and ready_l[ai] <= start:
                heapq.heappush(pending, (key_l[ai], ai))
                ai += 1
            deferred: List[Tuple[float, int]] = []
            while pending and len(take) < eff_batch:
                item = heapq.heappop(pending)
                if ready_l[item[1]] <= start:
                    take.append(item[1])
                else:
                    deferred.append(item)
            for item in deferred:
                heapq.heappush(pending, item)
            if take:
                break
            # nothing serviceable at `start`: the replica idles until the
            # earliest instant any unserved query becomes ready
            t_next = min((ready_l[i] for _, i in pending), default=np.inf)
            if ai < k and ready_l[ai] < t_next:
                t_next = ready_l[ai]
            start = t_next          # finite: served < k => queries remain
        if retired:
            continue
        b = len(take)
        end = start + lut_l[b]
        for i in take:
            done[i] = end
        batches.append(b)
        served += b
        heapq.heappush(pool.free, end)
    return done, np.asarray(batches, dtype=np.int64), dropped


def slo_drop(
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
) -> StageOutcome:
    """FIFO with SLO-aware shedding at dequeue (admission control).

    When a batch is formed at time ``start``, any candidate query whose
    deadline cannot be met even by a batch-1 dispatch right now
    (``deadline < start + lut[1]``) is dropped rather than served: it
    completes at ``+inf`` and is flagged in the drop mask. Under overload
    this keeps the queue from collapsing — the paper's feasibility-only
    planner has no answer once the offered load exceeds capacity.

    ``timeout_s`` is ignored (as in ``edf``) — holding a batch open is
    at odds with shedding already-late work — and it is ignored
    consistently whether or not deadlines are supplied, so a stage
    config means the same system with and without an ``slo_s``.
    Without deadlines there is nothing to shed against and the policy
    reduces to greedy-batching ``fifo``.
    """
    if deadline is None:
        return fifo(ready, latency_lut, max_batch, replicas,
                    replica_events, timeout_s=0.0)
    k = ready.shape[0]
    done = np.empty(k, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return done, np.zeros(0, dtype=np.int64), dropped
    eff_batch = _effective_max_batch(latency_lut, max_batch)
    solo_lat = latency_lut[1]
    pool = _ReplicaPool(replicas, replica_events)
    batches: List[int] = []

    ptr = 0
    while ptr < k:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            done[ptr:] = _FAR_FUTURE
            break
        f = heapq.heappop(pool.free)
        r0 = ready[ptr]
        start = r0 if r0 > f else f
        pool.apply_events(start)
        if pool.retire_if_pending(start):
            continue
        # form the batch in arrival order, shedding hopeless queries
        take: List[int] = []
        i = ptr
        while i < k and ready[i] <= start and len(take) < eff_batch:
            if deadline[i] < start + solo_lat:
                dropped[i] = True
                done[i] = np.inf
            else:
                take.append(i)
            i += 1
        ptr = i
        if not take:                 # everything scanned was shed
            heapq.heappush(pool.free, f)
            continue
        b = len(take)
        end = start + latency_lut[b]
        done[take] = end
        batches.append(b)
        heapq.heappush(pool.free, end)
    return done, np.asarray(batches, dtype=np.int64), dropped


PolicyFn = Callable[..., StageOutcome]

QUEUE_POLICIES: Dict[str, PolicyFn] = {
    "fifo": fifo,
    "edf": edf,
    "slo-drop": slo_drop,
}


def get_policy(name: str) -> PolicyFn:
    try:
        return QUEUE_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown queueing policy {name!r}; "
            f"have {sorted(QUEUE_POLICIES)}") from None


def simulate_stage(
    policy: str,
    ready: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
    deadline: Optional[np.ndarray] = None,
) -> StageOutcome:
    """Dispatch to a named policy. `ready` must be sorted ascending."""
    return get_policy(policy)(ready, latency_lut, max_batch, replicas,
                              replica_events, timeout_s, deadline)
