"""Frozen seed simulator — the golden oracle for engine equivalence.

This module is a verbatim port of the pre-refactor estimator (the
per-stage heap loop with per-query Python fill scans, per-call routing
draws, and per-call LUT construction). It exists for two purposes only:

1. **Golden-equivalence tests** (``tests/test_sim_engine.py``): the
   unified engine must reproduce these per-query latencies *exactly*
   (bit-identical float64) on randomized DAG pipelines and traces.
2. **Speedup benchmarking** (``benchmarks/bench_engine.py``): the
   "before" column of ``BENCH_engine.json`` drives the planner through
   this implementation, so the recorded plan wall-clock improvement is
   measured against the real seed code path, not a strawman.

Do NOT route production consumers through this module, and do not
"improve" it — its value is that it never changes.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline import SOURCE, Pipeline, PipelineConfig
from repro.core.profiler import ProfileStore
from repro.sim.result import SimResult

GOLDEN_RPC_DELAY_S = 0.0005
_FAR_FUTURE = 1e18


def golden_simulate_stage(
    ready: np.ndarray,
    order: np.ndarray,
    latency_lut: np.ndarray,
    max_batch: int,
    replicas: int,
    replica_events: Optional[Sequence[Tuple[float, int]]] = None,
    timeout_s: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """The seed `_simulate_stage`, kept byte-for-byte in behavior."""
    k = ready.shape[0]
    done = np.empty(k, dtype=np.float64)
    batches: List[int] = []
    if k == 0:
        return done, np.zeros(0, dtype=np.int64)

    free: List[float] = [0.0] * max(replicas, 0)
    heapq.heapify(free)
    ev = list(replica_events or [])
    ev_i = 0
    pending_removals: List[float] = []

    def apply_events(now: float) -> None:
        nonlocal ev_i
        while ev_i < len(ev) and ev[ev_i][0] <= now:
            t, delta = ev[ev_i]
            ev_i += 1
            if delta > 0:
                for _ in range(delta):
                    heapq.heappush(free, t)
            else:
                for _ in range(-delta):
                    pending_removals.append(t)

    ptr = 0
    lat_len = latency_lut.shape[0]
    while ptr < k:
        if not free:
            if ev_i < len(ev):
                apply_events(ev[ev_i][0])
                continue
            done[ptr:] = _FAR_FUTURE
            break
        f = heapq.heappop(free)
        start = max(f, ready[ptr])
        apply_events(start)
        if pending_removals and pending_removals[0] <= start:
            pending_removals.pop(0)
            continue
        hi = ptr
        limit = ptr + max_batch
        while hi < k and hi < limit and ready[hi] <= start:
            hi += 1
        if hi == ptr:
            start = ready[ptr]
            while hi < k and hi < limit and ready[hi] <= start:
                hi += 1
        if timeout_s > 0.0 and hi < limit and hi < k:
            deadline = ready[ptr] + timeout_s
            if deadline > start:
                fill_t = ready[limit - 1] if limit - 1 < k else _FAR_FUTURE
                start = min(max(start, fill_t), deadline)
                while hi < k and hi < limit and ready[hi] <= start:
                    hi += 1
        b = hi - ptr
        lat = latency_lut[b] if b < lat_len else latency_lut[-1] * b / (lat_len - 1)
        end = start + lat
        done[ptr:hi] = end
        batches.append(b)
        ptr = hi
        heapq.heappush(free, end)

    completion = np.empty(k, dtype=np.float64)
    completion[:] = done
    return completion, np.asarray(batches, dtype=np.int64)


class GoldenEstimator:
    """The seed `Estimator` class, frozen (same constructor/API shape)."""

    def __init__(
        self,
        pipeline: Pipeline,
        profiles: ProfileStore,
        rpc_delay_s: float = GOLDEN_RPC_DELAY_S,
        seed: int = 0,
    ):
        self.pipeline = pipeline
        self.profiles = profiles
        self.rpc_delay_s = rpc_delay_s
        self.seed = seed
        self._topo = pipeline.toposort()
        self._edges_in: Dict[str, List] = {
            s: [e for e in pipeline.edges if e.dst == s] for s in self._topo
        }

    def _edge_draws(self, n: int) -> Dict[Tuple[str, str], np.ndarray]:
        rng = np.random.default_rng(self.seed)
        draws = {}
        for e in self.pipeline.edges:
            if e.probability >= 1.0:
                draws[(e.src, e.dst)] = np.ones(n, dtype=bool)
            else:
                draws[(e.src, e.dst)] = rng.random(n) < e.probability
        return draws

    def simulate(
        self,
        config: PipelineConfig,
        arrivals: np.ndarray,
        replica_schedules: Optional[Dict[str, Sequence[Tuple[float, int]]]] = None,
    ) -> SimResult:
        arrivals = np.asarray(arrivals, dtype=np.float64)
        n = arrivals.shape[0]
        draws = self._edge_draws(n)

        visited: Dict[str, np.ndarray] = {SOURCE: np.ones(n, dtype=bool)}
        completion: Dict[str, np.ndarray] = {SOURCE: arrivals}
        last_done = np.array(arrivals, copy=True)
        per_stage_batches: Dict[str, np.ndarray] = {}

        for stage in self._topo:
            vis = np.zeros(n, dtype=bool)
            ready = np.zeros(n, dtype=np.float64)
            for e in self._edges_in[stage]:
                active = visited[e.src] & draws[(e.src, e.dst)]
                deliver = completion[e.src] + self.rpc_delay_s
                ready = np.where(active, np.maximum(ready, deliver), ready)
                vis |= active
            visited[stage] = vis
            k = int(vis.sum())
            if k == 0:
                completion[stage] = np.full(n, -np.inf)
                per_stage_batches[stage] = np.zeros(0, dtype=np.int64)
                continue

            cfg = config[stage]
            prof = self.profiles.get(self.pipeline.stages[stage].model_id)
            lut = prof.latency_lut(cfg.hardware, cfg.batch_size)

            idx = np.nonzero(vis)[0]
            order = idx[np.argsort(ready[idx], kind="stable")]
            sorted_ready = ready[order]
            sched = (replica_schedules or {}).get(stage)
            comp_sorted, batches = golden_simulate_stage(
                sorted_ready, order, lut, cfg.batch_size, cfg.replicas,
                sched, timeout_s=getattr(cfg, "timeout_s", 0.0)
            )
            comp = np.full(n, -np.inf)
            comp[order] = comp_sorted
            completion[stage] = comp
            per_stage_batches[stage] = batches
            last_done = np.where(vis, np.maximum(last_done, comp), last_done)

        latency = last_done - arrivals + self.rpc_delay_s
        return SimResult(arrivals, latency, per_stage_batches)

    def estimate_p99(self, config: PipelineConfig, arrivals: np.ndarray) -> float:
        return self.simulate(config, arrivals).p99

    def service_time(self, config: PipelineConfig) -> float:
        total = 0.0
        path = self.pipeline.longest_path_stages()
        for stage in path:
            cfg = config[stage]
            prof = self.profiles.get(self.pipeline.stages[stage].model_id)
            total += prof.batch_latency(cfg.hardware, cfg.batch_size)
            total += self.rpc_delay_s
        return total + self.rpc_delay_s
