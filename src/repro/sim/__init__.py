"""repro.sim — the unified incremental discrete-event simulation core.

One engine for every consumer: the Estimator façade, the Planner and
AnnealedPlanner search loops, the live-cluster simulation, the
coarse-grained and DS2 baselines, and the benchmark drivers.

* :mod:`repro.sim.engine`   — SimEngine + TraceSession (incremental
  per-stage memoization, ``simulate_delta`` / ``simulate_many``,
  ``stage_states`` queue snapshots)
* :mod:`repro.sim.queueing` — pluggable per-stage policies: ``fifo``
  (paper + timeout batching), ``edf`` (deadline scheduling),
  ``slo-drop`` (SLO-aware load shedding w/ reprogrammable shed margin)
* :mod:`repro.sim.jax_backend` — accelerator-resident planner sweeps:
  a ``lax.scan`` port of the FIFO fill and a vmapped (hw, batch,
  replica) candidate grid, bit-identical to the numpy kernels. Opt in
  per session via ``SimEngine.session(..., backend="jax")`` (default
  ``"numpy"``); eligible ``percentile_many`` grids then score in one
  device launch, everything else falls back to numpy transparently.
* :mod:`repro.sim.result`   — per-query SimResult (+ dropped mask),
  per-epoch EpochTelemetry / StageTelemetry control records
* :mod:`repro.sim.control`  — closed-loop Tuner co-simulation: epoch
  stepping (ControlLoopSession), ControlEvent, replica cost timelines
* :mod:`repro.sim.golden`   — frozen seed implementation (equivalence
  oracle + benchmark baseline only)
"""

from repro.sim.control import (  # noqa: F401
    ClosedLoopResult,
    ControlEvent,
    ControlLoopSession,
    NoOpController,
    ScheduleController,
    replica_cost_timeline,
)
from repro.sim.engine import (  # noqa: F401
    DEFAULT_RPC_DELAY_S,
    SimEngine,
    StageState,
    TraceSession,
)
from repro.sim.queueing import (  # noqa: F401
    QUEUE_POLICIES,
    get_policy,
    simulate_stage,
)
from repro.sim.result import (  # noqa: F401
    EpochTelemetry,
    SimResult,
    StageTelemetry,
)
