from repro.roofline.analysis import (  # noqa: F401
    RooflineReport,
    collective_bytes_from_hlo,
    roofline_terms,
)
