"""Roofline analysis over compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are NOT in cost_analysis, so we parse the optimized HLO text and sum
the output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op. Hardware constants (v5e): 197 bf16
TFLOP/s per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.hardware import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[256,4096,7168]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)"
                       r"\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\)\s*->.*)?\{\s*$")
_WHILE_RE = re.compile(
    r"=\s*(?:\([^=]*\)|\S+)\s+while\(.*?condition=%?([\w.\-]+),\s*"
    r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"\bs32\[\]\s+constant\((\d+)\)")


def _computations(hlo_text: str) -> Dict[str, str]:
    """Split an HLO module's text into {computation_name: body_text}.

    Lines outside any recognized computation land in the "" bucket so
    nothing is silently dropped (counted at multiplier 1).
    """
    comps: Dict[str, list] = {"": []}
    cur = ""
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m:
            cur = m.group(1)
            comps.setdefault(cur, [])
            continue
        if line.strip() == "}" and cur:
            cur = ""
            continue
        comps.setdefault(cur, []).append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _loop_multipliers(hlo_text: str) -> Dict[str, int]:
    """{body_computation_name: trip_count} for every while loop.

    jax.lax.scan lowers to while loops whose condition compares the
    induction variable against a constant trip count; we take the largest
    s32[] constant in the condition computation as the trip count. XLA's
    ``cost_analysis()`` counts each loop body ONCE (verified empirically:
    a scan of 10 matmuls reports 1 matmul of FLOPs), so collective bytes
    inside scanned layers must be multiplied back up.
    """
    comps = _computations(hlo_text)
    mult: Dict[str, int] = {}
    for m in _WHILE_RE.finditer(hlo_text):
        cond, body = m.group(1), m.group(2)
        trips = [int(c) for c in _CONST_RE.findall(comps.get(cond, ""))]
        if trips:
            mult[body] = max(mult.get(body, 1), max(trips))
    return mult


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind over an HLO module,
    multiplying collectives inside while-loop bodies (scanned layer
    stacks) by their trip counts.

    ``-start``/``-done`` pairs are counted once (the -done line repeats the
    shape); we count only lines without the ``-done`` suffix.
    """
    comps = _computations(hlo_text)
    mult = _loop_multipliers(hlo_text)
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}

    def scan_comp(name: str, text: str, factor: int) -> None:
        for line in text.splitlines():
            m = _OP_RE.match(line)
            if not m:
                continue
            if f"{m.group(2)}-done(" in line:
                continue
            out[m.group(2)] += _shape_bytes(m.group(1)) * factor

    # attribute each computation once, at its loop multiplier (nested
    # loops are rare in this codebase's programs; direct attribution)
    for name, text in comps.items():
        scan_comp(name, text, mult.get(name, 1))
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # PER-DEVICE values: compiled.cost_analysis() and compiled.as_text()
    # describe the SPMD-partitioned per-device program (verified: an 8-way
    # sharded matmul reports 1/8 of the total FLOPs). Each term therefore
    # divides by a SINGLE chip's peak, not by the chip count.
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives_by_kind: Dict[str, int]
    model_flops: float                    # TOTAL 6*N*D (train) / 2*N*D (serve)
    peak_mem_per_device: Optional[float] = None
    # Analytic per-device floors. XLA's cost_analysis counts while-loop
    # (scan) bodies ONCE, undercounting flops/bytes of scanned layer
    # stacks by ~num_layers; the floors (6*N*D napkin math and
    # params+optimizer+cache traffic) restore a sound lower bound. Terms
    # take max(measured, floor).
    analytic_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        floor = self.model_flops / self.chips
        return max(self.hlo_flops, floor) / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        floor = self.analytic_bytes or 0.0
        return max(self.hlo_bytes, floor) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def total_hlo_flops(self) -> float:
        return self.hlo_flops * self.chips

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — fraction of compiled compute
        that is 'useful'; catches remat/redundancy waste (can exceed 1 if
        XLA fuses/elides, <1 with remat recompute or attention FLOPs the
        6*N*D napkin model ignores)."""
        total = self.total_hlo_flops
        return self.model_flops / total if total else 0.0

    @property
    def step_time(self) -> float:
        """Roofline-optimistic step latency (max of the three terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_json(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "total_hlo_flops": self.total_hlo_flops,
            "collective_bytes": self.collective_bytes,
            "collectives_by_kind": self.collectives_by_kind,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_mem_per_device": self.peak_mem_per_device,
            "analytic_bytes": self.analytic_bytes,
        }


def model_flops_estimate(n_active: float, tokens: float,
                         kind: str) -> float:
    """6*N*D for training, 2*N*D for inference (per the assignment)."""
    return (6.0 if kind == "train" else 2.0) * n_active * tokens


def roofline_terms(arch: str, shape: str, mesh: str, chips: int,
                   cost_analysis: Dict, hlo_text: str,
                   model_flops: float,
                   peak_mem: Optional[float] = None,
                   analytic_bytes: Optional[float] = None) -> RooflineReport:
    coll = collective_bytes_from_hlo(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=float(cost_analysis.get("flops", 0.0)),
        hlo_bytes=float(cost_analysis.get("bytes accessed", 0.0)),
        collective_bytes=float(sum(coll.values())),
        collectives_by_kind=coll,
        model_flops=model_flops,
        peak_mem_per_device=peak_mem,
        analytic_bytes=analytic_bytes,
    )
