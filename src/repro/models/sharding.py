"""FSDP x TP partition specs for the model zoo.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod. Parameters are fully sharded (FSDP over the data axes + tensor
parallelism over `model` on the layer's natural parallel dimension:
attention heads, FFN hidden, experts, vocab). Divisibility is validated
per leaf; any non-divisible dim falls back to replication on that axis so
odd vocabularies (whisper's 51865) and tiny smoke configs still lower.

Rules are path-based (regex on the flattened param path, e.g.
``['segments'][0][0]['core']['wq']``); stacked segment leaves carry a
leading ``repeat`` axis which is always replicated (specs align to the
TRAILING dims, tolerating 0 or 1 leading axes).
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, spec). "fsdp" => mesh data axes; "model" => TP axis.
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / heads / modality projectors
    (r"\['embed'\]$", ("model", "fsdp")),
    (r"\['unembed'\]$", ("fsdp", "model")),
    (r"\['img_proj'\]$", (None, "fsdp")),
    (r"\['encoder'\]\['in_proj'\]$", (None, "fsdp")),
    # attention (3-D head-split weights) + biases
    (r"\['(?:core|cross)'\]\['wq'\]$", ("fsdp", "model", None)),
    (r"\['(?:core|cross)'\]\['w[kv]'\]$", ("fsdp", "model", None)),
    (r"\['(?:core|cross)'\]\['wo'\]$", ("model", None, "fsdp")),
    (r"\['b[qkv]'\]$", ("model", None)),
    # MLA
    (r"\['wq_a'\]$", ("fsdp", None)),
    (r"\['wq_b'\]$", ("fsdp", "model", None)),
    (r"\['wkv_a'\]$", ("fsdp", None)),
    (r"\['wkv_b_[kv]'\]$", (None, "model", None)),
    # MoE router
    (r"\['router'\]$", ("fsdp", None)),
    # mamba
    (r"\['core'\]\['in_proj'\]$", ("fsdp", "model")),
    (r"\['conv_w'\]$", (None, "model")),
    (r"\['w_bc'\]$", ("model", None)),
    (r"\['(?:w_dt|b_dt|d_skip)'\]$", ("model",)),
    (r"\['a_log'\]$", ("model", None)),
    (r"\['out_proj'\]$", ("model", "fsdp")),
    # mlstm
    (r"\['up'\]$", ("fsdp", "model")),
    (r"\['m[qkv]'\]$", ("fsdp", "model")),
    (r"\['w_[if]'\]$", ("model", None)),
    (r"\['b_[if]'\]$", ("model",)),
    (r"\['down'\]$", ("model", "fsdp")),
    # slstm: REPLICATED. The sLSTM recurrence is a 4096-step sequential
    # scan; TP-sharding r_h puts one small all-reduce inside every
    # timestep (measured: t_collective 1.06 s/step on xlstm-125m
    # train_4k — the dominant term). The weights are d_model^2-sized
    # (2.4 MB at d=768): replicating them deletes the per-step
    # collectives entirely (§Perf iteration 10).
    (r"\['(?:w_x|r_h)'\]$", (None, None)),
    (r"\['core'\]\['bias'\]$", (None,)),
    (r"\['core'\]\['proj'\]$", (None, None)),
    # heads
    (r"\['mtp'\]\['proj'\]$", ("fsdp", None)),
)

# dense-vs-MoE FFN weights share names under ['ffn']/['shared']; the MoE
# variants are one rank higher ((E, D, F) with experts over `model`).
_FFN_RE = re.compile(r"\['(?:ffn|shared)'\]\['w([gud])'\]$")
_FFN_DENSE = {"g": ("fsdp", "model"), "u": ("fsdp", "model"),
              "d": ("model", "fsdp")}
_FFN_MOE = {"g": ("model", "fsdp", None), "u": ("model", "fsdp", None),
            "d": ("model", "fsdp", None)}


def _path_str(path) -> str:
    return "".join(str(p) for p in path)


def _axes(mesh: Mesh) -> Tuple[Sequence[str], str]:
    names = mesh.axis_names
    model = "model"
    fsdp = tuple(n for n in names if n != model)
    return fsdp, model


def _resolve(spec: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: Mesh) -> P:
    """Align `spec` to the trailing dims of `shape` (0-1 leading repeat
    axes allowed) with per-dim divisibility fallbacks."""
    fsdp_axes, model_axis = _axes(mesh)
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= mesh.shape[a]
    model_size = mesh.shape[model_axis]

    n_lead = len(shape) - len(spec)
    if n_lead not in (0, 1):
        return P()
    out: list = [None] * n_lead
    for dim_size, s in zip(shape[n_lead:], spec):
        if s == "fsdp" and dim_size % fsdp_size == 0:
            out.append(fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0])
        elif s == "model" and dim_size % model_size == 0:
            out.append(model_axis)
        else:
            out.append(None)
    return P(*out)


def constrain_batch(x, extra=()):
    """Pin the leading (batch) dim of an activation to the ambient mesh's
    data axes; no-op outside a mesh context.

    WHY: FSDP shards weights over the same mesh axes as the batch. In an
    unconstrained module XLA's sharding propagation may resolve the
    (batch over data) x (weight-contraction over data) conflict by
    REPLICATING activations instead of all-gathering weights — observed as
    full-batch f32[256,4096,8192] FFN activations on every device in the
    llama3.2-1b train_4k dry-run. An explicit constraint on the residual
    stream forces the ZeRO-3 resolution (gather weights, keep activations
    sharded).

    `extra` optionally pins trailing dims (e.g. ("model",) for a
    vocab-sharded logits tensor).
    """
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names:
        return x
    fsdp_axes, _ = _axes(mesh)
    size = 1
    for a in fsdp_axes:
        size *= mesh.shape[a]
    if x.ndim < 1 or size <= 1 or x.shape[0] % size != 0:
        return x
    first = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    tail = list(extra) + [None] * (x.ndim - 1 - len(extra))
    for i, name in enumerate(tail):
        if name is not None and x.shape[1 + i] % mesh.shape[name] != 0:
            tail[i] = None
    return jax.lax.with_sharding_constraint(x, P(first, *tail))


def constrain_kv(x):
    """Pin one layer's KV-cache tensor (B, S, KV, hd) to the canonical
    cache sharding inside the decode/prefill computation; no-op outside a
    mesh context.

    Mirrors ``cache_pspec``: batch over data; KV heads over `model` when
    divisible, otherwise the SEQUENCE over `model`. Without this pin SPMD
    propagation inside the layer scan flips between seq-sharded (the
    cache argument) and head-sharded (what the attention einsum prefers),
    hitting XLA's "involuntary full rematerialization" path — a fully
    replicated cache copy per layer (observed on qwen2-72b decode_32k).
    """
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names or x.ndim != 4:
        return x
    fsdp_axes, model_axis = _axes(mesh)
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= mesh.shape[a]
    model_size = mesh.shape[model_axis]
    b, s, kv, hd = x.shape
    spec = [None, None, None, None]
    if fsdp_size > 1 and b % fsdp_size == 0:
        spec[0] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    if model_size > 1:
        if kv % model_size == 0:
            spec[2] = model_axis
        elif s % model_size == 0:
            spec[1] = model_axis
    return jax.lax.with_sharding_constraint(x, P(*spec))


def param_pspec(params: Any, mesh: Mesh) -> Any:
    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        m = _FFN_RE.search(ps)
        if m:
            which = m.group(1)
            # stacked MoE: (repeat,E,D,F)=4; unstacked MoE: 3 with experts
            # -- distinguish dense (<=3 with last-2 dims) by trying MoE
            # spec first when rank allows a valid alignment
            for spec in ((_FFN_MOE[which],) if len(shape) >= 3 else ()) + \
                    (_FFN_DENSE[which],):
                n_lead = len(shape) - len(spec)
                if n_lead in (0, 1):
                    # rank-3 could be stacked-dense or unstacked-moe; the
                    # shared expert and dense MLP are (D,F)-shaped on the
                    # trailing dims, experts are (E,D,F). Stacked dense has
                    # (repeat, D, F): middle dim == d_model distinguishes.
                    if len(spec) == 3 and len(shape) == 3 and \
                            "shared" in ps:
                        continue  # shared expert is dense-shaped
                    return _resolve(spec, shape, mesh)
            return P()
        for pat, spec in _RULES:
            if re.search(pat, ps):
                return _resolve(spec, shape, mesh)
        return P()  # replicated (norm scales, small vectors)

    return jax.tree_util.tree_map_with_path(assign, params)


def param_sharding(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspec(params, mesh),
        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(batch: Any, mesh: Mesh) -> Any:
    """Shard the batch dimension over the data axes when divisible."""
    fsdp_axes, _ = _axes(mesh)
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= mesh.shape[a]

    def assign(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % fsdp_size == 0:
            first = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
            return P(first, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map(assign, batch)


def cache_pspec(cache: Any, mesh: Mesh, shard_seq: bool = False) -> Any:
    """Decode-cache specs: batch over data axes; KV heads / latent dim /
    state channels over model where divisible. With ``shard_seq``
    (long_500k, batch=1) the cache *sequence* axis shards over the data
    axes instead — sequence-parallel attention over the long context."""
    fsdp_axes, model_axis = _axes(mesh)
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= mesh.shape[a]
    model_size = mesh.shape[model_axis]
    data_axes = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]

    def assign(path, leaf):
        shape = leaf.shape
        ps = _path_str(path)
        spec: list = [None] * len(shape)
        # leading repeat axis replicated; dim 1 is batch
        if len(shape) >= 2 and shape[1] % fsdp_size == 0 and not shard_seq:
            spec[1] = data_axes
        if re.search(r"\['(?:k|v|k_rope|c_kv)'\]$", ps) and len(shape) >= 4:
            # dense KV (rep,B,S,KV,hd) / MLA latent (rep,B,S,kr)
            if shard_seq and shape[2] % fsdp_size == 0:
                spec[2] = data_axes
            if shape[3] % model_size == 0:
                spec[3] = model_axis
            elif spec[2] is None and shape[2] % model_size == 0:
                # GQA caches whose KV heads don't divide the model axis
                # (qwen2 kv=8 on model=16: 1.37 TiB cache replicated
                # model-wise). Shard the SEQUENCE dim over `model`
                # instead — flash-decode style: each model shard holds a
                # context slice; softmax max/sum combine via the
                # reductions XLA already partializes.
                spec[2] = model_axis
        elif re.search(r"\['(?:h|conv|C|n)'\]$", ps) and len(shape) >= 3:
            # ssm/xlstm states: channel dim over model
            ch_dim = 2 if not re.search(r"\['conv'\]$", ps) else 3
            if ch_dim < len(shape) and shape[ch_dim] % model_size == 0:
                spec[ch_dim] = model_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, cache)
