from repro.models.config import ArchConfig, Block, Segment  # noqa: F401
from repro.models.model import Model, build_model  # noqa: F401
