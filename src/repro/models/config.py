"""Architecture configuration for the served/trained model zoo.

One ``ArchConfig`` describes any of the six assigned architecture families
(dense / moe / ssm / hybrid / enc-dec audio / vlm) as a sequence of
*segments*: a segment is a repeating pattern of blocks whose parameters
are stacked along a leading ``repeat`` axis and executed with
``jax.lax.scan`` (keeps the HLO small for 61-88-layer models).

Example patterns:
  dense llama:  [Segment((Block("attn","dense"),), repeat=16)]
  deepseek-v3:  [Segment((attn,"dense"), 3), Segment((attn,"moe"), 58)]
  jamba:        [Segment(8-block period {1 attn + 7 mamba, moe on odd}, 9)]
  xlstm:        [Segment((mlstm, mlstm, mlstm, slstm), 3)]
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

BLOCK_KINDS = ("attn", "mamba", "mlstm", "slstm")
FFN_KINDS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class Block:
    kind: str = "attn"        # one of BLOCK_KINDS
    ffn: str = "dense"        # one of FFN_KINDS

    def __post_init__(self):
        assert self.kind in BLOCK_KINDS, self.kind
        assert self.ffn in FFN_KINDS, self.ffn


@dataclasses.dataclass(frozen=True)
class Segment:
    blocks: Tuple[Block, ...]
    repeat: int

    @property
    def num_layers(self) -> int:
        return len(self.blocks) * self.repeat


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|encdec|vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Segment, ...]  # decoder stack
    head_dim: Optional[int] = None

    # -- attention flavor --------------------------------------------------
    qkv_bias: bool = False                 # qwen2
    rope_theta: float = 10000.0
    sliding_window: int = 0                # 0 = full attention
    use_mla: bool = False                  # deepseek-v3 MLA
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                      # expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_groups: int = 1                    # group-limited routing (EP)

    # -- SSM (mamba) / xLSTM ------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    xlstm_expand: int = 2
    ssm_chunk: int = 256                   # chunked-scan length

    # -- encoder-decoder (whisper) -------------------------------------------
    encoder_segments: Tuple[Segment, ...] = ()
    encoder_max_frames: int = 1500         # whisper 30 s @ 50 Hz

    # -- vlm ------------------------------------------------------------------
    num_image_tokens: int = 0              # stub patch-embedding count

    # -- activation -------------------------------------------------------------
    act: str = "swiglu"                    # "swiglu" | "gelu" (non-gated)

    # -- heads / training ------------------------------------------------------
    mtp_depth: int = 0                     # deepseek multi-token prediction
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # -- numerics ---------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False

    # ------------------------------------------------------------------ helpers
    @property
    def qk_head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.segments)

    @property
    def is_encoder_decoder(self) -> bool:
        return bool(self.encoder_segments)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: no full-attention block, or sliding window."""
        if self.sliding_window > 0:
            return True
        kinds = {
            b.kind for seg in self.segments for b in seg.blocks
        }
        if "attn" not in kinds:
            return True
        # hybrids keep attention but KV is O(window)=O(full) — attention KV
        # at 500k is fine when it is a small minority and batch==1; mark
        # hybrids as long-context capable per the assignment.
        attn_frac = sum(
            seg.repeat * sum(1 for b in seg.blocks if b.kind == "attn")
            for seg in self.segments
        ) / max(self.num_layers, 1)
        return attn_frac <= 0.25

    # ---------------------------------------------------------- param counts
    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            h = self.num_heads
            qk = self.qk_nope_head_dim + self.qk_rope_head_dim
            return (d * self.q_lora_rank
                    + self.q_lora_rank * h * qk
                    + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    + self.kv_lora_rank * h * (self.qk_nope_head_dim
                                               + self.v_head_dim)
                    + h * self.v_head_dim * d)
        hd = self.resolved_head_dim
        return (d * self.num_heads * hd          # q
                + 2 * d * self.num_kv_heads * hd  # k, v
                + self.num_heads * hd * d)        # o

    def _dense_ffn_params(self) -> int:
        mats = 3 if self.act == "swiglu" else 2  # gate/up/down vs up/down
        return mats * self.d_model * self.d_ff

    def _moe_ffn_params(self) -> int:
        per_expert = 3 * self.d_model * self.moe_d_ff  # experts stay gated
        return (self.num_experts * per_expert
                + self.num_shared_experts * per_expert
                + self.d_model * self.num_experts)  # router

    def _mamba_params(self) -> int:
        d_in = self.d_model * self.mamba_expand
        st = self.mamba_d_state
        return (self.d_model * 2 * d_in           # in_proj
                + d_in * self.mamba_d_conv        # conv
                + d_in * (st * 2 + 1) + d_in * st  # x->B,C,dt; A
                + d_in * self.d_model)            # out_proj

    def _xlstm_params(self, kind: str) -> int:
        d_in = self.d_model * self.xlstm_expand
        if kind == "mlstm":
            return (self.d_model * 2 * d_in       # up proj (x, gate)
                    + 3 * d_in * d_in             # q, k, v
                    + 2 * d_in                    # i, f gate biases-ish
                    + d_in * self.d_model)
        # slstm: 4 gates over d_model + ffn-ish projection
        return 4 * self.d_model * self.d_model * 2 + self.d_model * self.d_model

    def _block_params(self, b: Block) -> int:
        n = {"attn": self._attn_params(),
             "mamba": self._mamba_params(),
             "mlstm": self._xlstm_params("mlstm"),
             "slstm": self._xlstm_params("slstm")}[b.kind]
        if b.ffn == "dense":
            n += self._dense_ffn_params()
        elif b.ffn == "moe":
            n += self._moe_ffn_params()
        return n + 2 * self.d_model  # norms

    def param_count(self) -> int:
        """Total parameters (embeddings + stacks + head)."""
        n = self.vocab_size * self.d_model       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # unembedding
        for seg in self.segments:
            n += seg.repeat * sum(self._block_params(b) for b in seg.blocks)
        for seg in self.encoder_segments:
            n += seg.repeat * sum(self._block_params(b) for b in seg.blocks)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.num_experts == 0:
            return self.param_count()
        n = self.param_count()
        per_expert = 3 * self.d_model * self.moe_d_ff
        moe_blocks = sum(
            seg.repeat * sum(1 for b in seg.blocks if b.ffn == "moe")
            for seg in tuple(self.segments) + tuple(self.encoder_segments)
        )
        inactive = moe_blocks * (self.num_experts
                                 - self.num_experts_per_tok) * per_expert
        return n - inactive

    def flops_per_token(self, seq_len: int = 1) -> float:
        """~6*N_active per trained token; 2*N_active per inferred token,
        plus attention O(s*d) term. Used by the analytic profiler."""
        n = self.active_param_count()
        attn_layers = sum(
            seg.repeat * sum(1 for b in seg.blocks if b.kind == "attn")
            for seg in self.segments
        )
        window = self.sliding_window or seq_len
        attn = 2 * 2 * attn_layers * min(seq_len, window) * \
            self.num_heads * self.qk_head_dim
        return 2 * n + attn


def dense_segments(num_layers: int) -> Tuple[Segment, ...]:
    return (Segment((Block("attn", "dense"),), num_layers),)


def scale_down(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 layers,
    d_model<=512, <=4 experts)."""
    def shrink_segments(segs: Tuple[Segment, ...]) -> Tuple[Segment, ...]:
        if not segs:
            return segs
        out = []
        budget = 2  # at most 2 pattern units total
        for seg in segs:
            if budget <= 0:
                break
            out.append(Segment(seg.blocks, 1))
            budget -= 1
        return tuple(out)

    d_model = min(cfg.d_model, 256)
    num_heads = min(cfg.num_heads, 4)
    base = dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=max(1, min(cfg.num_kv_heads,
                                num_heads if cfg.num_kv_heads >= cfg.num_heads
                                else max(1, num_heads // 2))),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else cfg.d_ff,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=64 if cfg.head_dim else None,
        segments=shrink_segments(cfg.segments),
        encoder_segments=shrink_segments(cfg.encoder_segments),
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        moe_d_ff=min(cfg.moe_d_ff, 256) if cfg.moe_d_ff else cfg.moe_d_ff,
        q_lora_rank=64, kv_lora_rank=32,
        qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        num_image_tokens=min(cfg.num_image_tokens, 16),
        encoder_max_frames=min(cfg.encoder_max_frames, 64),
        mtp_depth=min(cfg.mtp_depth, 1),
        ssm_chunk=64,
    )
    return dataclasses.replace(base, **overrides)
