"""Model assembly: init / train forward / prefill / decode for all six
architecture families, driven entirely by ``ArchConfig``.

Layer stacks execute as ``jax.lax.scan`` over each segment's ``repeat``
axis (parameters and caches carry a leading repeat dim), which keeps the
HLO size independent of depth — essential for lowering 61-88 layer
configs quickly and for the multi-pod dry-run.

Entry points (all pure, jit-able):
  Model.init(key)                                    -> params
  Model.forward(params, batch)                       -> (logits, aux_loss)
  Model.loss(params, batch)                          -> scalar
  Model.prefill(params, batch, smax)                 -> (last_logits, cache)
  Model.decode_step(params, token, pos, cache)       -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ArchConfig, Block, Segment
from repro.models.kvcache import init_cache
from repro.models.sharding import constrain_batch

Params = Dict[str, Any]

AUDIO_FEAT_DIM = 128     # stub mel/conv frontend feature width
IMAGE_FEAT_DIM = 1024    # stub ViT patch-embedding width


def _cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Per-token CE that stays vocab-sharding-friendly.

    ``take_along_axis`` over a vocab-sharded logits tensor lowers to a
    gather across the sharded axis, which XLA resolves by replicating the
    full (B,S,V) logits on every device (observed: 101 GiB/device for
    whisper-small train_4k). The masked-sum form keeps every op either
    elementwise or a vocab-axis reduction — both shard cleanly (partial
    reduce + small all-reduce), so the logits stay model-sharded.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jnp.arange(lf.shape[-1], dtype=targets.dtype)
    tgt_logit = jnp.sum(
        jnp.where(iota[None, None, :] == targets[..., None], lf, 0.0),
        axis=-1)
    return lse - tgt_logit


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, block: Block,
                cross_attn: bool) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": L.init_rmsnorm(cfg)}
    if block.kind == "attn":
        p["core"] = L.init_mla(ks[0], cfg) if cfg.use_mla \
            else L.init_attention(ks[0], cfg)
    elif block.kind == "mamba":
        p["core"] = L.init_mamba(ks[0], cfg)
    elif block.kind == "mlstm":
        p["core"] = L.init_mlstm(ks[0], cfg)
    elif block.kind == "slstm":
        p["core"] = L.init_slstm(ks[0], cfg)
    if cross_attn and block.kind == "attn":
        p["norm_cross"] = L.init_rmsnorm(cfg)
        p["cross"] = L.init_cross_attention(ks[1], cfg)
    if block.ffn == "dense":
        p["norm2"] = L.init_rmsnorm(cfg)
        p["ffn"] = L.init_mlp(ks[2], cfg)
    elif block.ffn == "moe":
        p["norm2"] = L.init_rmsnorm(cfg)
        p["ffn"] = L.init_moe(ks[2], cfg)
    return p


def _apply_block(p: Params, cfg: ArchConfig, block: Block, x: jnp.ndarray,
                 positions: jnp.ndarray, mask: Optional[jnp.ndarray],
                 mask_kind: Optional[str],
                 cache: Optional[Params], cache_pos,
                 enc_out: Optional[jnp.ndarray],
                 cross_cache: Optional[Params],
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Params],
                            Optional[Params]]:
    """Returns (x, aux_loss, new_cache, new_cross_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(p["norm1"], cfg, x)
    new_cache = None
    # mask_kind describes the mask structurally ("causal"/"full"/None for
    # decode) so the attention path never materializes S^2 masks/scores.
    struct_kind = mask_kind if mask_kind in ("causal", "full") else None
    if block.kind == "attn":
        if cfg.use_mla:
            out, new_cache = L.mla_attention(p["core"], cfg, h, positions,
                                             mask, cache, cache_pos,
                                             kind=struct_kind)
        else:
            out, new_cache = L.attention(p["core"], cfg, h, positions, mask,
                                         cache=cache, cache_pos=cache_pos,
                                         kind=struct_kind)
    elif block.kind == "mamba":
        out, new_cache = L.mamba_block(p["core"], cfg, h, cache)
    elif block.kind == "mlstm":
        out, new_cache = L.mlstm_block(p["core"], cfg, h, cache)
    else:
        out, new_cache = L.slstm_block(p["core"], cfg, h, cache)
    x = x + out

    new_cross = None
    if "cross" in p:
        h = L.rmsnorm(p["norm_cross"], cfg, x)
        if enc_out is not None:
            out, _ = L.attention(p["cross"], cfg, h, positions, mask=None,
                                 kv_x=enc_out, use_rope=False, kind="full")
            if cross_cache is not None:
                # populate cross K/V once (prefill)
                ck = jnp.einsum("bsd,dhk->bshk", enc_out,
                                p["cross"]["wk"].astype(cfg.cdtype))
                cv = jnp.einsum("bsd,dhk->bshk", enc_out,
                                p["cross"]["wv"].astype(cfg.cdtype))
                new_cross = {"k": ck.astype(cross_cache["k"].dtype),
                             "v": cv.astype(cross_cache["v"].dtype)}
        else:
            # decode: attend over cached encoder K/V
            q = jnp.einsum("bsd,dhk->bshk", h,
                           p["cross"]["wq"].astype(cfg.cdtype))
            from repro.kernels import ops
            o = ops.attention(q, cross_cache["k"].astype(cfg.cdtype),
                              cross_cache["v"].astype(cfg.cdtype),
                              None, cfg.cdtype, kind="full")
            out = jnp.einsum("bshk,hkd->bsd", o,
                             p["cross"]["wo"].astype(cfg.cdtype))
            new_cross = cross_cache
        x = x + out

    if block.ffn == "dense":
        h = L.rmsnorm(p["norm2"], cfg, x)
        x = x + L.mlp(p["ffn"], cfg, h)
    elif block.ffn == "moe":
        h = L.rmsnorm(p["norm2"], cfg, x)
        out, aux = L.moe(p["ffn"], cfg, h)
        x = x + out
    return x, aux, new_cache, new_cross


# ---------------------------------------------------------------------------
# segment execution (scan over repeats)
# ---------------------------------------------------------------------------

def _init_segment(key, cfg: ArchConfig, seg: Segment,
                  cross_attn: bool) -> Tuple[Params, ...]:
    out = []
    for bi, block in enumerate(seg.blocks):
        keys = jax.random.split(jax.random.fold_in(key, bi), seg.repeat)
        stacked = jax.vmap(
            lambda k, blk=block: _init_block(k, cfg, blk, cross_attn)
        )(keys)
        out.append(stacked)
    return tuple(out)


def _run_segment(params_stack, cfg: ArchConfig, seg: Segment, x,
                 positions, mask, mask_kind,
                 cache_stack=None, cache_pos=None,
                 enc_out=None, cross_stack=None):
    """Scan over the repeat axis. Returns (x, aux_sum, new_cache_stack,
    new_cross_stack)."""
    has_cache = cache_stack is not None
    has_cross = cross_stack is not None

    # enc-dec segments carry exactly one attention block per pattern unit
    # (whisper), so one cross K/V slot per repeat.
    if has_cross:
        n_attn = sum(1 for b in seg.blocks if b.kind == "attn")
        assert n_attn == 1, "enc-dec pattern must have exactly 1 attn block"

    def body(carry, xs):
        # re-pin the residual stream each layer: without this XLA may
        # resolve the FSDP weight/batch axis conflict by replicating
        # activations (see sharding.constrain_batch).
        h = constrain_batch(carry)
        idx = 0
        blk_params = xs[idx]; idx += 1
        blk_cache = (None,) * len(seg.blocks)
        if has_cache:
            blk_cache = xs[idx]; idx += 1
        cross_c = xs[idx] if has_cross else None
        aux_sum = jnp.zeros((), jnp.float32)
        new_caches = []
        new_cross = cross_c
        for bi, block in enumerate(seg.blocks):
            cc = cross_c if (has_cross and block.kind == "attn") else None
            h, aux, nc, ncross = _apply_block(
                blk_params[bi], cfg, block, h, positions, mask, mask_kind,
                blk_cache[bi], cache_pos, enc_out, cc)
            aux_sum = aux_sum + aux
            new_caches.append(nc if nc is not None else blk_cache[bi])
            if ncross is not None:
                new_cross = ncross
        outs = (aux_sum,)
        if has_cache:
            outs = outs + (tuple(new_caches),)
        if has_cross:
            outs = outs + (new_cross,)
        # Megatron-style sequence parallelism at the layer boundary: the
        # carried residual (== the activation the remat scan saves per
        # layer) is seq-sharded over `model`; XLA inserts the all-gather
        # at the next layer's entry. Shrinks the saved-activation stack
        # (and XLA's fp32-widened copy of it) by the model-axis size.
        # ONLY for attention-bearing segments: in pure SSM/xLSTM
        # segments the seq axis is reshaped into (chunks, chunk) for the
        # recurrent scan and XLA propagates the seq sharding onto the
        # chunk axis — an all-gather inside EVERY chunk step (measured:
        # 1.06 s/step of collectives on xlstm-125m train_4k).
        if any(blk.kind == "attn" for blk in seg.blocks):
            h = constrain_batch(h, ("model",))
        else:
            h = constrain_batch(h)
        return h, outs

    if cfg.remat:
        body = jax.checkpoint(body)

    xs = (params_stack,)
    if has_cache:
        xs = xs + (cache_stack,)
    if has_cross:
        xs = xs + (cross_stack,)
    x, ys = jax.lax.scan(body, x, xs)
    aux = ys[0].sum()
    new_cache = ys[1] if has_cache else None
    new_cross = ys[2] if has_cross else None
    return x, aux, new_cache, new_cross


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p: Params = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(cfg.pdtype),
            "final_norm": L.init_rmsnorm(cfg),
            "segments": tuple(
                _init_segment(jax.random.fold_in(ks[1], i), cfg, seg,
                              cross_attn=cfg.is_encoder_decoder)
                for i, seg in enumerate(cfg.segments)
            ),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = (jax.random.normal(
                ks[2], (cfg.d_model, cfg.vocab_size)) * 0.02).astype(
                    cfg.pdtype)
        if cfg.is_encoder_decoder:
            p["encoder"] = {
                "in_proj": (jax.random.normal(
                    ks[3], (AUDIO_FEAT_DIM, cfg.d_model)) * 0.05).astype(
                        cfg.pdtype),
                "segments": tuple(
                    _init_segment(jax.random.fold_in(ks[4], i), cfg, seg,
                                  cross_attn=False)
                    for i, seg in enumerate(cfg.encoder_segments)
                ),
                "final_norm": L.init_rmsnorm(cfg),
            }
        if cfg.num_image_tokens:
            p["img_proj"] = (jax.random.normal(
                ks[5], (IMAGE_FEAT_DIM, cfg.d_model)) * 0.05).astype(
                    cfg.pdtype)
        if cfg.mtp_depth:
            p["mtp"] = {
                "proj": (jax.random.normal(
                    ks[6], (2 * cfg.d_model, cfg.d_model)) * 0.02).astype(
                        cfg.pdtype),
                "block": _init_block(ks[7], cfg,
                                     Block("attn", "dense"), False),
                "norm": L.init_rmsnorm(cfg),
            }
        return p

    # ------------------------------------------------------------- embedding
    def _embed_inputs(self, params: Params, batch: Dict[str, jnp.ndarray]
                      ) -> Tuple[jnp.ndarray, int]:
        """Token (+modality stub) embedding. Returns (x, n_prefix) where
        n_prefix = number of non-text positions prepended (vlm)."""
        cfg = self.cfg
        x = params["embed"].astype(cfg.cdtype)[batch["tokens"]]
        n_prefix = 0
        if cfg.num_image_tokens and "image_feats" in batch:
            img = jnp.einsum("bnf,fd->bnd",
                             batch["image_feats"].astype(cfg.cdtype),
                             params["img_proj"].astype(cfg.cdtype))
            x = jnp.concatenate([img, x], axis=1)
            n_prefix = img.shape[1]
        return constrain_batch(x), n_prefix

    def _encode(self, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        """Whisper-style encoder over stub frame features (B,F,feat)."""
        cfg = self.cfg
        enc = params["encoder"]
        x = jnp.einsum("bfe,ed->bfd", frames.astype(cfg.cdtype),
                       enc["in_proj"].astype(cfg.cdtype))
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(
            cfg.cdtype)
        x = constrain_batch(x)
        positions = jnp.arange(x.shape[1])[None, :]
        for seg, ps in zip(cfg.encoder_segments, enc["segments"]):
            x, _, _, _ = _run_segment(ps, cfg, seg, x, positions,
                                      mask=None, mask_kind="full")
        return L.rmsnorm(enc["final_norm"], cfg, x)

    def _head(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = L.rmsnorm(params["final_norm"], cfg, x)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x,
                            w.astype(cfg.cdtype)).astype(jnp.float32)
        # keep logits vocab-sharded over `model`; the CE formulation in
        # `_cross_entropy` never gathers them.
        return constrain_batch(logits, (None, "model"))

    # --------------------------------------------------------------- forward
    def forward(self, params: Params, batch: Dict[str, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Training/scoring forward. Returns (logits(B,S,V) fp32, aux)."""
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)[None, :]
        mask = None   # structural "causal" kind; never materialized
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self._encode(params, batch["frames"])
        aux_total = jnp.zeros((), jnp.float32)
        for seg, ps in zip(cfg.segments, params["segments"]):
            x, aux, _, _ = _run_segment(ps, cfg, seg, x, positions, mask,
                                        "causal", enc_out=enc_out)
            aux_total = aux_total + aux
        logits = self._head(params, x)
        if n_prefix:
            logits = logits[:, n_prefix:]
            x = x[:, n_prefix:]
        if cfg.mtp_depth and batch.get("enable_mtp", True) is not False:
            aux_total = aux_total + self._mtp_loss(params, x, batch["tokens"])
        return logits, aux_total

    def _mtp_loss(self, params: Params, h: jnp.ndarray,
                  tokens: jnp.ndarray) -> jnp.ndarray:
        """DeepSeek-V3 multi-token prediction (depth 1): from h_i and
        emb(t_{i+1}) predict t_{i+2}; weighted auxiliary CE."""
        cfg = self.cfg
        if tokens.shape[1] < 3:
            return jnp.zeros((), jnp.float32)
        emb_next = params["embed"].astype(cfg.cdtype)[tokens[:, 1:]]
        hcat = jnp.concatenate([h[:, :-1], emb_next], axis=-1)
        x = jnp.einsum("bsd,de->bse", hcat,
                       params["mtp"]["proj"].astype(cfg.cdtype))
        s = x.shape[1]
        positions = jnp.arange(s)[None, :]
        x, _, _, _ = _apply_block(params["mtp"]["block"], cfg,
                                  Block("attn", "dense"), x, positions, None,
                                  "causal", None, None, None, None)
        x = L.rmsnorm(params["mtp"]["norm"], cfg, x)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x,
                            w.astype(cfg.cdtype)).astype(jnp.float32)
        targets = tokens[:, 2:]
        ce = _cross_entropy(logits[:, :-1], targets).mean()
        return 0.1 * ce

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]
             ) -> jnp.ndarray:
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        ce = _cross_entropy(logits[:, :-1], tokens[:, 1:])
        if "loss_mask" in batch:
            m = batch["loss_mask"][:, 1:].astype(jnp.float32)
            ce = (ce * m).sum() / jnp.clip(m.sum(), 1.0)
        else:
            ce = ce.mean()
        return ce + aux

    # --------------------------------------------------------------- serving
    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                smax: int) -> Tuple[jnp.ndarray, Any]:
        """Process the full prompt; returns (last-position logits, cache)."""
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)[None, :]
        mask = None   # structural "causal" kind; never materialized
        enc_out = None
        cache, cross = init_cache(cfg, x.shape[0], smax)
        if cfg.is_encoder_decoder:
            enc_out = self._encode(params, batch["frames"])
        new_cache = []
        new_cross = []
        for i, (seg, ps) in enumerate(zip(cfg.segments, params["segments"])):
            cs = cross[i] if cross is not None else None
            x, _, nc, ncross = _run_segment(
                ps, cfg, seg, x, positions, mask, "causal",
                cache_stack=cache[i], enc_out=enc_out, cross_stack=cs)
            new_cache.append(nc)
            new_cross.append(ncross)
        logits = self._head(params, x[:, -1:])
        cross_out = tuple(new_cross) if cross is not None else None
        return logits, (tuple(new_cache), cross_out)

    def decode_step(self, params: Params, token: jnp.ndarray, pos,
                    cache_state) -> Tuple[jnp.ndarray, Any]:
        """One decode step. token: (B,1) int32; pos: scalar int32 (current
        sequence position, 0-based). Returns (logits (B,1,V), new cache)."""
        cfg = self.cfg
        cache, cross = cache_state
        x = params["embed"].astype(cfg.cdtype)[token]
        positions = jnp.full((1, 1), pos, jnp.int32)
        new_cache = []
        for i, (seg, ps) in enumerate(zip(cfg.segments, params["segments"])):
            cs = cross[i] if cross is not None else None
            x, _, nc, _ = _run_segment(
                ps, cfg, seg, x, positions, mask=None, mask_kind="decode",
                cache_stack=cache[i], cache_pos=pos, cross_stack=cs)
            new_cache.append(nc)
        logits = self._head(params, x)
        return logits, (tuple(new_cache), cross)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
