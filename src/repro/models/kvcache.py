"""Serving-state (KV cache / recurrent state) construction.

The cache mirrors the model's segment structure: for each segment, a dict
per block position whose leaves carry a leading ``repeat`` axis (so the
decode scan can consume them alongside the stacked parameters).

Cache kinds per block:
  attn  (dense KV) : k,v            (repeat, B, Smax, KV, hd)
  attn  (MLA)      : c_kv, k_rope   (repeat, B, Smax, kr|rope)
  mamba            : h (repeat,B,D_in,N), conv (repeat,B,dc-1,D_in)
  mlstm            : C (repeat,B,H,dh,dh), n (repeat,B,H,dh)
  slstm            : h,c,n,m        (repeat, B, D)
  cross-attn (enc-dec): k,v over encoder states, built at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp

from repro.models.config import ArchConfig, Block, Segment


def _attn_cache(cfg: ArchConfig, repeat: int, batch: int, smax: int,
                dtype) -> Dict[str, Any]:
    if cfg.use_mla:
        return {
            "c_kv": jnp.zeros((repeat, batch, smax, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((repeat, batch, smax, cfg.qk_rope_head_dim),
                                dtype),
        }
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((repeat, batch, smax, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((repeat, batch, smax, cfg.num_kv_heads, hd), dtype),
    }


def _block_cache(cfg: ArchConfig, block: Block, repeat: int, batch: int,
                 smax: int, dtype) -> Dict[str, Any]:
    if block.kind == "attn":
        return _attn_cache(cfg, repeat, batch, smax, dtype)
    if block.kind == "mamba":
        d_in = cfg.d_model * cfg.mamba_expand
        return {
            "h": jnp.zeros((repeat, batch, d_in, cfg.mamba_d_state),
                           jnp.float32),
            "conv": jnp.zeros((repeat, batch, cfg.mamba_d_conv - 1, d_in),
                              dtype),
        }
    if block.kind == "mlstm":
        d_in = cfg.d_model * cfg.xlstm_expand
        dh = d_in // cfg.num_heads
        return {
            "C": jnp.zeros((repeat, batch, cfg.num_heads, dh, dh),
                           jnp.float32),
            "n": jnp.zeros((repeat, batch, cfg.num_heads, dh), jnp.float32),
            # log-space stabilizer carried across decode steps
            "m": jnp.full((repeat, batch, cfg.num_heads), -1e30,
                          jnp.float32),
        }
    if block.kind == "slstm":
        d = cfg.d_model
        z = jnp.zeros((repeat, batch, d), jnp.float32)
        return {"h": z, "c": z, "n": z,
                "m": jnp.full((repeat, batch, d), -1e9, jnp.float32)}
    raise ValueError(block.kind)


def init_cache(cfg: ArchConfig, batch: int, smax: int,
               dtype=None) -> Tuple[Any, ...]:
    """Decode cache for the decoder stack; window-capped for SW attention."""
    dtype = dtype or cfg.cdtype
    cache = []
    for seg in cfg.segments:
        seg_cache = []
        for b in seg.blocks:
            # sliding-window attention never needs more than `window` slots
            s_eff = smax
            if b.kind == "attn" and cfg.sliding_window > 0:
                s_eff = min(smax, cfg.sliding_window)
            seg_cache.append(
                _block_cache(cfg, b, seg.repeat, batch, s_eff, dtype))
        cache.append(tuple(seg_cache))
    out = tuple(cache)
    if cfg.is_encoder_decoder:
        # cross-attention K/V over encoder outputs, filled at prefill;
        # one slot per repeat (enc-dec patterns carry one attn block each)
        hd = cfg.resolved_head_dim
        cross = []
        for seg in cfg.segments:
            cross.append({
                "k": jnp.zeros((seg.repeat, batch, cfg.encoder_max_frames,
                                cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((seg.repeat, batch, cfg.encoder_max_frames,
                                cfg.num_kv_heads, hd), dtype),
            })
        return out, tuple(cross)
    return out, None


def cache_bytes(cfg: ArchConfig, batch: int, smax: int) -> int:
    """Analytic cache footprint (profiler/roofline helper)."""
    import numpy as np

    cache, cross = init_cache(cfg, 1, 8)  # tiny instantiation for structure
    del cache, cross
    total = 0
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    for seg in cfg.segments:
        for b in seg.blocks:
            if b.kind == "attn":
                s_eff = min(smax, cfg.sliding_window) if cfg.sliding_window \
                    else smax
                if cfg.use_mla:
                    per = s_eff * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                else:
                    per = 2 * s_eff * cfg.num_kv_heads * cfg.resolved_head_dim
            elif b.kind == "mamba":
                d_in = cfg.d_model * cfg.mamba_expand
                per = d_in * cfg.mamba_d_state * 2 + (cfg.mamba_d_conv - 1) * d_in
            elif b.kind == "mlstm":
                d_in = cfg.d_model * cfg.xlstm_expand
                dh = d_in // cfg.num_heads
                per = cfg.num_heads * (dh * dh + dh) * 2
            else:  # slstm
                per = 4 * cfg.d_model * 2
            total += seg.repeat * per * batch * itemsize
    return int(total)
