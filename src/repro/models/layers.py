"""Neural building blocks for the model zoo — pure JAX, init/apply pairs.

Every layer is a pair of functions:
  ``init_<layer>(key, cfg) -> params``  (nested dict of jnp arrays)
  ``<layer>(params, cfg, x, ...) -> y`` (pure function)

Covered: RMSNorm, RoPE, GQA/MQA attention (full / causal / sliding-window /
cross), DeepSeek-style MLA (naive-expand prefill + absorbed decode),
SwiGLU MLP, scatter-based top-k MoE with capacity + aux loss, Mamba
selective-SSM block (chunked associative scan), and xLSTM mLSTM
(chunkwise-parallel) / sLSTM (sequential scan) cells.

Attention inner products route through ``repro.kernels.ops`` which
dispatches Pallas kernels on TPU and the jnp reference elsewhere.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

Params = Dict[str, Any]


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(cfg: ArchConfig, d: Optional[int] = None) -> Params:
    return {"scale": jnp.ones(d or cfg.d_model, cfg.pdtype)}


def rmsnorm(params: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels import ops
    return ops.rmsnorm(x, params["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., s, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h, hd), cfg.pdtype),
        "wk": _dense_init(ks[1], (d, kv, hd), cfg.pdtype),
        "wv": _dense_init(ks[2], (d, kv, hd), cfg.pdtype),
        "wo": _dense_init(ks[3], (h, hd, d), cfg.pdtype,
                          scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.pdtype)
        p["bk"] = jnp.zeros((kv, hd), cfg.pdtype)
        p["bv"] = jnp.zeros((kv, hd), cfg.pdtype)
    return p


def init_cross_attention(key, cfg: ArchConfig) -> Params:
    return init_attention(key, cfg)


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B,S,KV,hd) -> (B,S,KV*groups,hd) by repeat (GQA share)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   mask: Optional[jnp.ndarray], compute_dtype,
                   kind: Optional[str] = None,
                   window: int = 0) -> jnp.ndarray:
    """q: (B,Sq,H,Dq) k: (B,Sk,H,Dq) v: (B,Sk,H,Dv) -> (B,Sq,H,Dv).

    Routed through kernels.ops (Pallas flash attention on TPU, blockwise
    xla_flash on other backends when `kind` describes the mask
    structurally)."""
    from repro.kernels import ops
    return ops.attention(q, k, v, mask, compute_dtype, kind=kind,
                         window=window)


def make_causal_mask(sq: int, sk: int, window: int = 0,
                     offset: int = 0) -> jnp.ndarray:
    """(sq, sk) boolean mask. query i attends key j iff
    j <= i+offset and (window==0 or i+offset-j < window)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window > 0:
        m &= (qi - kj) < window
    return m


def attention(params: Params, cfg: ArchConfig, x: jnp.ndarray,
              positions: jnp.ndarray,
              mask: Optional[jnp.ndarray],
              kv_x: Optional[jnp.ndarray] = None,
              use_rope: bool = True,
              cache: Optional[Params] = None,
              cache_pos: Optional[jnp.ndarray] = None,
              kind: Optional[str] = None,
              ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """General GQA attention.

    * self-attention over x when kv_x is None
    * cross-attention over kv_x otherwise (no rope on cross)
    * with `cache` (dict k,v of (B,Smax,KV,hd)) and scalar `cache_pos`:
      single-token decode — writes the new kv at cache_pos, attends over
      the cache prefix.
    `kind` describes the mask structurally ("causal" | "full") so large
    sequences never materialize a dense mask or S^2 scores.
    Returns (output, updated_cache_or_None).
    """
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = h // kvh
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cfg.cdtype))
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(cfg.cdtype))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(cfg.cdtype))
    if "bq" in params:
        q = q + params["bq"].astype(cfg.cdtype)
        k = k + params["bk"].astype(cfg.cdtype)
        v = v + params["bv"].astype(cfg.cdtype)
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    valid_len = None
    if cache is not None:
        smax = cache["k"].shape[1]
        if cache_pos is not None:
            # decode one token. Sliding-window caches (smax == window) are
            # ring buffers: slot = pos % window; RoPE is pre-applied so the
            # permuted order is harmless.
            slot = cache_pos % smax if cfg.sliding_window > 0 else cache_pos
            from repro.models.sharding import constrain_kv
            ck = constrain_kv(jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1))
            cv = constrain_kv(jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1))
            new_cache = {"k": ck, "v": cv}
            k, v = ck.astype(cfg.cdtype), cv.astype(cfg.cdtype)
            valid_len = jnp.minimum(cache_pos + 1, smax)
            kj = jnp.arange(smax)[None, :]
            mask = (kj < valid_len)[None, :]        # broadcast (B,H,1,smax)
            kind = "decode"
        else:
            # prefill: populate the cache (tail only if window < seq)
            s = k.shape[1]
            kc, vc = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
            if smax >= s:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], kc, (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], vc, (0, 0, 0, 0))
            else:
                if cfg.sliding_window <= 0:
                    raise ValueError(
                        f"full-attention cache too small: smax={smax} < "
                        f"prompt length {s} (did you forget the modality "
                        f"prefix when sizing the cache?)")
                slots = jnp.arange(s - smax, s) % smax
                ck = cache["k"].at[:, slots].set(kc[:, -smax:])
                cv = cache["v"].at[:, slots].set(vc[:, -smax:])
            new_cache = {"k": ck, "v": cv}

    # GQA expansion happens inside the kernel/ref (KV heads stay compact).
    # Decode sliding windows are enforced by the ring buffer itself (slots
    # wrap), so the structural window only applies to prefill/train.
    window = cfg.sliding_window if (kv_x is None and kind != "decode") else 0
    from repro.kernels import ops
    out = ops.attention(q, k, v, mask, cfg.cdtype, kind=kind,
                        window=window,
                        valid_len=valid_len)       # (B,Sq,H,hd)
    return jnp.einsum("bshk,hkd->bsd", out,
                      params["wo"].astype(cfg.cdtype)), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3) — compressed-latent KV attention
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig) -> Params:
    d, h = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, \
        cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": _dense_init(ks[0], (d, qr), cfg.pdtype),
        "q_norm": jnp.ones(qr, cfg.pdtype),
        "wq_b": _dense_init(ks[1], (qr, h, nope + rope_d), cfg.pdtype),
        "wkv_a": _dense_init(ks[2], (d, kr + rope_d), cfg.pdtype),
        "kv_norm": jnp.ones(kr, cfg.pdtype),
        "wkv_b_k": _dense_init(ks[3], (kr, h, nope), cfg.pdtype),
        "wkv_b_v": _dense_init(ks[4], (kr, h, vd), cfg.pdtype),
        "wo": _dense_init(ks[5], (h, vd, d), cfg.pdtype,
                          scale=1.0 / math.sqrt(h * vd)),
    }


def _mla_qc(params: Params, cfg: ArchConfig, x, positions):
    """Shared MLA projections: per-head q (nope+rope'd) and latent kv."""
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    kr = cfg.kv_lora_rank
    q_lat = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(cfg.cdtype))
    q_lat = _rms(q_lat, params["q_norm"].astype(cfg.cdtype), cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"].astype(cfg.cdtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(cfg.cdtype))
    c_kv, k_rope = kv[..., :kr], kv[..., kr:]
    c_kv = _rms(c_kv, params["kv_norm"].astype(cfg.cdtype), cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[..., 0, :]


def _rms(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(
        x.dtype) * scale


def mla_attention(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, mask: Optional[jnp.ndarray],
                  cache: Optional[Params] = None,
                  cache_pos: Optional[jnp.ndarray] = None,
                  kind: Optional[str] = None,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Prefill/train: naive-expand form. Decode: absorbed form over the
    latent cache (c_kv, k_rope) — never materializes per-head K/V for the
    full context (the MLA memory saving)."""
    h = cfg.num_heads
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_nope, q_rope, c_kv, k_rope = _mla_qc(params, cfg, x, positions)

    if cache is not None and cache_pos is not None:
        # ---- absorbed decode ----
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache_pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            cache_pos, axis=1)
        new_cache = {"c_kv": cc, "k_rope": cr}
        ccf, crf = cc.astype(cfg.cdtype), cr.astype(cfg.cdtype)
        # absorb W_UK into q: (B,1,H,nope) x (kr,H,nope) -> (B,1,H,kr)
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope,
                           params["wkv_b_k"].astype(cfg.cdtype))
        scores = (jnp.einsum("bshr,btr->bhst", q_abs, ccf)
                  + jnp.einsum("bshr,btr->bhst", q_rope, crf)) * scale
        smax = cc.shape[1]
        valid = jnp.arange(smax)[None, :] <= cache_pos
        scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
        w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(
            cfg.cdtype)
        out_lat = jnp.einsum("bhst,btr->bshr", w, ccf)
        out = jnp.einsum("bshr,rhv->bshv", out_lat,
                         params["wkv_b_v"].astype(cfg.cdtype))
        return jnp.einsum("bshv,hvd->bsd", out,
                          params["wo"].astype(cfg.cdtype)), new_cache

    # ---- train / prefill: expand latent to per-head K,V ----
    k_nope = jnp.einsum("btr,rhn->bthn", c_kv,
                        params["wkv_b_k"].astype(cfg.cdtype))
    v = jnp.einsum("btr,rhv->bthv", c_kv,
                   params["wkv_b_v"].astype(cfg.cdtype))
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_nope.shape[:3] + (k_rope.shape[-1],))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = attention_core(q, k, v, mask, cfg.cdtype, kind=kind)
    new_cache = None
    if cache is not None:
        new_cache = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, 0, 0)),
        }
    return jnp.einsum("bshv,hvd->bsd", out,
                      params["wo"].astype(cfg.cdtype)), new_cache


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None,
             gated: Optional[bool] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = (cfg.act == "swiglu") if gated is None else gated
    ks = jax.random.split(key, 3)
    p = {
        "wu": _dense_init(ks[1], (d, f), cfg.pdtype),
        "wd": _dense_init(ks[2], (f, d), cfg.pdtype),
    }
    if gated:
        p["wg"] = _dense_init(ks[0], (d, f), cfg.pdtype)
    return p


def mlp(params: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    u = jnp.einsum("bsd,df->bsf", x, params["wu"].astype(cfg.cdtype))
    if "wg" in params:  # swiglu
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(cfg.cdtype))
        h = jax.nn.silu(g) * u
    else:               # non-gated gelu (granite code models)
        h = jax.nn.gelu(u)
    return jnp.einsum("bsf,fd->bsd", h, params["wd"].astype(cfg.cdtype))


# ---------------------------------------------------------------------------
# MoE: scatter-dispatch top-k with static capacity (expert-parallel ready)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ArchConfig) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),  # fp32 router
        "wg": _dense_init(ks[1], (e, d, f), cfg.pdtype),
        "wu": _dense_init(ks[2], (e, d, f), cfg.pdtype),
        "wd": _dense_init(ks[3], (e, f, d), cfg.pdtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, gated=True,
                               d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def _moe_tokens(params: Params, cfg: ArchConfig, xt: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Route one token group (t, d) through the experts.

    Sort-based dispatch (MaxText-style): slot positions within each
    expert's capacity come from a stable argsort over expert ids, keeping
    peak memory O(t*k + E*C*D) instead of the O(t*E) one-hot cumsum.
    """
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                   # (t,k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9, None)

    flat_e = top_i.reshape(-1)                                # (t*k,)
    # aux load-balance loss (switch-style) without one-hot
    me = probs.mean(axis=0)
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e, jnp.float32),
                                 flat_e, num_segments=e)
    ce = counts / (t * k)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight

    capacity = max(1, int(math.ceil(t * k / e * cfg.capacity_factor)))
    # Drop-free floor for small token counts (decode steps, smoke tests):
    # an expert receives at most `t` assignments, so capacity == t makes
    # routing exact at negligible memory cost when t is tiny.
    if t <= 64:
        capacity = max(capacity, t)

    # slot position of each assignment within its expert (stable sort)
    order = jnp.argsort(flat_e, stable=True)                  # (t*k,)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_sorted = jnp.arange(t * k) - starts[sorted_e]
    pos = jnp.zeros(t * k, jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos < capacity

    # scatter tokens into (E, C, D) — E shards over `model` (EP)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    src = jnp.where(keep[:, None], xt[tok_idx].astype(cfg.cdtype), 0.0)
    pe = jnp.where(keep, flat_e, e - 1)
    pp = jnp.where(keep, pos, capacity - 1)
    buf = jnp.zeros((e, capacity, d), cfg.cdtype).at[pe, pp].add(src)

    # expert FFNs: batched matmul, E sharded over `model`
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(cfg.cdtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["wu"].astype(cfg.cdtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   params["wd"].astype(cfg.cdtype))

    # gather back and combine with routing weights
    out_tk = jnp.where(keep[:, None], y[pe, pp], 0.0)         # (t*k, d)
    w = top_w.reshape(-1).astype(cfg.cdtype)
    out = jnp.zeros((t, d), cfg.cdtype).at[tok_idx].add(out_tk * w[:, None])

    if "shared" in params:
        out = out + mlp(params["shared"], cfg, xt[None]).reshape(t, d)
    return out, aux.astype(jnp.float32)


def moe(params: Params, cfg: ArchConfig, x: jnp.ndarray
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed experts with static capacity; returns (out, aux_loss).

    With ``cfg.moe_groups > 1`` tokens are routed in independent groups
    (group-limited capacity, as deployed EP systems do per-device): the
    group axis aligns with the mesh data axes so each shard dispatches its
    own tokens and the (G, E, C, D) buffer shards over (data, model).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    g = cfg.moe_groups
    if g > 1 and t % g == 0 and (t // g) >= 1:
        xg = xt.reshape(g, t // g, d)
        # Streaming (lax.map) vs parallel (vmap) groups: vmapping
        # materializes every group's (E, C, d_ff) expert hidden at once
        # (15 GiB/device fp32 on jamba prefill_32k), while scanning only
        # keeps one group live. But scan-AD's per-iteration residual
        # stacking costs small-expert models MORE than the vmap working
        # set (granite-moe train: 18 -> 35 GiB). Choose by the per-group
        # hidden size: stream when one group's hidden exceeds ~1 GiB
        # (jamba: 7.5 GiB -> map; deepseek: 2.7 GiB -> map;
        # granite-moe: 0.67 GiB -> vmap).
        tg = t // g
        e, k = max(cfg.num_experts, 1), max(cfg.num_experts_per_tok, 1)
        cap = max(1, int(math.ceil(tg * k / e * cfg.capacity_factor)))
        hidden_bytes = e * cap * max(cfg.moe_d_ff, 1) * 2
        if hidden_bytes > 1024 * 1024 * 1024:
            out, aux = jax.lax.map(
                lambda xx: _moe_tokens(params, cfg, xx), xg)
        else:
            out, aux = jax.vmap(
                lambda xx: _moe_tokens(params, cfg, xx))(xg)
        return out.reshape(b, s, d), aux.mean()
    out, aux = _moe_tokens(params, cfg, xt)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Mamba selective SSM block (chunked scan)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in = d * cfg.mamba_expand
    st, dc = cfg.mamba_d_state, cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * d_in), cfg.pdtype),
        "conv_w": _dense_init(ks[1], (dc, d_in), cfg.pdtype, scale=0.5),
        "w_bc": _dense_init(ks[2], (d_in, 2 * st), cfg.pdtype),
        "w_dt": jnp.full((d_in,), 0.1, cfg.pdtype),
        "b_dt": jnp.full((d_in,), -2.0, cfg.pdtype),  # softplus(-2)~0.12
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, st + 1, dtype=jnp.float32), (d_in, st))
        ).astype(cfg.pdtype),
        "d_skip": jnp.ones(d_in, cfg.pdtype),
        "out_proj": _dense_init(ks[5], (d_in, d), cfg.pdtype),
    }


def _mamba_scan_chunk(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t within a chunk.

    a, bx: (B, L, D, N); h0: (B, D, N). Returns (h over chunk, h_last).
    """
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_s, b_s = jax.lax.associative_scan(comb, (a, bx), axis=1)
    h = a_s * h0[:, None] + b_s
    return h, h[:, -1]


def mamba_block(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                state: Optional[Params] = None,
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B,S,D). With `state` (dict h:(B,D_in,N), conv:(B,dc-1,D_in)):
    recurrent continuation (decode uses S==1). Returns (y, new_state).

    FULLY CHUNK-STREAMED: the in-projection, causal conv, discretization,
    selective scan, gating and out-projection all run inside the chunk
    scan (conv tail and SSM state carried between chunks). Computing any
    of these full-sequence materializes (B,S,2*D_in)-class tensors —
    jamba-1.5-large prefill_32k paid ~90 GiB/device before this change
    (§Perf iterations 6 + 11).
    """
    b, s, d = x.shape
    d_in = d * cfg.mamba_expand
    st, dc = cfg.mamba_d_state, cfg.mamba_d_conv

    conv_tail0 = (state["conv"].astype(cfg.cdtype) if state is not None
                  else jnp.zeros((b, dc - 1, d_in), cfg.cdtype))
    h0 = (state["h"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, d_in, st), jnp.float32))

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk != 0:
        chunk = s  # fall back to single chunk for ragged lengths
    n_chunks = s // chunk

    w_in = params["in_proj"].astype(cfg.cdtype)
    conv_w = params["conv_w"].astype(cfg.cdtype)
    w_bc = params["w_bc"].astype(cfg.cdtype)
    w_dt = params["w_dt"].astype(cfg.cdtype)
    b_dt = params["b_dt"].astype(cfg.cdtype)
    d_skip = params["d_skip"].astype(jnp.float32)
    w_out = params["out_proj"].astype(cfg.cdtype)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))    # (D_in,N)

    def step(carry, x_c):
        h_carry, tail = carry                 # (B,D_in,N), (B,dc-1,D_in)
        xz = jnp.einsum("bld,de->ble", x_c, w_in)
        xs, z = jnp.split(xz, 2, axis=-1)
        xpad = jnp.concatenate([tail, xs], axis=1)
        new_tail = xpad[:, -(dc - 1):, :] if dc > 1 else tail
        xc = sum(xpad[:, i:i + chunk, :] * conv_w[i] for i in range(dc))
        xc = jax.nn.silu(xc)
        bc = jnp.einsum("ble,en->bln", xc, w_bc)
        b_c, c_c = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
        dt = jax.nn.softplus(xc * w_dt + b_dt).astype(jnp.float32)
        xcf = xc.astype(jnp.float32)
        # fused Pallas selective-scan kernel on TPU; associative scan on
        # other backends (repro.kernels.ops.mamba_chunk)
        from repro.kernels import ops
        y_c, h_last = ops.mamba_chunk(dt, xcf, b_c, c_c, a, h_carry)
        y_c = (y_c + d_skip * xcf).astype(cfg.cdtype)
        y_c = y_c * jax.nn.silu(z)
        out_c = jnp.einsum("ble,ed->bld", y_c, w_out)
        return (h_last, new_tail), out_c

    x_ch = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    (h_last, tail_last), out = jax.lax.scan(step, (h0, conv_tail0), x_ch)
    out = out.swapaxes(0, 1).reshape(b, s, d)
    new_state = None
    if state is not None:
        new_state = {"h": h_last.astype(state["h"].dtype),
                     "conv": tail_last.astype(state["conv"].dtype)}
    return out, new_state


# ---------------------------------------------------------------------------
# xLSTM cells
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in = d * cfg.xlstm_expand
    ks = jax.random.split(key, 7)
    return {
        "up": _dense_init(ks[0], (d, 2 * d_in), cfg.pdtype),
        "mq": _dense_init(ks[1], (d_in, d_in), cfg.pdtype),
        "mk": _dense_init(ks[2], (d_in, d_in), cfg.pdtype),
        "mv": _dense_init(ks[3], (d_in, d_in), cfg.pdtype),
        "w_i": _dense_init(ks[4], (d_in, cfg.num_heads), cfg.pdtype),
        "w_f": _dense_init(ks[5], (d_in, cfg.num_heads), cfg.pdtype),
        "b_i": jnp.zeros(cfg.num_heads, cfg.pdtype),
        "b_f": jnp.full((cfg.num_heads,), 3.0, cfg.pdtype),
        # per-head group-norm on the cell output (official xLSTM applies
        # MultiHeadLayerNorm here; without it denominator cancellation
        # lets |h| spike and training NaNs within ~20 steps)
        "out_norm": jnp.ones(d_in, cfg.pdtype),
        "down": _dense_init(ks[6], (d_in, d), cfg.pdtype),
    }


def mlstm_block(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                state: Optional[Params] = None,
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """mLSTM: matrix-memory cell (linear-attention-like), chunkwise
    parallel with the paper's LOG-SPACE STABILIZER.

    Unstabilized form:  C_t = f_t C_{t-1} + i_t v_t k_t^T ;
                        n_t = f_t n_{t-1} + i_t k_t ;
                        h_t = C_t q_t / max(|n_t . q_t|, 1)
    with exponential input gate i = exp(i~) and sigmoid forget gate. The
    raw exp overflows under training (observed: NaN after ~15 optimizer
    steps), so states are kept stabilized: every weight
    exp(F_t - F_s + i~_s) is divided by exp(m_t) where
    m_t = F_t + G_t,  G_t = max(m_prev, cummax_{s<=t}(i~_s - F_s)),
    F = intra-chunk cumulative log-forget. The carried (C, n, m) triple
    makes the recursion exact across chunks and decode steps.
    """
    b, s, d = x.shape
    h = cfg.num_heads
    d_in = d * cfg.xlstm_expand
    dh = d_in // h

    xu, z = jnp.split(
        jnp.einsum("bsd,de->bse", x, params["up"].astype(cfg.cdtype)),
        2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xu, params["mq"].astype(cfg.cdtype))
    k = jnp.einsum("bse,ef->bsf", xu, params["mk"].astype(cfg.cdtype))
    v = jnp.einsum("bse,ef->bsf", xu, params["mv"].astype(cfg.cdtype))
    q = q.reshape(b, s, h, dh).astype(jnp.float32) / math.sqrt(dh)
    k = k.reshape(b, s, h, dh).astype(jnp.float32)
    v = v.reshape(b, s, h, dh).astype(jnp.float32)

    logit_i = (jnp.einsum("bse,eh->bsh", xu, params["w_i"].astype(cfg.cdtype))
               + params["b_i"].astype(cfg.cdtype)).astype(jnp.float32)
    logit_f = (jnp.einsum("bse,eh->bsh", xu, params["w_f"].astype(cfg.cdtype))
               + params["b_f"].astype(cfg.cdtype)).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(logit_f)                  # (B,S,H), <= 0

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk != 0:
        chunk = s
    n_chunks = s // chunk

    def reshape_c(t):
        return t.reshape((b, n_chunks, chunk) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)
    fic, iic = reshape_c(log_f), reshape_c(logit_i)

    def step(carry, inp):
        C, n, m_prev = carry         # (B,H,dh,dh), (B,H,dh), (B,H)
        qq, kk, vv, lf, li = inp
        F = jnp.cumsum(lf, axis=1)                         # (B,L,H)
        ss = li - F                                        # i~_s - F_s
        G = jnp.maximum(m_prev[:, None, :],
                        jax.lax.cummax(ss, axis=1))        # (B,L,H)
        m_t = F + G
        # carried-state weight exp(m_prev - G_t); key weight exp(s_s - G_t)
        w_carry = jnp.exp(m_prev[:, None, :] - G)          # (B,L,H) <= 1
        y_inter = jnp.einsum("blh,bhde,blhe->blhd", w_carry, C, qq)
        n_inter = jnp.einsum("blh,bhd,blhd->blh", w_carry, n, qq)
        # intra-chunk: w'_ts = exp(s_s - G_t) for s <= t (stabilized, <= 1).
        # Mask the EXPONENT, not the exp: for s > t the raw exponent is
        # unbounded-positive, exp overflows to inf, and the cotangent of
        # the subsequent where is 0 * inf = NaN (the backward-only NaN
        # that killed training while the forward loss stayed finite).
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        expo = jnp.where(mask[None, :, :, None],
                         ss[:, None, :, :] - G[:, :, None, :], -1e30)
        w_rel = jnp.exp(jnp.minimum(expo, 0.0))                # (B,L,L,H)
        scores = jnp.einsum("blhd,bmhd->blmh", qq, kk) * w_rel
        y_intra = jnp.einsum("blmh,bmhd->blhd", scores, vv)
        n_intra = jnp.einsum("blmh,bmhd,blhd->blh", w_rel, kk, qq)
        y = y_inter + y_intra
        # exp(-m_t) saturates the output toward 0 once it exceeds the
        # numerator scale; clip the exponent so extreme log-forget sums
        # (F_t << 0 under training) cannot overflow to inf and poison
        # gradients.
        floor = jnp.exp(jnp.clip(-m_t, -40.0, 40.0))
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), floor)
        y = y / denom[..., None]
        # carry to chunk end (t = L): same stabilized weights at G_L
        G_L = G[:, -1]                                     # (B,H)
        w_end = jnp.exp(ss - G_L[:, None, :])              # (B,L,H)
        cf = jnp.exp(m_prev - G_L)                         # (B,H)
        C_new = C * cf[:, :, None, None] + jnp.einsum(
            "blh,blhd,blhe->bhde", w_end, vv, kk)
        n_new = n * cf[:, :, None] + jnp.einsum(
            "blh,blhd->bhd", w_end, kk)
        m_new = F[:, -1] + G_L
        return (C_new, n_new, m_new), y

    if state is not None:
        C0 = state["C"].astype(jnp.float32)
        n0 = state["n"].astype(jnp.float32)
        m0 = state["m"].astype(jnp.float32)
    else:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    (C_last, n_last, m_last), yc = jax.lax.scan(
        step, (C0, n0, m0), (qc, kc, vc, fic, iic))
    y = yc.swapaxes(0, 1).reshape(b, s, h, dh)
    # per-head group norm (see init_mlstm)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6)
    y = y.reshape(b, s, d_in).astype(cfg.cdtype) \
        * params["out_norm"].astype(cfg.cdtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["down"].astype(cfg.cdtype))
    new_state = None
    if state is not None:
        new_state = {"C": C_last.astype(state["C"].dtype),
                     "n": n_last.astype(state["n"].dtype),
                     "m": m_last.astype(state["m"].dtype)}
    return out, new_state


def init_slstm(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w_x": _dense_init(ks[0], (d, 4 * d), cfg.pdtype),   # z,i,f,o from x
        "r_h": _dense_init(ks[1], (d, 4 * d), cfg.pdtype,
                           scale=0.5 / math.sqrt(d)),        # recurrent
        "bias": jnp.concatenate([
            jnp.zeros(2 * d), jnp.full((d,), 3.0), jnp.zeros(d)
        ]).astype(cfg.pdtype),
        "proj": _dense_init(ks[2], (d, d), cfg.pdtype),
    }


def slstm_block(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                state: Optional[Params] = None,
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """sLSTM: scalar-memory cell with exponential gating and stabilizer
    state m; inherently sequential (true recurrence through h)."""
    b, s, d = x.shape
    pre = jnp.einsum("bsd,de->bse", x,
                     params["w_x"].astype(cfg.cdtype)) + \
        params["bias"].astype(cfg.cdtype)
    r_h = params["r_h"].astype(cfg.cdtype)

    def step(carry, pre_t):
        h, c, n, m = carry
        gates = (pre_t + jnp.einsum("bd,de->be", h, r_h)).astype(jnp.float32)
        z_t, i_t, f_t, o_t = jnp.split(gates, 4, axis=-1)
        z_t = jnp.tanh(z_t)
        o_t = jax.nn.sigmoid(o_t)
        m_new = jnp.maximum(f_t + m, i_t)               # log-space stabilizer
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(f_t + m - m_new)
        c_new = f_s * c + i_s * z_t
        n_new = f_s * n + i_s
        h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
        return (h_new.astype(jnp.float32), c_new, n_new, m_new), h_new

    if state is not None:
        carry0 = (state["h"].astype(jnp.float32),
                  state["c"].astype(jnp.float32),
                  state["n"].astype(jnp.float32),
                  state["m"].astype(jnp.float32))
    else:
        zeros = jnp.zeros((b, d), jnp.float32)
        carry0 = (zeros, zeros, zeros, jnp.full((b, d), -1e9, jnp.float32))
    carry, hs = jax.lax.scan(step, carry0, pre.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(cfg.cdtype)
    out = jnp.einsum("bsd,de->bse", y, params["proj"].astype(cfg.cdtype))
    new_state = None
    if state is not None:
        h, c, n, m = carry
        new_state = {"h": h.astype(state["h"].dtype),
                     "c": c.astype(state["c"].dtype),
                     "n": n.astype(state["n"].dtype),
                     "m": m.astype(state["m"].dtype)}
    return out, new_state
