from repro.train.optimizer import AdamW, adamw  # noqa: F401
from repro.train.trainer import Trainer, make_train_step  # noqa: F401
