"""Pytree checkpointing to .npz (flat path-keyed arrays).

bfloat16 leaves are stored as uint16 bit patterns (numpy's npz format has
no native bf16 cast path) with a ``__bf16__`` key prefix and viewed back
on restore — lossless.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_BF16_PREFIX = "__bf16__"


def _flatten(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            key = _BF16_PREFIX + key
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)


def _decode(key: str, arr: np.ndarray):
    if key.startswith(_BF16_PREFIX):
        return key[len(_BF16_PREFIX):], arr.view(jnp.bfloat16)
    return key, arr


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    data = np.load(path)
    stored = dict(_decode(k, data[k]) for k in data.files)
    flat = {}
    for path_, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
        flat["/".join(str(p) for p in path_)] = leaf
    if set(stored) != set(flat):
        missing = set(flat) - set(stored)
        extra = set(stored) - set(flat)
        raise ValueError(f"checkpoint mismatch: missing={missing} "
                         f"extra={extra}")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_, leaf in leaves_with_paths:
        key = "/".join(str(p) for p in path_)
        arr = stored[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(np.asarray(arr).astype(leaf.dtype)
                          if arr.dtype != leaf.dtype else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
