"""Training loop: jit'd train_step factory + a small driver.

``make_train_step`` returns the pure (params, opt_state, batch) ->
(params, opt_state, metrics) function used both by the CPU examples and
by the multi-pod dry-run (where it is lowered with sharded
ShapeDtypeStructs instead of real arrays).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import AdamW


def make_train_step(model: Model, opt: AdamW, microbatches: int = 1,
                    accum_dtype=None
                    ) -> Callable[..., Tuple[Any, Any, Dict[str, Any]]]:
    """One optimizer step; with ``microbatches > 1`` the global batch is
    split along dim 0 and gradients are accumulated in fp32 across a
    ``lax.scan`` (standard gradient accumulation). Activation memory
    scales with the microbatch, which is what lets the >300B configs
    (jamba-1.5-large, deepseek-v3) fit a 16 GiB/chip pod for train_4k —
    at the cost of re-gathering FSDP-sharded weights once per microbatch.

    The split keeps dim 0 of each microbatch on the batch axis (global
    (B, ...) -> (n, B/n, ...)), so data-axis sharding is preserved.
    """

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state = opt.update(params, opt_state, grads)
            return params, opt_state, {"loss": loss}

        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]),
            batch)

        def body(carry, mbatch):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(model.loss)(params, mbatch)
            grad_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), grad_acc, grads)
            return (loss_acc + loss, grad_acc), None

        # fp32 accumulation by default; the >300B configs pass bf16 so
        # the accumulator (one param-sized tree) fits the HBM budget
        adt = jnp.dtype(accum_dtype) if accum_dtype else jnp.float32
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, adt), params)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mb)
        inv = 1.0 / microbatches
        grads = jax.tree_util.tree_map(
            lambda g, p: (g * inv).astype(p.dtype), grad_sum, params)
        params, opt_state = opt.update(params, opt_state, grads)
        return params, opt_state, {"loss": loss_sum * inv}

    return train_step


@dataclasses.dataclass
class Trainer:
    model: Model
    opt: AdamW
    log_every: int = 10

    def fit(self, params, data: Iterator[Dict[str, Any]], steps: int,
            callback: Optional[Callable[[int, float], None]] = None):
        step_fn = jax.jit(make_train_step(self.model, self.opt))
        opt_state = self.opt.init(params)
        losses = []
        t0 = time.time()
        for i, batch in enumerate(data):
            if i >= steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if callback:
                callback(i, loss)
            if self.log_every and i % self.log_every == 0:
                dt = time.time() - t0
                print(f"step {i:5d}  loss {loss:.4f}  ({dt:.1f}s elapsed)",
                      flush=True)
        return params, opt_state, losses
