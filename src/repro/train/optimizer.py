"""AdamW in pure JAX (pytree-structured state, shardable like params).

``moment_dtype`` lets the very large assigned configs (deepseek-v3-671b,
jamba-1.5-large) keep first/second moments in bf16 so optimizer state fits
the per-chip HBM budget at 256-512-way sharding (noted in EXPERIMENTS.md
§Dry-run); defaults to fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Optional[str] = None     # None => fp32
    grad_clip: float = 1.0

    def _mdtype(self):
        return jnp.dtype(self.moment_dtype) if self.moment_dtype else \
            jnp.float32

    def init(self, params) -> AdamWState:
        md = self._mdtype()
        zeros = lambda p: jnp.zeros(p.shape, md)  # noqa: E731
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(self, params, state: AdamWState, grads
               ) -> Tuple[Any, AdamWState]:
        md = self._mdtype()
        step = state.step + 1

        if self.grad_clip > 0:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: g * scale.astype(g.dtype), grads)

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - self.lr * delta
            return p_new.astype(p.dtype), m_new.astype(md), v_new.astype(md)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v)


def adamw(lr: float = 3e-4, **kw) -> AdamW:
    return AdamW(lr=lr, **kw)
