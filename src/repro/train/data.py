"""Synthetic data pipeline: deterministic, seekable token streams.

Generates Zipf-distributed token sequences with light Markov structure so
the loss actually decreases during the example training runs (a learnable
bigram signal), plus modality stubs (frame/patch features) for the
enc-dec and vlm families. Batches are yielded as the exact dict the model
family expects.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ArchConfig

AUDIO_FEAT_DIM = 128
IMAGE_FEAT_DIM = 1024


class SyntheticCorpus:
    """Deterministic pseudo-corpus: each document is sampled from a fixed
    random bigram table (Zipf marginals), so next-token prediction has
    learnable structure."""

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 8):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        self.branch = branch
        v_eff = min(vocab_size, 4096)
        self._succ = rng.integers(0, v_eff, size=(v_eff, branch))
        self._v_eff = v_eff

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int64)
        tok = int(rng.integers(0, self._v_eff))
        for i in range(length):
            out[i] = tok
            tok = int(self._succ[tok, rng.integers(0, self.branch)])
        return out


def batches(cfg: ArchConfig, batch_size: int, seq_len: int,
            seed: int = 0, steps: Optional[int] = None
            ) -> Iterator[Dict[str, np.ndarray]]:
    corpus = SyntheticCorpus(cfg.vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    i = 0
    while steps is None or i < steps:
        toks = np.stack([corpus.sample(rng, seq_len)
                         for _ in range(batch_size)])
        batch: Dict[str, np.ndarray] = {"tokens": toks}
        if cfg.num_image_tokens:
            batch["image_feats"] = rng.normal(
                size=(batch_size, cfg.num_image_tokens, IMAGE_FEAT_DIM)
            ).astype(np.float32)
        if cfg.is_encoder_decoder:
            batch["frames"] = rng.normal(
                size=(batch_size, cfg.encoder_max_frames, AUDIO_FEAT_DIM)
            ).astype(np.float32)
        yield batch
        i += 1
