"""Real (wall-clock) pipeline executor: policy-aware centralized batched
queues + thread-pool model replicas serving actual JAX models on CPU.

This is the runtime half of the serving system: the same
Pipeline/PipelineConfig the Planner emits is deployed over real queues
and real jitted models, with the three properties InferLine requires of
a serving runtime (§3) implemented for real —

* **centralized batched queue per stage**, driven by the SAME policy
  core as the simulator (:class:`repro.core.policy.LiveQueue`): fifo,
  edf (per-query deadlines), and slo-drop with a runtime-reprogrammable
  shed margin, plus mid-run policy switching;
* **configurable max batch size**, enforced at batch formation;
* **runtime replica scaling in BOTH directions**: scale-up spawns
  worker threads (optionally activating only after a modeled activation
  delay, like the engine's ``(t, +1)`` events), scale-down *drains* —
  a retiring worker finishes its in-service batch, never abandons it.

Shutdown is condition-variable based: no queue sentinels, so there is
no sentinel/batch-assembly race — ``shutdown()`` joins every worker.

The executor also exposes the control-plane surface the closed-loop
Tuner drives in co-simulation: :meth:`PipelineExecutor.apply_control_event`
accepts the same :class:`repro.control.ControlEvent` s, and
:meth:`telemetry_counters` feeds the :class:`repro.serving.loop
.LiveControlLoop` driver that assembles real
:class:`~repro.sim.result.EpochTelemetry` records.

Scale is CPU-sized (tiny models, tens of QPS); the large-scale behavior
is covered by the discrete-event backends (`repro.serving.cluster`,
`repro.sim.control`), whose queue discipline this executor shares by
construction — `benchmarks/bench_live_loop.py` measures the residual
sim<->real gap. ``StageConfig.timeout_s`` (the beyond-paper formation
hold) is honored by the live queue exactly as in the simulator: a
partial fifo batch is held open until ``timeout_s`` past the head-of-
line arrival or the batch fills, whichever comes first (workers sleep
through the hold rather than polling).
"""

from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.control import ControlEvent
from repro.core.pipeline import Pipeline, PipelineConfig
from repro.core.policy import LiveQueue
from repro.serving.frontends import Frontend


@dataclasses.dataclass
class _Request:
    rid: int
    t_arrival: float                    # executor-clock seconds
    payload: Any
    deadline: float = float("inf")      # executor-clock seconds
    t_done: Optional[float] = None
    shed: bool = False                  # shed by an slo-drop stage
    cancelled: bool = False             # released by a timed-out driver
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    # routing state lives ON the request (object identity), so a stale
    # request draining after a run reset can never corrupt the
    # bookkeeping of a new run that reuses its rid
    visited: set = dataclasses.field(default_factory=set)  # guarded-by: _lock
    pending: int = 0                    # guarded-by: _lock (branches in flight)


class _Stage:
    """One centralized policy queue + its replica worker threads."""

    def __init__(self, name: str, fn: Callable[[List[Any]], List[Any]],
                 max_batch: int, policy: str, solo_latency_s: float,
                 timeout_s: float = 0.0):
        self.name = name
        self.fn = fn
        self.max_batch = max_batch
        self.solo_latency_s = solo_latency_s
        self.queue = LiveQueue(policy, timeout_s=timeout_s)  # guarded-by: cond
        self.cond = threading.Condition()
        self.workers: List[threading.Thread] = []      # guarded-by: cond
        self.target = 0                 # guarded-by: cond (replica target)
        self.retire_pending = 0         # guarded-by: cond
        self.stop = False               # guarded-by: cond
        # cumulative counters (run-relative; reset by start_run)
        self.arrived = 0                # guarded-by: cond
        self.completed = 0              # guarded-by: cond
        self.dropped = 0                # guarded-by: cond
        self.in_flight = 0              # guarded-by: cond
        self.batch_log: List[Tuple[float, int]] = []   # guarded-by: cond


# -- worker-thread crash surfacing ------------------------------------------
# `_worker_loop` catches model-fn exceptions per batch, but an exception
# anywhere ELSE in a worker (batch formation, routing, a checker bug)
# would previously just kill the thread: the pipeline deadlocks quietly
# and the run times out 300 s later with no cause in sight. A chained
# `threading.excepthook` routes any uncaught worker exception back to
# its owning executor, which fails the run loudly.
_WORKER_OWNERS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_PREV_EXCEPTHOOK: Optional[Callable] = None


def _worker_excepthook(hook_args) -> None:
    owner = _WORKER_OWNERS.get(hook_args.thread)
    if owner is not None and hook_args.exc_type is not SystemExit:
        ex, stage = owner[0](), owner[1]
        if ex is not None:
            ex._note_worker_failure(stage, hook_args.exc_value)
    if _PREV_EXCEPTHOOK is not None:    # keep the loud stderr traceback
        _PREV_EXCEPTHOOK(hook_args)


def _install_worker_excepthook() -> None:
    global _PREV_EXCEPTHOOK
    if threading.excepthook is _worker_excepthook:
        return
    _PREV_EXCEPTHOOK = threading.excepthook
    threading.excepthook = _worker_excepthook


class PipelineExecutor:
    """Deploys a configured pipeline over real threads and jitted models.

    Args:
      pipeline: the DAG; conditional edges are sampled per request.
      config: per-stage (hardware*, batch, replicas, policy) — hardware
        is informational on this CPU host; batch/replicas/policy are
        enforced.
      stage_fns: model_id -> callable(List[payload]) -> List[payload].
      solo_latency_s: per-stage batch-1 service latency (seconds) — the
        slo-drop viability floor (``deadline < now + solo + margin``).
        Take it from the measured profile's ``lut[1]``; defaults to 0
        (shed only queries already past their deadline).
      frontend: optional :class:`~repro.serving.frontends.Frontend`
        whose ``hop_delay_s`` is applied to every inter-stage hand-off
        (a request becomes batchable ``hop_delay_s`` after its parent
        completes) and to the reply hop — mirroring the simulator's
        ``rpc_delay_s`` so sim<->real comparisons model the same
        network.

    Join semantics: a request visits a stage at most once (same cap the
    scale-factor computation uses); the first triggering parent routes it.
    """

    def __init__(self, pipeline: Pipeline, config: PipelineConfig,
                 stage_fns: Dict[str, Callable[[List[Any]], List[Any]]],
                 seed: int = 0,
                 solo_latency_s: Optional[Dict[str, float]] = None,
                 frontend: Optional[Frontend] = None):
        self.pipeline = pipeline
        self.config = config
        self.rng = np.random.default_rng(seed)
        self._rng_lock = threading.Lock()
        self._lock = threading.Lock()     # guards per-request routing state
        self._children = {s: pipeline.children(s) for s in pipeline.stages}
        self.hop_delay_s = frontend.hop_delay_s if frontend else 0.0
        self._t0 = time.perf_counter()             # guarded-by: _lock
        self._shutdown = False
        self.on_request_done: Optional[Callable[[_Request], None]] = None
        # (stage, exception) per uncaught worker crash — failing loudly
        # beats a silent replica loss that deadlocks the run
        self.worker_failures: List[Tuple[str, BaseException]] = []  # guarded-by: _lock
        _install_worker_excepthook()
        solo = solo_latency_s or {}
        self._stages: Dict[str, _Stage] = {}
        # (t_effective, +/-delta) per stage; the replica_timeline property
        # derives the sorted cumulative step function, so a scale-up
        # recorded at its future activation instant and a later-issued
        # but earlier-effective scale-down still render in time order
        self._timeline_deltas: Dict[str, List[Tuple[float, int]]] = {}  # guarded-by: cond
        self._base_replicas: Dict[str, int] = {}   # guarded-by: cond
        for name, stage in pipeline.stages.items():
            cfg = config[name]
            st = _Stage(name, stage_fns[stage.model_id], cfg.batch_size,
                        getattr(cfg, "policy", "fifo"),
                        float(solo.get(name, 0.0)),
                        timeout_s=float(getattr(cfg, "timeout_s", 0.0)))
            self._stages[name] = st
            self._timeline_deltas[name] = []
            self._base_replicas[name] = cfg.replicas
            for _ in range(cfg.replicas):
                self._spawn_worker(st, t_active=0.0)
            with st.cond:       # workers are already running and racing
                st.target = cfg.replicas

    # -- clock -------------------------------------------------------------
    def now(self) -> float:
        """Seconds on the executor clock (zeroed by :meth:`start_run`)."""
        # analysis: allow LOCK01 — lock-free hot path: a float read is
        # GIL-atomic and a torn run-boundary timestamp only skews one
        # wait interval, never correctness
        return time.perf_counter() - self._t0

    def start_run(self) -> None:
        """Re-zero the clock and per-run stats for a fresh serving run.

        Stage queues are purged: requests a previous run left behind
        (released on timeout) carry pre-reset clock stamps and belong to
        nobody — they must not be served against the new clock."""
        with self._lock:
            self._t0 = time.perf_counter()
            self.worker_failures = []
        for st in self._stages.values():
            with st.cond:
                st.arrived = st.completed = st.dropped = 0
                st.batch_log = []
                st.queue.clear()
                self._timeline_deltas[st.name] = []
                self._base_replicas[st.name] = st.target

    # -- replica lifecycle -------------------------------------------------
    def _spawn_worker(self, st: _Stage, t_active: float) -> None:
        t = threading.Thread(target=self._worker_loop, args=(st, t_active),
                             daemon=True)
        _WORKER_OWNERS[t] = (weakref.ref(self), st.name)
        with st.cond:                 # workers list is shared state
            st.workers.append(t)
        t.start()

    def _note_worker_failure(self, stage: str, exc: BaseException) -> None:
        with self._lock:
            self.worker_failures.append((stage, exc))

    def _record_delta(self, st: _Stage, t: float, delta: int) -> None:  # holds-lock: cond
        self._timeline_deltas[st.name].append((t, delta))

    @property
    def replica_timeline(self) -> Dict[str, List[Tuple[float, int]]]:
        """Per-stage replica-target step function, sorted by effective
        time — the same (t, count) shape the simulated loops record."""
        out: Dict[str, List[Tuple[float, int]]] = {}
        for name, st in self._stages.items():
            with st.cond:
                deltas = sorted(self._timeline_deltas[name])
                count = self._base_replicas[name]
            tl = [(0.0, count)]
            for t, d in deltas:
                count += d
                tl.append((t, count))
            out[name] = tl
        return out

    def add_replicas(self, stage: str, n: int,
                     t_active: Optional[float] = None) -> None:
        """Spawn `n` workers; they begin serving at ``t_active`` (executor
        clock) — the runtime analogue of the engine's ``(t, +1)`` events
        with activation delay."""
        st = self._stages[stage]
        t_act = self.now() if t_active is None else float(t_active)
        with st.cond:
            st.target += n
            self._record_delta(st, t_act, n)
        for _ in range(n):
            self._spawn_worker(st, t_act)

    def retire_replicas(self, stage: str, n: int) -> None:
        """Retire `n` workers by draining: each exits after finishing any
        batch it is currently serving; queued work is never abandoned."""
        st = self._stages[stage]
        with st.cond:
            n = min(n, st.target)
            if n <= 0:
                return
            st.retire_pending += n
            st.target -= n
            self._record_delta(st, self.now(), -n)
            st.cond.notify_all()

    def scale(self, stage: str, replicas: int) -> None:
        """Runtime replica scaling to an absolute target — both
        directions (scale-down drains)."""
        cur = self.replica_target(stage)
        if replicas > cur:
            self.add_replicas(stage, replicas - cur)
        elif replicas < cur:
            self.retire_replicas(stage, cur - replicas)

    def live_worker_count(self, stage: str) -> int:
        """Worker threads actually alive (draining included)."""
        st = self._stages[stage]
        with st.cond:
            st.workers = [t for t in st.workers if t.is_alive()]
            return len(st.workers)

    def replica_target(self, stage: str) -> int:
        st = self._stages[stage]
        with st.cond:
            return st.target

    # -- control-plane surface --------------------------------------------
    def set_shed_margin(self, stage: str, margin_s: float) -> None:
        st = self._stages[stage]
        with st.cond:
            st.queue.shed_margin = float(margin_s)
            st.cond.notify_all()

    def set_policy(self, stage: str, policy: str) -> None:
        st = self._stages[stage]
        with st.cond:
            st.queue.set_policy(policy)
            st.cond.notify_all()

    def apply_control_event(self, ev: ControlEvent) -> None:
        """Land one controller decision on the running pipeline — the
        same event vocabulary the co-simulation loop folds into engine
        schedules (:func:`repro.control.fold_control_event`)."""
        if ev.stage not in self._stages:
            raise ValueError(f"control event for unknown stage {ev.stage!r}")
        if ev.kind == "up":
            self.add_replicas(ev.stage, int(ev.value), ev.t_effective)
        elif ev.kind == "down":
            self.retire_replicas(ev.stage, int(-ev.value))
        elif ev.kind == "shed":
            self.set_shed_margin(ev.stage, float(ev.value))
        elif ev.kind == "policy":
            if not ev.policy:
                raise ValueError("policy control event carries no policy")
            self.set_policy(ev.stage, ev.policy)
        else:
            raise ValueError(f"unknown control event kind {ev.kind!r}")

    # -- the worker loop ---------------------------------------------------
    def _worker_loop(self, st: _Stage, t_active: float) -> None:
        cond = st.cond
        while True:
            with cond:
                batch: List[_Request] = []
                shed: List[_Request] = []
                while True:
                    if st.stop:
                        return
                    if st.retire_pending > 0:
                        # drain: exit between batches, never mid-batch
                        st.retire_pending -= 1
                        return
                    now = self.now()
                    if now < t_active:
                        cond.wait(min(t_active - now, 0.1))
                        continue
                    batch, shed = st.queue.form_batch(
                        now, st.max_batch, st.solo_latency_s)
                    if batch or shed:
                        break
                    nxt = st.queue.next_ready_after(now, st.max_batch)
                    cond.wait(0.25 if nxt is None
                              else min(max(nxt - now, 0.0) + 1e-4, 0.25))
                cancelled = [r for r in batch if r.cancelled]
                batch = [r for r in batch if not r.cancelled]
                if batch:
                    st.batch_log.append((self.now(), len(batch)))
                    st.in_flight += len(batch)
            for req in cancelled:       # released by a timed-out driver
                self._finish_branch(st, req)
            for req in shed:
                self._finish_branch(st, req, shed_here=True)
            if not batch:
                continue
            try:
                outs = st.fn([r.payload for r in batch])
            except Exception as e:  # noqa: BLE001 — a dead worker
                # deadlocks the pipeline; surface the failure per-request
                import traceback
                print(f"[executor] stage {st.name} batch failed: {e!r}")
                traceback.print_exc()
                outs = [None] * len(batch)
            for req, out in zip(batch, outs):
                self._on_done(st, req, out)
            with cond:
                st.in_flight -= len(batch)
                st.completed += len(batch)

    # -- request routing ---------------------------------------------------
    def _coin(self, p: float) -> bool:
        if p >= 1.0:
            return True
        with self._rng_lock:
            return bool(self.rng.random() < p)

    def _enqueue(self, stage: str, req: _Request, ready: float) -> bool:
        with self._lock:
            if stage in req.visited:
                return False
            req.visited.add(stage)
            req.pending += 1
        st = self._stages[stage]
        with st.cond:
            st.arrived += 1
            st.queue.push(req, ready, req.deadline)
            st.cond.notify()
        return True

    def _finish_branch(self, st: _Stage, req: _Request,
                       shed_here: bool = False) -> None:
        """One branch of the request resolved without outputs (shed)."""
        if shed_here:
            req.shed = True
            with st.cond:
                st.dropped += 1
        with self._lock:
            req.pending -= 1
            finished = req.pending == 0
        if finished:
            self._finalize(req)

    def _on_done(self, st: _Stage, req: _Request, out: Any) -> None:
        if not req.shed:
            req.payload = out
        if not req.cancelled:
            ready = self.now() + self.hop_delay_s
            for e in self._children[st.name]:
                if self._coin(e.probability):
                    self._enqueue(e.dst, req, ready)
        with self._lock:
            req.pending -= 1
            finished = req.pending == 0
        if finished:
            self._finalize(req)

    def _finalize(self, req: _Request) -> None:
        req.t_done = self.now() + self.hop_delay_s   # reply hop
        req.done.set()
        cb = self.on_request_done
        if cb is not None:
            cb(req)

    def inject(self, req: _Request) -> None:
        routed = False
        ready = req.t_arrival + self.hop_delay_s
        for e in self.pipeline.entry_edges():
            if self._coin(e.probability):
                routed |= self._enqueue(e.dst, req, ready)
        if not routed:
            req.t_done = req.t_arrival
            req.done.set()

    def release(self, reqs: List[_Request]) -> int:
        """Cancel every unfinished request in `reqs`: queued occurrences
        are discarded at the next batch formation, in-service batches
        complete but route no further. Returns the number released —
        the timed-out ``serve_trace`` path uses this so stages do not
        keep grinding through a backlog nobody is waiting for."""
        n = 0
        for req in reqs:
            if not req.done.is_set():
                req.cancelled = True
                n += 1
        for st in self._stages.values():
            with st.cond:
                st.cond.notify_all()
        return n

    # -- serving -----------------------------------------------------------
    def serve_trace(self, arrivals: np.ndarray, payload_fn,
                    time_scale: float = 1.0,
                    timeout_s: float = 300.0,
                    slo_s: Optional[float] = None) -> np.ndarray:
        """Replay `arrivals` (seconds, scaled by `time_scale`) against the
        running pipeline; returns per-query latency (unscaled seconds).

        Requests still unfinished ``timeout_s`` after the last injection
        are *released* (cancelled and reported as ``inf``), not silently
        abandoned to keep grinding through the stages. ``slo_s`` stamps
        per-request deadlines (scaled), which the edf/slo-drop queue
        policies consume; shed requests report ``inf``.
        """
        arrivals = np.asarray(arrivals, dtype=np.float64) * time_scale
        self.start_run()
        reqs: List[_Request] = []
        for i, t_arr in enumerate(arrivals):
            now = self.now()
            if t_arr > now:
                time.sleep(t_arr - now)
            t_inj = self.now()
            deadline = (t_inj + slo_s * time_scale if slo_s is not None
                        else float("inf"))
            req = _Request(i, t_inj, payload_fn(i), deadline)
            reqs.append(req)
            self.inject(req)
        deadline_t = time.perf_counter() + timeout_s
        for req in reqs:
            req.done.wait(max(0.0, deadline_t - time.perf_counter()))
        self.release(reqs)
        with self._lock:
            failures = list(self.worker_failures)
        if failures:
            stages = ", ".join(f"{s}: {e!r}" for s, e in failures)
            raise RuntimeError(
                f"{len(failures)} worker thread(s) crashed during the "
                f"run ({stages}) — results would silently under-serve")
        return np.array([
            np.inf if (r.t_done is None or r.shed or r.cancelled)
            else (r.t_done - r.t_arrival) / time_scale
            for r in reqs])

    # -- telemetry ---------------------------------------------------------
    def telemetry_counters(self) -> Dict[str, Dict[str, float]]:
        """Instantaneous per-stage counters (cumulative arrived/completed/
        dropped + live queue depth, in-flight, replica target) — the raw
        feed the live control loop turns into ``StageTelemetry`` deltas."""
        out: Dict[str, Dict[str, float]] = {}
        for name, st in self._stages.items():
            with st.cond:
                out[name] = {
                    "arrived": st.arrived,
                    "completed": st.completed,
                    "dropped": st.dropped,
                    "queue_depth": len(st.queue),
                    "in_flight": st.in_flight,
                    "replicas": st.target,
                }
        return out

    def batch_sizes(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        for s, st in self._stages.items():
            with st.cond:
                sizes = [b for _, b in st.batch_log]
            out[s] = np.asarray(sizes, dtype=np.int64)
        return out

    def batch_stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for s, st in self._stages.items():
            with st.cond:
                sizes = [b for _, b in st.batch_log]
            out[s] = float(np.mean(sizes)) if sizes else 0.0
        return out

    # -- shutdown ----------------------------------------------------------
    def shutdown(self, join_timeout_s: float = 5.0) -> bool:
        """Stop every worker and join it. Returns True when all worker
        threads exited within the timeout. Safe to call twice."""
        self._shutdown = True
        to_join: List[threading.Thread] = []
        for st in self._stages.values():
            with st.cond:
                st.stop = True
                st.cond.notify_all()
                to_join.extend(st.workers)
        ok = True
        deadline = time.perf_counter() + join_timeout_s
        for t in to_join:
            t.join(max(0.0, deadline - time.perf_counter()))
            ok &= not t.is_alive()
        return ok
