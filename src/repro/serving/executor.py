"""Real (wall-clock) pipeline executor: policy-aware centralized batched
queues + thread-pool model replicas serving actual JAX models on CPU.

This is the runtime half of the serving system: the same
Pipeline/PipelineConfig the Planner emits is deployed over real queues
and real jitted models, with the three properties InferLine requires of
a serving runtime (§3) implemented for real —

* **centralized batched queue per stage**, driven by the SAME policy
  core as the simulator (:class:`repro.core.policy.LiveQueue`): fifo,
  edf (per-query deadlines), and slo-drop with a runtime-reprogrammable
  shed margin, plus mid-run policy switching;
* **configurable max batch size**, enforced at batch formation;
* **runtime replica scaling in BOTH directions**: scale-up spawns
  worker threads (optionally activating only after a modeled activation
  delay, like the engine's ``(t, +1)`` events), scale-down *drains* —
  a retiring worker finishes its in-service batch, never abandons it.

Shutdown is condition-variable based: no queue sentinels, so there is
no sentinel/batch-assembly race — ``shutdown()`` joins every worker.

The executor also exposes the control-plane surface the closed-loop
Tuner drives in co-simulation: :meth:`PipelineExecutor.apply_control_event`
accepts the same :class:`repro.control.ControlEvent` s, and
:meth:`telemetry_counters` feeds the :class:`repro.serving.loop
.LiveControlLoop` driver that assembles real
:class:`~repro.sim.result.EpochTelemetry` records.

Scale is CPU-sized (tiny models, tens of QPS); the large-scale behavior
is covered by the discrete-event backends (`repro.serving.cluster`,
`repro.sim.control`), whose queue discipline this executor shares by
construction — `benchmarks/bench_live_loop.py` measures the residual
sim<->real gap. ``StageConfig.timeout_s`` (the beyond-paper formation
hold) is honored by the live queue exactly as in the simulator: a
partial fifo batch is held open until ``timeout_s`` past the head-of-
line arrival or the batch fills, whichever comes first (workers sleep
through the hold rather than polling).

**Fault injection** (:mod:`repro.faults`): constructed with a
``FaultSchedule``, the executor kills real worker threads on the crash
schedule (a per-run driver thread calls :meth:`PipelineExecutor
.crash_replicas`; an in-service victim's batch requeues, never lost),
stretches batch service inside straggle windows, and fails batches
inside error windows from a per-stage seeded substream (same
``[seed, crc32(stage)]`` convention as the sim path). Failed work is
retried under the schedule's :class:`~repro.faults.schedule
.RecoveryPolicy` — bounded attempts, exponential backoff, optional
hedged duplicate near the deadline — with exactly-once delivery
enforced by per-(request, stage) resolve-once claims.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
import weakref
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.control import ControlEvent
from repro.core.pipeline import Pipeline, PipelineConfig
from repro.core.policy import LiveQueue
from repro.faults.schedule import (
    FaultSchedule,
    InjectedFault,
    RecoveryPolicy,
    StageFaults,
)
from repro.serving.dataplane import DataplaneStats
from repro.serving.frontends import Frontend
from repro.serving.procpool import (
    DEFAULT_SLAB_BYTES,
    ProcessReplicaPool,
    ProcReplica,
    ReplicaDead,
    StageWorkerError,
)


@dataclasses.dataclass
class _Request:
    rid: int
    t_arrival: float                    # executor-clock seconds
    payload: Any
    deadline: float = float("inf")      # executor-clock seconds
    t_done: Optional[float] = None
    shed: bool = False                  # shed by an slo-drop stage
    cancelled: bool = False             # released by a timed-out driver
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    # routing state lives ON the request (object identity), so a stale
    # request draining after a run reset can never corrupt the
    # bookkeeping of a new run that reuses its rid
    visited: set = dataclasses.field(default_factory=set)  # guarded-by: _lock
    pending: int = 0                    # guarded-by: _lock (branches in flight)
    # per-stage delivery attempt count (1 = first try) for bounded retry
    attempts: dict = dataclasses.field(default_factory=dict)  # guarded-by: _lock
    # stages where this request already resolved (delivered, shed, or
    # given up) — hedged duplicate queue entries lose against this set
    resolved_stages: set = dataclasses.field(default_factory=set)  # guarded-by: _lock
    # AND-join barrier: per-stage count of parent messages received and
    # the max readiness over *firing* parents (see _route_child)
    join_msgs: dict = dataclasses.field(default_factory=dict)  # guarded-by: _lock
    join_ready: dict = dataclasses.field(default_factory=dict)  # guarded-by: _lock


class _Stage:
    """One centralized policy queue + its replica worker threads."""

    def __init__(self, name: str, fn: Callable[[List[Any]], List[Any]],
                 max_batch: int, policy: str, solo_latency_s: float,
                 timeout_s: float = 0.0,
                 fault_rng: Optional[np.random.Generator] = None,
                 pool: Optional[ProcessReplicaPool] = None):
        self.name = name
        self.fn = fn
        # process backend: each dispatcher thread pairs with one worker
        # process from this pool (None = thread backend, fn runs inline).
        # The pool carries its own lock; it is NOT guarded by cond.
        self.pool = pool
        self.max_batch = max_batch
        self.solo_latency_s = solo_latency_s
        self.queue = LiveQueue(policy, timeout_s=timeout_s)  # guarded-by: cond
        self.cond = threading.Condition()
        self.workers: List[threading.Thread] = []      # guarded-by: cond
        self.target = 0                 # guarded-by: cond (replica target)
        self.retire_pending = 0         # guarded-by: cond
        self.kill_pending = 0           # guarded-by: cond (injected crashes)
        # per-stage substream for injected transient errors (drawn in
        # batch-dispatch order, like the sim's StageFaults.rng())
        self.fault_rng = fault_rng      # guarded-by: cond
        self.stop = False               # guarded-by: cond
        # cumulative counters (run-relative; reset by start_run)
        self.arrived = 0                # guarded-by: cond
        self.completed = 0              # guarded-by: cond
        self.dropped = 0                # guarded-by: cond
        self.in_flight = 0              # guarded-by: cond
        self.batch_log: List[Tuple[float, int]] = []   # guarded-by: cond


# -- worker-thread crash surfacing ------------------------------------------
# `_worker_loop` catches model-fn exceptions per batch, but an exception
# anywhere ELSE in a worker (batch formation, routing, a checker bug)
# would previously just kill the thread: the pipeline deadlocks quietly
# and the run times out 300 s later with no cause in sight. A chained
# `threading.excepthook` routes any uncaught worker exception back to
# its owning executor, which fails the run loudly.
_WORKER_OWNERS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_PREV_EXCEPTHOOK: Optional[Callable] = None


def _worker_excepthook(hook_args) -> None:
    owner = _WORKER_OWNERS.get(hook_args.thread)
    if owner is not None and hook_args.exc_type is not SystemExit:
        ex, stage = owner[0](), owner[1]
        if ex is not None:
            ex._note_worker_failure(stage, hook_args.exc_value)
    if _PREV_EXCEPTHOOK is not None:    # keep the loud stderr traceback
        _PREV_EXCEPTHOOK(hook_args)


def _install_worker_excepthook() -> None:
    global _PREV_EXCEPTHOOK
    if threading.excepthook is _worker_excepthook:
        return
    _PREV_EXCEPTHOOK = threading.excepthook
    threading.excepthook = _worker_excepthook


class PipelineExecutor:
    """Deploys a configured pipeline over real threads and jitted models.

    Args:
      pipeline: the DAG; conditional edges are sampled per request.
      config: per-stage (hardware*, batch, replicas, policy) — hardware
        is informational on this CPU host; batch/replicas/policy are
        enforced.
      stage_fns: model_id -> callable(List[payload]) -> List[payload].
      solo_latency_s: per-stage batch-1 service latency (seconds) — the
        slo-drop viability floor (``deadline < now + solo + margin``).
        Take it from the measured profile's ``lut[1]``; defaults to 0
        (shed only queries already past their deadline).
      frontend: optional :class:`~repro.serving.frontends.Frontend`
        whose ``hop_delay_s`` is applied to every inter-stage hand-off
        (a request becomes batchable ``hop_delay_s`` after its parent
        completes) and to the reply hop — mirroring the simulator's
        ``rpc_delay_s`` so sim<->real comparisons model the same
        network.
      faults: optional :class:`repro.faults.FaultSchedule` — crashes are
        driven against the run clock by a per-run driver thread,
        straggle/error windows are consulted at each batch dispatch, and
        the schedule's recovery policy arms the retry machinery.
      retry: override the recovery policy without a fault schedule
        (e.g. to retry real model-fn exceptions); defaults to
        ``faults.recovery`` when a schedule is given, else None
        (legacy behavior: a failed batch reports None payloads).
      backend: ``"thread"`` (default) runs stage fns inline in the
        dispatcher threads; ``"process"`` pairs every dispatcher with a
        worker OS process (:mod:`repro.serving.procpool`) fed through a
        shared-memory ring — same LiveQueue/batch-formation contract,
        but service escapes the GIL and injected crashes SIGKILL real
        processes. Stage fns must be fork-safe for the process backend
        (or importable, with ``start_method="spawn"``).
      slab_bytes: per-replica shared-memory slab size for the process
        backend; split into ``ring_depth`` buffers (oversize batches
        fall back to chunked-slab transport).
      transport: process-backend data plane — ``"ring"`` (default) is
        the typed zero-copy codec with a double-buffered ring
        overlapping dispatch with compute; ``"pickle"`` is the legacy
        PR 9 whole-batch-pickle lane kept for A/B benchmarking.
      ring_depth: ring buffers per replica (``transport="ring"``); 2 =
        double-buffered — the dispatcher assembles batch B into the
        slab while the worker computes on batch A. 1 degenerates to
        strictly synchronous dispatch.
      start_method: multiprocessing start method for worker processes
        (``fork`` default; ``spawn`` needs importable stage fns, see
        :func:`repro.serving.procpool.register_worker_fn`).

    Join semantics: AND-join with per-request barriers, mirroring the
    simulator's ``_stage_ready``. Every stage receives exactly one
    message per inbound edge per request — a firing token (parent
    completed and the edge's coin came up) or a non-firing anti-token —
    and is enqueued at most once, after ALL parents reported, iff at
    least one token fired, ready ``hop_delay_s`` after the latest
    firing parent. A stage none of whose tokens fired relays
    anti-tokens to its own children so descendants never stall.
    """

    def __init__(self, pipeline: Pipeline, config: PipelineConfig,
                 stage_fns: Dict[str, Callable[[List[Any]], List[Any]]],
                 seed: int = 0,
                 solo_latency_s: Optional[Dict[str, float]] = None,
                 frontend: Optional[Frontend] = None,
                 faults: Optional[FaultSchedule] = None,
                 retry: Optional[RecoveryPolicy] = None,
                 backend: str = "thread",
                 slab_bytes: int = DEFAULT_SLAB_BYTES,
                 transport: str = "ring",
                 ring_depth: int = 2,
                 start_method: str = "fork"):
        if backend not in ("thread", "process"):
            raise ValueError(f"unknown executor backend {backend!r}")
        self.pipeline = pipeline
        self.config = config
        self.backend = backend
        self.rng = np.random.default_rng(seed)
        self._rng_lock = threading.Lock()
        self._lock = threading.Lock()     # guards per-request routing state
        self._children = {s: pipeline.children(s) for s in pipeline.stages}
        self.hop_delay_s = frontend.hop_delay_s if frontend else 0.0
        self._t0 = time.perf_counter()             # guarded-by: _lock
        self._shutdown = False
        self.on_request_done: Optional[Callable[[_Request], None]] = None
        # invoked (outside locks) when a worker records a real crash —
        # lets a driver blocked on a timed wait fail the run immediately
        # (a reference read is GIL-atomic; set it before the run starts)
        self.on_worker_failure: Optional[Callable[[], None]] = None
        # (stage, exception) per uncaught worker crash — failing loudly
        # beats a silent replica loss that deadlocks the run
        self.worker_failures: List[Tuple[str, BaseException]] = []  # guarded-by: _lock
        # injection-lag telemetry of the most recent trace injection
        self._injection_stats: Optional[Dict[str, float]] = None  # guarded-by: _lock
        _install_worker_excepthook()
        # fault injection + recovery (repro.faults)
        self._faults = faults
        self._retry = retry if retry is not None else (
            faults.recovery if faults is not None else None)
        self._fault_specs: Dict[str, StageFaults] = {}
        if faults is not None:
            for s in pipeline.stages:
                spec = faults.stage(s)
                if spec is not None:
                    self._fault_specs[s] = spec
        # (t, -n) capacity losses from injected crashes, per stage —
        # the live analogue of the sim's crash schedule (feeds the
        # `alive` telemetry field); accessed under the stage's cond
        self._fault_deltas: Dict[str, List[Tuple[float, int]]] = {
            s: [] for s in pipeline.stages}  # guarded-by: cond
        # crash-driver thread control; touched only by the run driver
        # (start_run / shutdown), never by workers
        self._fault_stop: Optional[threading.Event] = None
        # AND-join fan-in per stage. pipeline.edges includes SOURCE
        # edges, so entry stages count the source message `inject` sends
        self._parents_n: Dict[str, int] = {}
        for e in pipeline.edges:
            self._parents_n[e.dst] = self._parents_n.get(e.dst, 0) + 1
        solo = solo_latency_s or {}
        self._stages: Dict[str, _Stage] = {}
        # (t_effective, +/-delta) per stage; the replica_timeline property
        # derives the sorted cumulative step function, so a scale-up
        # recorded at its future activation instant and a later-issued
        # but earlier-effective scale-down still render in time order
        self._timeline_deltas: Dict[str, List[Tuple[float, int]]] = {}  # guarded-by: cond
        self._base_replicas: Dict[str, int] = {}   # guarded-by: cond
        for name, stage in pipeline.stages.items():
            cfg = config[name]
            fault_rng = (np.random.default_rng(
                [int(faults.seed), zlib.crc32(name.encode())])
                if faults is not None else None)
            pool = (ProcessReplicaPool(stage_fns[stage.model_id],
                                       slab_bytes=slab_bytes,
                                       start_method=start_method,
                                       transport=transport,
                                       ring_depth=ring_depth)
                    if backend == "process" else None)
            st = _Stage(name, stage_fns[stage.model_id], cfg.batch_size,
                        getattr(cfg, "policy", "fifo"),
                        float(solo.get(name, 0.0)),
                        timeout_s=float(getattr(cfg, "timeout_s", 0.0)),
                        fault_rng=fault_rng, pool=pool)
            self._stages[name] = st
            self._timeline_deltas[name] = []
            self._base_replicas[name] = cfg.replicas
            for _ in range(cfg.replicas):
                self._spawn_worker(st, t_active=0.0)
            with st.cond:       # workers are already running and racing
                st.target = cfg.replicas

    # -- clock -------------------------------------------------------------
    def now(self) -> float:
        """Seconds on the executor clock (zeroed by :meth:`start_run`)."""
        # analysis: allow LOCK01 — lock-free hot path: a float read is
        # GIL-atomic and a torn run-boundary timestamp only skews one
        # wait interval, never correctness
        return time.perf_counter() - self._t0

    def start_run(self) -> None:
        """Re-zero the clock and per-run stats for a fresh serving run.

        Stage queues are purged: requests a previous run left behind
        (released on timeout) carry pre-reset clock stamps and belong to
        nobody — they must not be served against the new clock."""
        with self._lock:
            self._t0 = time.perf_counter()
            self.worker_failures = []
            self._injection_stats = None
        for st in self._stages.values():
            with st.cond:
                st.arrived = st.completed = st.dropped = 0
                st.batch_log = []
                st.queue.clear()
                self._timeline_deltas[st.name] = []
                self._base_replicas[st.name] = st.target
                self._fault_deltas[st.name] = []
        self._start_fault_driver()

    # -- replica lifecycle -------------------------------------------------
    def _spawn_worker(self, st: _Stage, t_active: float) -> None:
        t = threading.Thread(target=self._worker_loop, args=(st, t_active),
                             daemon=True)
        _WORKER_OWNERS[t] = (weakref.ref(self), st.name)
        with st.cond:                 # workers list is shared state
            st.workers.append(t)
        t.start()

    def _note_worker_failure(self, stage: str, exc: BaseException) -> None:
        with self._lock:
            self.worker_failures.append((stage, exc))
            cb = self.on_worker_failure
        if cb is not None:   # wake a blocked driver (e.g. the epoch wait)
            cb()

    def _record_delta(self, st: _Stage, t: float, delta: int) -> None:  # holds-lock: cond
        self._timeline_deltas[st.name].append((t, delta))

    @property
    def replica_timeline(self) -> Dict[str, List[Tuple[float, int]]]:
        """Per-stage replica-target step function, sorted by effective
        time — the same (t, count) shape the simulated loops record."""
        out: Dict[str, List[Tuple[float, int]]] = {}
        for name, st in self._stages.items():
            with st.cond:
                deltas = sorted(self._timeline_deltas[name])
                count = self._base_replicas[name]
            tl = [(0.0, count)]
            for t, d in deltas:
                count += d
                tl.append((t, count))
            out[name] = tl
        return out

    def add_replicas(self, stage: str, n: int,
                     t_active: Optional[float] = None) -> None:
        """Spawn `n` workers; they begin serving at ``t_active`` (executor
        clock) — the runtime analogue of the engine's ``(t, +1)`` events
        with activation delay."""
        st = self._stages[stage]
        t_act = self.now() if t_active is None else float(t_active)
        with st.cond:
            st.target += n
            self._record_delta(st, t_act, n)
        for _ in range(n):
            self._spawn_worker(st, t_act)

    def retire_replicas(self, stage: str, n: int) -> None:
        """Retire `n` workers by draining: each exits after finishing any
        batch it is currently serving; queued work is never abandoned."""
        st = self._stages[stage]
        with st.cond:
            n = min(n, st.target)
            if n <= 0:
                return
            st.retire_pending += n
            st.target -= n
            self._record_delta(st, self.now(), -n)
            st.cond.notify_all()

    def scale(self, stage: str, replicas: int) -> None:
        """Runtime replica scaling to an absolute target — both
        directions (scale-down drains)."""
        cur = self.replica_target(stage)
        if replicas > cur:
            self.add_replicas(stage, replicas - cur)
        elif replicas < cur:
            self.retire_replicas(stage, cur - replicas)

    # -- fault injection ---------------------------------------------------
    def crash_replicas(self, stage: str, n: int = 1) -> int:
        """Kill `n` replicas of `stage` (fault injection).

        Thread backend: each victim dies at its next scheduling point —
        an idle victim exits immediately; an in-service victim dies
        *instead of delivering* and its batch requeues under the
        recovery policy (the work is never silently lost). The deaths
        are clean thread exits — injected failures must not trip the
        ``worker_failures`` crash-surfacing path reserved for real bugs.

        Process backend: the victims are real OS processes, SIGKILLed
        immediately (busy ones first). A mid-batch death surfaces as
        :class:`~repro.serving.procpool.ReplicaDead` in the paired
        dispatcher thread, which requeues the in-flight batch exactly
        like the thread backend's kill path and exits cleanly.

        Returns the number killed (capped at the stage's live target).
        """
        st = self._stages[stage]
        t = self.now()
        with st.cond:
            n_eff = min(int(n), st.target)
            if n_eff <= 0:
                return 0
            if st.pool is not None:
                st.pool.kill(n_eff)
            else:
                st.kill_pending += n_eff
            st.target -= n_eff
            self._record_delta(st, t, -n_eff)
            self._fault_deltas[stage].append((t, -n_eff))
            st.cond.notify_all()
        return n_eff

    def fault_deltas(self) -> Dict[str, List[Tuple[float, int]]]:
        """Per-stage ``(t, -n)`` capacity losses from injected crashes
        this run — what the live control loop subtracts from the replica
        target to report the ``alive`` telemetry field."""
        out: Dict[str, List[Tuple[float, int]]] = {}
        for name, st in self._stages.items():
            with st.cond:
                out[name] = list(self._fault_deltas[name])
        return out

    def _start_fault_driver(self) -> None:
        """(Re)arm the crash schedule against the freshly-zeroed run
        clock. Called by :meth:`start_run`; a previous run's driver is
        stopped first so stale crash times never fire into a new run."""
        if self._fault_stop is not None:
            self._fault_stop.set()
            self._fault_stop = None
        crashes: List[Tuple[float, str, int]] = []
        for s, spec in self._fault_specs.items():
            for t, n in spec.crashes():
                crashes.append((float(t), s, int(n)))
        if not crashes:
            return
        crashes.sort()
        stop = threading.Event()
        self._fault_stop = stop
        t = threading.Thread(target=self._fault_driver_loop,
                             args=(crashes, stop), daemon=True)
        t.start()

    def _fault_driver_loop(self, crashes: List[Tuple[float, str, int]],
                           stop: threading.Event) -> None:
        for t_c, stage, n in crashes:
            while not stop.is_set():
                dt = t_c - self.now()
                if dt <= 0:
                    break
                stop.wait(min(dt, 0.05))
            if stop.is_set():
                return
            self.crash_replicas(stage, n)

    def live_worker_count(self, stage: str) -> int:
        """Worker threads actually alive (draining included)."""
        st = self._stages[stage]
        with st.cond:
            st.workers = [t for t in st.workers if t.is_alive()]
            return len(st.workers)

    def live_process_count(self, stage: str) -> int:
        """Worker OS processes alive (process backend; 0 for threads)."""
        st = self._stages[stage]
        return st.pool.alive_count() if st.pool is not None else 0

    def worker_pids(self, stage: str) -> List[int]:
        """PIDs of the stage's live worker processes (process backend)."""
        st = self._stages[stage]
        return st.pool.pids() if st.pool is not None else []

    def replica_target(self, stage: str) -> int:
        st = self._stages[stage]
        with st.cond:
            return st.target

    # -- control-plane surface --------------------------------------------
    def set_shed_margin(self, stage: str, margin_s: float) -> None:
        st = self._stages[stage]
        with st.cond:
            st.queue.shed_margin = float(margin_s)
            st.cond.notify_all()

    def set_policy(self, stage: str, policy: str) -> None:
        st = self._stages[stage]
        with st.cond:
            st.queue.set_policy(policy)
            st.cond.notify_all()

    def apply_control_event(self, ev: ControlEvent) -> None:
        """Land one controller decision on the running pipeline — the
        same event vocabulary the co-simulation loop folds into engine
        schedules (:func:`repro.control.fold_control_event`)."""
        if ev.stage not in self._stages:
            raise ValueError(f"control event for unknown stage {ev.stage!r}")
        if ev.kind == "up":
            self.add_replicas(ev.stage, int(ev.value), ev.t_effective)
        elif ev.kind == "down":
            self.retire_replicas(ev.stage, int(-ev.value))
        elif ev.kind == "shed":
            self.set_shed_margin(ev.stage, float(ev.value))
        elif ev.kind == "policy":
            if not ev.policy:
                raise ValueError("policy control event carries no policy")
            self.set_policy(ev.stage, ev.policy)
        else:
            raise ValueError(f"unknown control event kind {ev.kind!r}")

    # -- the worker loop ---------------------------------------------------
    def _worker_loop(self, st: _Stage, t_active: float) -> None:
        """Dispatcher thread body. With the process backend it first
        claims a paired worker process from the stage pool and always
        returns it (graceful close) on exit — including injected-death
        exits, where close() just reaps the corpse and frees the slab."""
        proc: Optional[ProcReplica] = None
        if st.pool is not None:
            proc = st.pool.spawn()
        try:
            if proc is None:
                self._dispatch_loop(st, t_active)
            else:
                self._dispatch_loop_proc(st, t_active, proc)
        finally:
            if proc is not None:
                st.pool.discard(proc)
                proc.close()

    def _formation_step(self, st: _Stage, t_active: float,
                        proc: Optional[ProcReplica], block: bool = True
                        ) -> Tuple[str, List[_Request], List[_Request],
                                   float]:
        """One batch-formation attempt under ``st.cond``. Returns
        ``(verdict, batch, shed, wait_s)``:

        * ``"exit"`` — the dispatcher must wind down (stop flag, paired
          process found dead while idle, injected kill, or a retire
          drain — pending counters are consumed here);
        * ``"work"`` — a batch and/or shed set formed;
        * ``"none"`` — nothing formable right now (non-blocking mode
          only); ``wait_s`` is the suggested re-poll delay, the same
          bound the blocking mode would have slept.

        ``block=True`` reproduces the original loop: sleep on the cond
        until work or an exit condition appears. ``block=False`` is the
        overlapped process path: with batches already in the ring the
        caller must keep servicing responses, so formation may not
        park on the condvar.
        """
        cond = st.cond
        with cond:
            while True:
                if st.stop:
                    return "exit", [], [], 0.0
                if proc is not None and not proc.alive():
                    # our paired process was crash-killed while idle
                    # (process-backend fault injection): exit cleanly.
                    # In-flight ring batches surface as ReplicaDead in
                    # the caller's drain and requeue there.
                    return "exit", [], [], 0.0
                if st.kill_pending > 0:
                    # injected crash: die at the scheduling point.
                    # A clean return is invisible to the excepthook
                    # registry — this is a simulated failure, not a
                    # bug to surface via worker_failures
                    st.kill_pending -= 1
                    return "exit", [], [], 0.0
                if st.retire_pending > 0:
                    # drain: exit between batches, never mid-batch
                    st.retire_pending -= 1
                    return "exit", [], [], 0.0
                now = self.now()
                if now < t_active:
                    wait = min(t_active - now, 0.1)
                    if not block:
                        return "none", [], [], wait
                    cond.wait(wait)
                    continue
                batch, shed = st.queue.form_batch(
                    now, st.max_batch, st.solo_latency_s)
                if batch or shed:
                    return "work", batch, shed, 0.0
                nxt = st.queue.next_ready_after(now, st.max_batch)
                wait = (0.25 if nxt is None
                        else min(max(nxt - now, 0.0) + 1e-4, 0.25))
                if not block:
                    return "none", [], [], wait
                cond.wait(wait)

    def _prep_batch(self, st: _Stage, batch: List[_Request],
                    shed: List[_Request]) -> List[_Request]:
        """Post-formation bookkeeping shared by both backends: dedup
        hedged twins, peel off cancelled requests, account the batch
        (log + in-flight), and resolve cancelled/shed branches. Returns
        the servable batch (possibly empty)."""
        batch = self._dedup_batch(st, batch)
        cancelled = [r for r in batch if r.cancelled]
        batch = [r for r in batch if not r.cancelled]
        with st.cond:
            if batch:
                st.batch_log.append((self.now(), len(batch)))
                st.in_flight += len(batch)
        for req in cancelled:       # released by a timed-out driver
            if self._resolve_stage_once(st, req):
                self._finish_branch(st, req)
        for req in shed:
            if self._resolve_stage_once(st, req):
                self._finish_branch(st, req, shed_here=True)
        return batch

    def _complete_batch(self, st: _Stage, batch: List[_Request],
                        t_start: float, outs: List[Any],
                        err: Optional[BaseException],
                        proc_dead: bool) -> bool:
        """Service-completion tail shared by both backends: injected
        straggle/error draws, in-flight/completed accounting, the
        killed-replica requeue, retry routing, and the response scatter
        (:meth:`_on_done` per request). Returns True when the dispatcher
        must exit (its replica was killed mid-service)."""
        cond = st.cond
        spec = self._fault_specs.get(st.name)
        if spec is not None:
            slow = spec.slowdown_at(t_start)
            if slow > 1.0:
                # stretch the observed service time to `slow`x real
                time.sleep(max(0.0,
                               (self.now() - t_start) * (slow - 1.0)))
            if err is None:
                p_err = spec.error_p(t_start)
                if p_err > 0.0:
                    with cond:
                        fail = bool(st.fault_rng.random() < p_err)
                    if fail:
                        err = InjectedFault(
                            f"injected transient error on {st.name}")
        with cond:
            killed = proc_dead
            if not killed and st.kill_pending > 0:
                st.kill_pending -= 1
                killed = True
            st.in_flight -= len(batch)
            # legacy accounting: without retry machinery a failed
            # batch still counts completed (it delivered None)
            if not killed and (err is None or self._retry is None):
                st.completed += len(batch)
        if killed:
            # the replica died mid-service: its batch is lost and
            # requeues immediately (no backoff — the server failed,
            # not the work); the thread itself dies cleanly
            now = self.now()
            for req in batch:
                self._retry_or_fail(st, req, now, backoff=False)
            return True
        if err is not None and not isinstance(err, InjectedFault):
            import traceback
            print(f"[executor] stage {st.name} batch failed: {err!r}")
            traceback.print_exception(type(err), err, err.__traceback__)
        if err is not None and self._retry is not None:
            now = self.now()
            for req in batch:
                self._retry_or_fail(st, req, now, backoff=True)
            return False
        for req, out in zip(batch, outs):
            self._on_done(st, req, out)
        return False

    def _dispatch_loop(self, st: _Stage, t_active: float) -> None:
        """Thread-backend dispatcher: form, serve inline, complete —
        strictly synchronous, one batch at a time."""
        while True:
            verdict, batch, shed, _ = self._formation_step(
                st, t_active, None, block=True)
            if verdict == "exit":
                return
            batch = self._prep_batch(st, batch, shed)
            if not batch:
                continue
            t_start = self.now()
            err: Optional[BaseException] = None
            outs: List[Any] = []
            try:
                outs = st.fn([r.payload for r in batch])
            except Exception as e:  # noqa: BLE001 — a dead worker
                # deadlocks the pipeline; surface the failure per-request
                err = e
                outs = [None] * len(batch)
            if self._complete_batch(st, batch, t_start, outs, err, False):
                return

    def _abort_inflight(self, st: _Stage,
                        inflight: "deque") -> None:
        """The paired process died with batches still in the ring:
        none of them reached :meth:`_on_done`, so every request
        requeues immediately — the pipelined arm of the exactly-once
        contract (a SIGKILL mid-handoff loses the slab contents, never
        the requests)."""
        now = self.now()
        while inflight:
            batch, _t = inflight.popleft()
            with st.cond:
                st.in_flight -= len(batch)
            for req in batch:
                self._retry_or_fail(st, req, now, backoff=False)

    def _dispatch_loop_proc(self, st: _Stage, t_active: float,
                            proc: ProcReplica) -> None:
        """Process-backend dispatcher: overlapped dispatch/compute.

        While the ring has free buffers, keep forming batches and
        submitting them (the dispatcher encodes batch B directly into
        the slab while the worker computes on batch A); whenever
        something is in flight, service the oldest response. Formation
        blocks on the condvar only when the ring is empty — with work
        in flight it polls, bounded by the same wait the synchronous
        loop would have slept, so responses are never starved.
        ``ring_depth=1`` (or ``transport="pickle"``) degenerates to the
        strictly synchronous schedule through this same loop."""
        inflight: deque = deque()      # (batch, t_submit) FIFO
        exiting = False
        while True:
            wait_s = 0.25
            while not exiting and proc.free_slots > 0:
                verdict, batch, shed, wait_s = self._formation_step(
                    st, t_active, proc, block=not inflight)
                if verdict == "exit":
                    exiting = True
                    break
                if verdict == "none":
                    break
                batch = self._prep_batch(st, batch, shed)
                if not batch:
                    continue
                t_start = self.now()
                try:
                    proc.submit([r.payload for r in batch])
                except ReplicaDead:
                    self._complete_batch(st, batch, t_start,
                                         [None] * len(batch), None, True)
                    self._abort_inflight(st, inflight)
                    return
                proc.busy = True
                inflight.append((batch, t_start))
            if not inflight:
                if exiting:
                    return
                continue
            # with free ring slots left, poll so newly-ready queue work
            # can overlap the in-flight compute; ring-full (or draining
            # to exit) blocks until the worker responds
            timeout = (min(wait_s, 0.05)
                       if not exiting and proc.free_slots > 0 else None)
            err: Optional[BaseException] = None
            try:
                outs = proc.collect(timeout=timeout)
            except ReplicaDead:
                batch, t_start = inflight.popleft()
                self._complete_batch(st, batch, t_start,
                                     [None] * len(batch), None, True)
                self._abort_inflight(st, inflight)
                return
            except StageWorkerError as e:
                # the stage fn raised inside the worker: the replica
                # survives, the batch failed
                err = e
                outs = None
            if err is None and outs is None:
                continue                # poll timeout: try forming again
            batch, t_start = inflight.popleft()
            if not inflight:
                proc.busy = False
            if err is not None:
                outs = [None] * len(batch)
            if self._complete_batch(st, batch, t_start, outs, err, False):
                self._abort_inflight(st, inflight)
                return

    # -- request routing ---------------------------------------------------
    def _coin(self, p: float) -> bool:
        if p >= 1.0:
            return True
        with self._rng_lock:
            return bool(self.rng.random() < p)

    def _enqueue(self, stage: str, req: _Request, ready: float) -> bool:
        with self._lock:
            if stage in req.visited:
                return False
            req.visited.add(stage)
            req.pending += 1
        st = self._stages[stage]
        with st.cond:
            st.arrived += 1
            st.queue.push(req, ready, req.deadline)
            st.cond.notify()
        return True

    def _resolve_stage_once(self, st: _Stage, req: _Request) -> bool:
        """Claim the single resolution of `req` at this stage (delivery,
        shed, cancel, or retry give-up). Hedged duplicate entries lose
        the claim and must have NO routing or accounting effect."""
        with self._lock:
            if st.name in req.resolved_stages:
                return False
            req.resolved_stages.add(st.name)
            return True

    def _dedup_batch(self, st: _Stage,
                     batch: List[_Request]) -> List[_Request]:
        """Drop hedged-duplicate queue entries: the same request twice
        in one formation, or an entry whose request already resolved at
        this stage (its twin was served or shed earlier)."""
        out: List[_Request] = []
        seen: set = set()
        with self._lock:
            for r in batch:
                if id(r) in seen or st.name in r.resolved_stages:
                    continue
                seen.add(id(r))
                out.append(r)
        return out

    def _retry_or_fail(self, st: _Stage, req: _Request, now: float,
                       backoff: bool) -> None:
        """One failed delivery attempt of `req` at this stage: requeue
        under the recovery policy (exponential backoff for transient
        errors, immediate for crash-aborted work; a hedged duplicate is
        added when the remaining deadline budget is below
        ``hedge_slack_s``), or — retries exhausted / recovery disabled /
        request cancelled — resolve the branch as shed."""
        rec = self._retry
        with self._lock:
            a = req.attempts.get(st.name, 1) + 1
            req.attempts[st.name] = a
            give_up = (rec is None or not rec.enabled
                       or a > int(rec.max_attempts) or req.cancelled)
        if give_up:
            if self._resolve_stage_once(st, req):
                self._finish_branch(st, req, shed_here=True)
            return
        ready = now + (rec.backoff(a - 1) if backoff else 0.0)
        copies = 2 if (rec.hedge_slack_s > 0.0
                       and req.deadline - ready < rec.hedge_slack_s) else 1
        with st.cond:
            for _ in range(copies):
                st.queue.push(req, ready, req.deadline)
            st.cond.notify_all()

    def _route_child(self, stage: str, req: _Request, fired: bool,
                     ready: float) -> None:
        """Deliver one parent message to `stage`'s join barrier: a
        firing token (`fired`, batchable at `ready`) or an anti-token.
        When the last parent message lands, the stage either enqueues
        (>=1 token fired; ready = max over firing parents, the sim's
        AND-join) or relays anti-tokens to its own children."""
        with self._lock:
            got = req.join_msgs.get(stage, 0) + 1
            req.join_msgs[stage] = got
            if fired:
                prev = req.join_ready.get(stage)
                req.join_ready[stage] = (ready if prev is None
                                         else max(prev, ready))
            complete = got == self._parents_n.get(stage, 1)
            fire = complete and stage in req.join_ready
            r = req.join_ready.get(stage, 0.0)
        if not complete:
            return
        if fire:
            self._enqueue(stage, req, r)
        else:
            for e in self._children[stage]:
                self._route_child(e.dst, req, False, 0.0)

    def _finish_branch(self, st: _Stage, req: _Request,
                       shed_here: bool = False) -> None:
        """One branch of the request resolved without outputs (shed,
        cancelled, or retries exhausted). Caller must have won
        :meth:`_resolve_stage_once` for this stage. Children still
        receive their join messages — as anti-tokens — so AND-join
        descendants never stall on a missing parent report."""
        if shed_here:
            req.shed = True
            with st.cond:
                st.dropped += 1
        for e in self._children[st.name]:
            self._route_child(e.dst, req, False, 0.0)
        with self._lock:
            req.pending -= 1
            finished = req.pending == 0
        if finished:
            self._finalize(req)

    def _on_done(self, st: _Stage, req: _Request, out: Any) -> None:
        if not self._resolve_stage_once(st, req):
            return      # hedged twin: the other copy already resolved
        if not req.shed:
            req.payload = out
        ready = self.now() + self.hop_delay_s
        for e in self._children[st.name]:
            fired = (not req.cancelled) and self._coin(e.probability)
            self._route_child(e.dst, req, fired, ready)
        with self._lock:
            req.pending -= 1
            finished = req.pending == 0
        if finished:
            self._finalize(req)

    def _finalize(self, req: _Request) -> None:
        req.t_done = self.now() + self.hop_delay_s   # reply hop
        req.done.set()
        cb = self.on_request_done
        if cb is not None:
            cb(req)

    def inject(self, req: _Request) -> None:
        # the injection guard keeps `pending` positive while entry
        # messages land, so a fast first branch finishing cannot
        # finalize the request before its remaining entry edges route
        with self._lock:
            req.pending += 1
        ready = req.t_arrival + self.hop_delay_s
        for e in self.pipeline.entry_edges():
            self._route_child(e.dst, req, self._coin(e.probability), ready)
        with self._lock:
            req.pending -= 1
            finished = req.pending == 0
            routed = bool(req.visited)
        if finished:
            if routed:
                self._finalize(req)
            else:       # nothing fired anywhere: never entered a queue
                req.t_done = req.t_arrival
                req.done.set()

    def release(self, reqs: List[_Request]) -> int:
        """Cancel every unfinished request in `reqs`: queued occurrences
        are discarded at the next batch formation, in-service batches
        complete but route no further. Returns the number released —
        the timed-out ``serve_trace`` path uses this so stages do not
        keep grinding through a backlog nobody is waiting for."""
        n = 0
        for req in reqs:
            if not req.done.is_set():
                req.cancelled = True
                n += 1
        for st in self._stages.values():
            with st.cond:
                st.cond.notify_all()
        return n

    # -- serving -----------------------------------------------------------
    def release_starved(self) -> int:
        """Release requests stranded at a *dead* stage: replica target 0
        (all replicas crashed, or scaled to zero) with queued work and
        nothing to serve it. The live analogue of the sim's finite
        starvation sentinel — stranded requests resolve promptly
        (reported ``inf``) instead of grinding to the run timeout.
        Hedged duplicates resolve once; AND-join descendants receive
        anti-tokens so the rest of the DAG never stalls. Returns the
        number of requests released."""
        released = 0
        for st in self._stages.values():
            with st.cond:
                if st.target > 0 or st.stop or len(st.queue) == 0:
                    continue
                stranded = st.queue.drain_all()
            for req in stranded:
                if self._resolve_stage_once(st, req):
                    req.cancelled = True
                    released += 1
                    self._finish_branch(st, req)
        return released

    def await_all(self, reqs: List[_Request], timeout_s: float,
                  poll_s: float = 0.2) -> int:
        """Wait until every request in `reqs` resolves or `timeout_s`
        expires, releasing work stranded on starved (zero-replica)
        stages as soon as the condition is detected — an all-dead stage
        fast-fails in ~`poll_s` rather than eating the whole timeout.
        Returns the number of starvation-released requests."""
        deadline_t = time.perf_counter() + float(timeout_s)
        released = 0
        pending = [r for r in reqs if r is not None]
        while True:
            released += self.release_starved()
            pending = [r for r in pending if not r.done.is_set()]
            if not pending:
                return released
            rem = deadline_t - time.perf_counter()
            if rem <= 0.0:
                return released
            pending[0].done.wait(min(poll_s, rem))

    def check_worker_failures(self, context: str = "the run") -> None:
        """Raise if any worker thread crashed with a real (non-injected)
        exception during `context` — results would silently under-serve."""
        with self._lock:
            failures = list(self.worker_failures)
        if failures:
            stages = ", ".join(f"{s}: {e!r}" for s, e in failures)
            raise RuntimeError(
                f"{len(failures)} worker thread(s) crashed during "
                f"{context} ({stages}) — results would silently "
                f"under-serve")

    def _note_injection_lags(self, lags: np.ndarray) -> None:
        """Record injection-lag telemetry for the run (how late each
        request was admitted past its nominal absolute deadline)."""
        lags = np.asarray(lags, dtype=np.float64)
        stats = {
            "n": int(lags.size),
            "max_lag_s": float(lags.max()) if lags.size else 0.0,
            "p99_lag_s": (float(np.percentile(lags, 99.0))
                          if lags.size else 0.0),
            "mean_lag_s": float(lags.mean()) if lags.size else 0.0,
        }
        with self._lock:
            self._injection_stats = stats

    def injection_stats(self) -> Optional[Dict[str, float]]:
        """Injection-lag telemetry of the most recent trace injection
        (``serve_trace`` or :class:`~repro.serving.ingress.AsyncIngress`):
        ``{n, max_lag_s, p99_lag_s, mean_lag_s}``, or None before the
        first injection of a run."""
        with self._lock:
            return (dict(self._injection_stats)
                    if self._injection_stats is not None else None)

    def serve_trace(self, arrivals: np.ndarray, payload_fn,
                    time_scale: float = 1.0,
                    timeout_s: float = 300.0,
                    slo_s: Optional[float] = None,
                    prebuild: bool = True) -> np.ndarray:
        """Replay `arrivals` (seconds, scaled by `time_scale`) against the
        running pipeline; returns per-query latency (unscaled seconds).

        Open-loop injection is *absolute-deadline* scheduled: payloads
        are pre-built before the clock starts, each sleep targets
        ``start + t_arr`` (never re-anchored on the drifted ``now()``,
        so a late injection catches up instead of compounding), and
        requests are stamped with their NOMINAL arrival — measured
        latency and the ``slo_s`` deadline are charged against the
        intended schedule, not the drifted injection instant. Per-
        request injection lag is recorded (:meth:`injection_stats`).

        Requests still unfinished ``timeout_s`` after the last injection
        are *released* (cancelled and reported as ``inf``), not silently
        abandoned to keep grinding through the stages; requests stranded
        on a stage whose replicas all died release promptly
        (:meth:`release_starved`). ``slo_s`` stamps per-request
        deadlines (scaled), which the edf/slo-drop queue policies
        consume; shed requests report ``inf``.

        ``prebuild=False`` calls ``payload_fn(i)`` at injection time
        instead of materializing all n payloads up front — for large
        tensor payloads pair it with a reusable buffer pool
        (:class:`~repro.serving.ingress.PayloadRing`) so a million-query
        trace does not hold a million payloads; the fn must then be O(1)
        or injection lag suffers.
        """
        arrivals = np.asarray(arrivals, dtype=np.float64) * time_scale
        n = int(arrivals.size)
        payloads = ([payload_fn(i) for i in range(n)] if prebuild
                    else None)
        self.start_run()
        reqs: List[_Request] = []
        lags = np.zeros(n, dtype=np.float64)
        for i in range(n):
            t_arr = float(arrivals[i])
            while True:
                dt = t_arr - self.now()
                if dt <= 0.0:
                    break
                time.sleep(dt)
            deadline = (t_arr + slo_s * time_scale if slo_s is not None
                        else float("inf"))
            req = _Request(i, t_arr,
                           payloads[i] if prebuild else payload_fn(i),
                           deadline)
            reqs.append(req)
            self.inject(req)
            lags[i] = self.now() - t_arr
        self._note_injection_lags(lags)
        self.await_all(reqs, timeout_s)
        self.release(reqs)
        self.check_worker_failures()
        return np.array([
            np.inf if (r.t_done is None or r.shed or r.cancelled)
            else (r.t_done - r.t_arrival) / time_scale
            for r in reqs])

    # -- telemetry ---------------------------------------------------------
    def telemetry_counters(self) -> Dict[str, Dict[str, float]]:
        """Instantaneous per-stage counters (cumulative arrived/completed/
        dropped + live queue depth, in-flight, replica target) — the raw
        feed the live control loop turns into ``StageTelemetry`` deltas."""
        out: Dict[str, Dict[str, float]] = {}
        for name, st in self._stages.items():
            with st.cond:
                out[name] = {
                    "arrived": st.arrived,
                    "completed": st.completed,
                    "dropped": st.dropped,
                    "queue_depth": len(st.queue),
                    "in_flight": st.in_flight,
                    "replicas": st.target,
                }
        return out

    def batch_sizes(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        for s, st in self._stages.items():
            with st.cond:
                sizes = [b for _, b in st.batch_log]
            out[s] = np.asarray(sizes, dtype=np.int64)
        return out

    def batch_stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for s, st in self._stages.items():
            with st.cond:
                sizes = [b for _, b in st.batch_log]
            out[s] = float(np.mean(sizes)) if sizes else 0.0
        return out

    def dataplane_stats(self) -> Dict[str, DataplaneStats]:
        """Per-stage transport accounting (process backend; parent-side
        view over the pool lifetime, retired replicas included). Empty
        for the thread backend. The bench derives bytes-copied-per-
        request and lane occupancy from this."""
        out: Dict[str, DataplaneStats] = {}
        for s, st in self._stages.items():
            if st.pool is not None:
                out[s] = st.pool.stats()
        return out

    # -- shutdown ----------------------------------------------------------
    def shutdown(self, join_timeout_s: float = 5.0) -> bool:
        """Stop every worker and join it. Returns True when all worker
        threads exited within the timeout. Safe to call twice."""
        self._shutdown = True
        if self._fault_stop is not None:
            self._fault_stop.set()
        to_join: List[threading.Thread] = []
        for st in self._stages.values():
            with st.cond:
                st.stop = True
                st.cond.notify_all()
                to_join.extend(st.workers)
        deadline = time.perf_counter() + join_timeout_s
        for t in to_join:
            t.join(max(0.0, deadline - time.perf_counter()))
        stuck = [t for t in to_join if t.is_alive()]
        if stuck and any(st.pool is not None
                         for st in self._stages.values()):
            # a dispatcher past the join budget is almost always blocked
            # inside proc.run() on a wedged child: forking a
            # thread-heavy parent (e.g. once JAX has warmed its pools)
            # can deadlock the child on a lock an unforked thread held.
            # SIGKILL the worker processes — the death sentinel unblocks
            # connection.wait and the dispatcher exits via ReplicaDead.
            for st in self._stages.values():
                if st.pool is not None:
                    st.pool.kill(len(st.pool.pids()))
            for t in stuck:
                t.join(2.0)
        ok = all(not t.is_alive() for t in to_join)
        # process backend: dispatchers close their paired replicas on
        # exit; close_all reaps anything left (e.g. a dispatcher stuck
        # past the join budget) so no worker process or slab leaks
        for st in self._stages.values():
            if st.pool is not None:
                st.pool.close_all()
        return ok
