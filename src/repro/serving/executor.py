"""Real (wall-clock) pipeline executor: centralized batched queues +
thread-pool model replicas serving actual JAX models on CPU.

This is the runtime-path proof for the serving framework: the same
Pipeline/PipelineConfig the Planner emits is deployed over real queues
and real jitted models, demonstrating the three properties InferLine
requires of a serving system (§3): replica scaling at runtime, a
configurable max batch size, and a centralized batched queue per stage.

Scale is CPU-sized (tiny models, tens of QPS); the large-scale behavior
is covered by the discrete-event cluster (`repro.serving.cluster`) whose
queueing discipline this executor mirrors exactly.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.pipeline import SOURCE, Pipeline, PipelineConfig


@dataclasses.dataclass
class _Request:
    rid: int
    t_arrival: float
    payload: Any
    t_done: Optional[float] = None
    done: threading.Event = dataclasses.field(default_factory=threading.Event)


class _Stage:
    """Centralized batched queue + replica worker threads for one stage."""

    def __init__(self, name: str, fn: Callable[[List[Any]], List[Any]],
                 max_batch: int, replicas: int,
                 on_done: Callable[["_Request", Any], None]):
        self.name = name
        self.fn = fn
        self.max_batch = max_batch
        self.on_done = on_done
        self.q: "queue.Queue" = queue.Queue()
        self.workers: List[threading.Thread] = []
        self.batch_sizes: List[int] = []
        self._stop = False
        self._lock = threading.Lock()
        for _ in range(replicas):
            self.add_replica()

    def add_replica(self) -> None:
        t = threading.Thread(target=self._worker, daemon=True)
        t.start()
        self.workers.append(t)

    def _worker(self) -> None:
        while not self._stop:
            try:
                first = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            if first is None:
                return
            # batch everything already queued, up to max_batch (the
            # paper's centralized batch-at-a-time discipline)
            batch = [first]
            while len(batch) < self.max_batch:
                try:
                    item = self.q.get_nowait()
                except queue.Empty:
                    break
                if item is None:
                    self.q.put(None)
                    break
                batch.append(item)
            with self._lock:
                self.batch_sizes.append(len(batch))
            try:
                outs = self.fn([r.payload for r in batch])
            except Exception as e:  # noqa: BLE001 — a dead worker
                # deadlocks the pipeline; surface the failure per-request
                import traceback
                print(f"[executor] stage {self.name} batch failed: {e!r}")
                traceback.print_exc()
                outs = [None] * len(batch)
            for req, out in zip(batch, outs):
                self.on_done(req, out)

    def submit(self, req: _Request) -> None:
        self.q.put(req)

    def stop(self) -> None:
        self._stop = True
        for _ in self.workers:
            self.q.put(None)


class PipelineExecutor:
    """Deploys a configured pipeline over real threads and jitted models.

    Args:
      pipeline: the DAG; conditional edges are sampled per request.
      config: per-stage (hardware*, batch, replicas) — hardware is
        informational on this CPU host; batch/replicas are enforced.
      stage_fns: model_id -> callable(List[payload]) -> List[payload].

    Join semantics: a request visits a stage at most once (same cap the
    scale-factor computation uses); the first triggering parent routes it.
    """

    def __init__(self, pipeline: Pipeline, config: PipelineConfig,
                 stage_fns: Dict[str, Callable[[List[Any]], List[Any]]],
                 seed: int = 0):
        self.pipeline = pipeline
        self.config = config
        self.rng = np.random.default_rng(seed)
        self._rng_lock = threading.Lock()
        self._lock = threading.Lock()
        self._visited: Dict[int, set] = {}
        self._inflight: Dict[int, int] = {}
        self._sinks = set(pipeline.sinks())
        self._children = {s: pipeline.children(s) for s in pipeline.stages}
        self._stages: Dict[str, _Stage] = {}
        for name, stage in pipeline.stages.items():
            cfg = config[name]
            self._stages[name] = _Stage(
                name, stage_fns[stage.model_id], cfg.batch_size,
                cfg.replicas,
                on_done=lambda req, out, s=name: self._on_done(s, req, out))

    def _coin(self, p: float) -> bool:
        if p >= 1.0:
            return True
        with self._rng_lock:
            return bool(self.rng.random() < p)

    def _enqueue(self, stage: str, req: _Request) -> bool:
        with self._lock:
            seen = self._visited.setdefault(req.rid, set())
            if stage in seen:
                return False
            seen.add(stage)
            self._inflight[req.rid] = self._inflight.get(req.rid, 0) + 1
        self._stages[stage].submit(req)
        return True

    def _on_done(self, stage: str, req: _Request, out: Any) -> None:
        req.payload = out
        for e in self._children[stage]:
            if self._coin(e.probability):
                self._enqueue(e.dst, req)
        with self._lock:
            self._inflight[req.rid] -= 1
            finished = self._inflight[req.rid] == 0
        if finished:
            req.t_done = time.perf_counter()
            req.done.set()

    def inject(self, req: _Request) -> None:
        routed = False
        for e in self.pipeline.entry_edges():
            if self._coin(e.probability):
                routed |= self._enqueue(e.dst, req)
        if not routed:
            req.t_done = req.t_arrival
            req.done.set()

    def serve_trace(self, arrivals: np.ndarray, payload_fn,
                    time_scale: float = 1.0,
                    timeout_s: float = 300.0) -> np.ndarray:
        """Replay `arrivals` (seconds, scaled by `time_scale`) against the
        running pipeline; returns per-query latency (unscaled seconds)."""
        arrivals = np.asarray(arrivals, dtype=np.float64) * time_scale
        reqs: List[_Request] = []
        t0 = time.perf_counter()
        for i, t_arr in enumerate(arrivals):
            now = time.perf_counter() - t0
            if t_arr > now:
                time.sleep(t_arr - now)
            req = _Request(i, time.perf_counter(), payload_fn(i))
            reqs.append(req)
            self.inject(req)
        deadline = time.perf_counter() + timeout_s
        for req in reqs:
            req.done.wait(max(0.0, deadline - time.perf_counter()))
        return np.array([
            (r.t_done - r.t_arrival) / time_scale if r.t_done else np.inf
            for r in reqs])

    def batch_stats(self) -> Dict[str, float]:
        return {
            s: (float(np.mean(st.batch_sizes)) if st.batch_sizes else 0.0)
            for s, st in self._stages.items()
        }

    def scale(self, stage: str, replicas: int) -> None:
        """Runtime replica scaling (scale-up only on the CPU executor)."""
        cur = len(self._stages[stage].workers)
        for _ in range(replicas - cur):
            self._stages[stage].add_replica()

    def shutdown(self) -> None:
        for st in self._stages.values():
            st.stop()
