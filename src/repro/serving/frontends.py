"""Prediction-serving frontend adapters (§3, §7.4, Fig. 13).

InferLine composes with any serving framework that supports (1) runtime
replica scaling, (2) configurable max batch size, (3) a centralized
batched queue. We model two adapters with deliberately different
per-hop overhead constants, mirroring the paper's finding that TFS
carries extra RPC serialization overhead relative to Clipper.

The real (wall-clock, thread-pool) executor in ``repro.serving.executor``
consumes the same Frontend descriptors: its inter-stage hand-offs delay
a request's queue-ready instant by ``hop_delay_s`` (and the reply hop
adds one more), exactly where the simulation engine charges
``rpc_delay_s`` — so a sim<->real fidelity comparison
(``benchmarks/bench_live_loop.py``) models the same network on both
backends.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Frontend:
    name: str
    rpc_delay_s: float          # per-hop transport + dispatch
    serialization_s: float      # per-query (de)serialization at each hop

    @property
    def hop_delay_s(self) -> float:
        return self.rpc_delay_s + self.serialization_s


FRONTENDS: Dict[str, Frontend] = {
    # Clipper-like: compact binary RPC, low serialization cost.
    "clipper": Frontend("clipper", rpc_delay_s=0.0005, serialization_s=0.0001),
    # TFS-like: protobuf round-trips add measurable serialization (§7.4).
    "tfs": Frontend("tfs", rpc_delay_s=0.0005, serialization_s=0.0009),
}
