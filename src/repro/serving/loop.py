"""Closed-loop control of the REAL executor (wall-clock epoch stepping).

:class:`LiveControlLoop` is the runtime twin of
:class:`repro.sim.control.ControlLoopSession`: it serves a trace on a
:class:`~repro.serving.executor.PipelineExecutor` while sampling
:class:`~repro.sim.result.EpochTelemetry` at fixed control epochs and
feeding it to the SAME controller interface
(``step(EpochTelemetry) -> [ControlEvent]``) the co-simulation drives —
the :class:`~repro.core.tuner.ClosedLoopTuner`, the
:class:`~repro.core.tuner.OpenLoopTunerController` adapter, and
:class:`~repro.control.ScheduleController` all run unchanged against
real threads.

Telemetry is assembled with the simulator's exact window semantics
(per-stage arrived/completed/dropped deltas over ``(t0, t1]``, live
queue depth and in-service counts, pipeline-level completed/missed/
overdue/drops/p99 over the window, the streaming ingress envelope), and
each stage's ``replicas`` field is derived from the folded replica
schedule exactly as the engine derives it — so a controller cannot tell
which backend it is scaling except through the numbers themselves. The
residual sim<->real gap is measured by ``benchmarks/bench_live_loop.py``.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.control import (
    ControlEvent,
    CostAccounting,
    fold_control_event,
    replica_cost_timeline,
)
from repro.core.envelope import IncrementalEnvelope
from repro.serving.executor import PipelineExecutor, _Request
from repro.sim.result import EpochTelemetry, StageTelemetry

DEFAULT_EPOCH_S = 1.0


@dataclasses.dataclass
class LiveLoopResult(CostAccounting):
    """Outcome of one wall-clock closed-loop run — shaped like
    :class:`repro.sim.control.ClosedLoopResult` so benchmark and test
    code can compare the two backends field-for-field."""

    arrival: np.ndarray            # actual injection times (loop clock)
    latency: np.ndarray            # measured end-to-end (inf: shed/released)
    dropped: np.ndarray            # shed by an slo-drop stage
    released: int                  # unfinished at drain timeout, cancelled
    slo: float
    telemetry: List[EpochTelemetry]
    events: List[ControlEvent]
    replica_schedules: Dict[str, List[Tuple[float, int]]]
    shed_schedules: Dict[str, List[Tuple[float, float]]]
    policy_schedules: Dict[str, List[Tuple[float, str]]]
    cost_times: np.ndarray
    cost_per_hr: np.ndarray
    replica_timeline: Dict[str, List[Tuple[float, int]]]
    batch_sizes: Dict[str, np.ndarray]

    @property
    def miss_rate(self) -> float:
        if not self.latency.size:
            return 0.0
        miss = (self.latency > self.slo) | self.dropped
        return float(miss.mean())

    @property
    def attainment(self) -> float:
        return 1.0 - self.miss_rate

    def _cost_t_end_default(self) -> float:
        return float(self.arrival.max()) if self.arrival.size else 0.0

    def batch_stats(self) -> Dict[str, float]:
        return {s: (float(b.mean()) if b.size else 0.0)
                for s, b in self.batch_sizes.items()}


class LiveControlLoop:
    """Wall-clock epoch stepping of one executor + one controller.

    ``run(arrivals, controller, payload_fn)`` injects the trace in real
    time from a background thread while the main thread samples
    telemetry at every epoch boundary, invokes the controller, and lands
    its events on the executor (scale-ups activate at ``t_effective``,
    scale-downs drain, shed-margin and policy switches reprogram the
    live queues). Events are simultaneously folded into per-stage
    schedule streams with the shared :func:`repro.control
    .fold_control_event`, so the run record (cost timeline, replica
    timeline) is computed by the same code path as the simulated loops.
    """

    def __init__(self, executor: PipelineExecutor, slo: float,
                 epoch_s: float = DEFAULT_EPOCH_S,
                 service_time_s: float = 0.05,
                 envelope_max_window_s: float = 60.0,
                 drain_timeout_s: float = 30.0):
        if epoch_s <= 0:
            raise ValueError(f"epoch_s must be positive, got {epoch_s}")
        self.executor = executor
        self.pipeline = executor.pipeline
        self.config = executor.config
        self.slo = float(slo)
        self.epoch_s = float(epoch_s)
        self.service_time_s = float(service_time_s)
        self.envelope_max_window_s = float(envelope_max_window_s)
        self.drain_timeout_s = float(drain_timeout_s)

    # -- trace injection ---------------------------------------------------
    def _inject_all(self, arrivals: np.ndarray, payload_fn,
                    reqs: List[_Request], stop: threading.Event) -> None:
        ex = self.executor
        n = int(arrivals.size)
        # payloads are pre-built so payload_fn cost never eats into the
        # inter-arrival gaps at high rate
        payloads = [payload_fn(i) for i in range(n)]
        lags: List[float] = []
        for i in range(n):
            t_arr = float(arrivals[i])
            # absolute-deadline wait on the stop event: a stop (run cut
            # short by t_end) is honored IMMEDIATELY even mid-gap — no
            # sleep slicing — and never injects the arrival the
            # interrupted wait was waiting on; a late injection catches
            # up on the next arrival instead of compounding drift
            while True:
                dt = t_arr - ex.now()
                if dt <= 0.0:
                    break
                if stop.wait(dt):
                    ex._note_injection_lags(np.asarray(lags))
                    return
            if stop.is_set():
                break
            # nominal-arrival stamp: latency and the SLO deadline are
            # charged against the intended schedule, not the drifted
            # injection instant
            req = _Request(i, t_arr, payloads[i], t_arr + self.slo)
            reqs.append(req)
            ex.inject(req)
            lags.append(ex.now() - t_arr)
        ex._note_injection_lags(np.asarray(lags))

    # -- one epoch's telemetry --------------------------------------------
    def _telemetry(self, epoch: int, t0: float, t1: float,
                   reqs: List[_Request], prev: Dict[str, Dict[str, float]],
                   base_replicas: Dict[str, int],
                   sched: Dict[str, List[Tuple[float, int]]],
                   env: IncrementalEnvelope) -> EpochTelemetry:
        ex = self.executor
        # the first epoch's window is closed at both ends, matching the
        # co-simulation loop's partition of the run
        t_lo = -np.inf if epoch == 1 else t0
        counters = ex.telemetry_counters()
        fdel = ex.fault_deltas()
        stages: Dict[str, StageTelemetry] = {}
        for s, cur in counters.items():
            p = prev.get(s, {})
            # replicas exactly as the engine computes them: the fleet
            # the executor actually carried at run start (it may have
            # been scaled since deployment) plus the folded schedule's
            # deltas landed by t1
            replicas = base_replicas[s] + sum(
                d for (t, d) in sched.get(s, ()) if t <= t1)
            # alive = target minus injected-crash losses landed by t1 —
            # the capacity-loss signal failure-aware controllers react
            # to; floored at 0 (negative would read as "untracked")
            alive = max(0, replicas + sum(
                d for (t, d) in fdel.get(s, ()) if t <= t1))
            stages[s] = StageTelemetry(
                stage=s,
                arrived=int(cur["arrived"] - p.get("arrived", 0)),
                completed=int(cur["completed"] - p.get("completed", 0)),
                dropped=int(cur["dropped"] - p.get("dropped", 0)),
                queue_depth=int(cur["queue_depth"]),
                in_flight=int(cur["in_flight"]),
                replicas=replicas, alive=alive)
        prev.clear()
        prev.update(counters)

        # pipeline-level windowed accounting (the sim loop's semantics)
        snap = list(reqs)
        arr = np.asarray([r.t_arrival for r in snap])
        hi = int(np.searchsorted(arr, t1, side="right"))
        lo = 0 if epoch == 1 else int(np.searchsorted(arr, t0,
                                                      side="right"))
        prefix = arr[:hi]
        env.extend(arr[env.n:hi])
        completed = missed = overdue = drops = 0
        lats: List[float] = []
        for r in snap:
            finished = r.done.is_set() and not (r.shed or r.cancelled)
            comp = r.t_done if (finished and r.t_done is not None) \
                else np.inf
            ddl_in_win = t_lo < r.deadline <= t1
            if np.isfinite(comp) and t_lo < comp <= t1:
                completed += 1
                lat = comp - r.t_arrival
                lats.append(lat)
                if ddl_in_win and lat > self.slo:
                    missed += 1
            if ddl_in_win and (not np.isfinite(comp) or comp > t1):
                overdue += 1
            if r.shed and ddl_in_win:
                drops += 1
        p99 = float(np.percentile(np.asarray(lats), 99.0)) if lats \
            else float("nan")
        return EpochTelemetry(
            epoch=epoch, t_start=t0, t_end=t1, ingress=hi - lo,
            ingress_prefix=prefix, observed_envelope=env.snapshot(),
            stages=stages, completed=completed, missed=missed,
            overdue=overdue, drops=drops, p99_s=p99)

    # -- the loop ----------------------------------------------------------
    def run(self, arrivals: np.ndarray, controller, payload_fn,
            t_end: Optional[float] = None) -> LiveLoopResult:
        arr_nominal = np.asarray(arrivals, dtype=np.float64)
        if arr_nominal.size > 1 and np.any(np.diff(arr_nominal) < 0):
            raise ValueError("arrivals must be sorted ascending")
        t_stop = t_end if t_end is not None else (
            float(arr_nominal.max()) if arr_nominal.size else 0.0)
        ex = self.executor
        ex.start_run()
        # the run's replica baseline is the fleet the executor actually
        # carries NOW (it may have been scaled since deployment) — the
        # cost/replica timelines and telemetry all start from it
        base_replicas = {s: ex.replica_target(s)
                         for s in self.pipeline.stages}
        run_config = self.config.copy()
        for s, k in base_replicas.items():
            run_config[s].replicas = k
        reqs: List[_Request] = []
        stop = threading.Event()
        injector = threading.Thread(
            target=self._inject_all, args=(arr_nominal, payload_fn, reqs,
                                           stop),
            daemon=True)
        sched: Dict[str, List[Tuple[float, int]]] = {
            s: [] for s in self.pipeline.stages}
        shed: Dict[str, List[Tuple[float, float]]] = {}
        pols: Dict[str, List[Tuple[float, str]]] = {}
        telemetry: List[EpochTelemetry] = []
        events: List[ControlEvent] = []
        deferred: List[ControlEvent] = []
        prev_counters: Dict[str, Dict[str, float]] = {}
        env = IncrementalEnvelope(self.service_time_s,
                                  self.envelope_max_window_s)
        # precise interruptible timer for the epoch loop: one wakeup per
        # deadline (epoch boundary or earliest deferred event) instead of
        # 20 Hz sleep slices, and a real worker crash sets it so the run
        # fails immediately rather than at the next boundary
        wake = threading.Event()
        ex.on_worker_failure = wake.set
        injector.start()
        try:
            epoch = 0
            t0 = 0.0
            t = self.epoch_s
            while t <= t_stop + 1e-9:
                # event-timed ticks land deferred events (future-dated
                # downs/sheds/policy switches) at their t_effective;
                # scale-up activation is handled inside the executor
                while True:
                    now = ex.now()
                    deferred = [ev for ev in deferred
                                if not self._apply_if_due(ev, now)]
                    if now >= t:
                        break
                    self._check_worker_failures()
                    nxt = min([t] + [ev.t_effective for ev in deferred])
                    # epsilon past the deadline so the due-checks above
                    # see it due on the next pass
                    wake.wait(max(nxt - now, 0.0) + 1e-4)
                    wake.clear()
                epoch += 1
                # surface real worker crashes within one epoch — a dead
                # fleet must fail the run now, not at drain time
                self._check_worker_failures()
                tele = self._telemetry(epoch, t0, t, reqs, prev_counters,
                                       base_replicas, sched, env)
                telemetry.append(tele)
                for ev in controller.step(tele) or ():
                    # identical contract to the co-simulation loop
                    fold_control_event(ev, self.pipeline.stages, t, sched,
                                       shed, pols)
                    events.append(ev)
                    if not self._apply_if_due(ev, ex.now()):
                        deferred.append(ev)
                t0 = t
                t += self.epoch_s
        finally:
            stop.set()
            ex.on_worker_failure = None
        injector.join()
        for ev in deferred:                    # land stragglers
            self.executor.apply_control_event(ev)

        # drain: wait for the tail (requests stranded on a starved /
        # all-dead stage release promptly), then cancel anything stuck
        starved = ex.await_all(reqs, self.drain_timeout_s)
        released = ex.release(reqs) + starved
        self._check_worker_failures()

        lat = np.array([
            np.inf if (r.t_done is None or r.shed or r.cancelled)
            else r.t_done - r.t_arrival
            for r in reqs])
        dropped = np.array([r.shed for r in reqs], dtype=bool)
        times, costs, timeline = replica_cost_timeline(
            self.pipeline, run_config, sched, t_stop)
        return LiveLoopResult(
            arrival=np.asarray([r.t_arrival for r in reqs]),
            latency=lat, dropped=dropped, released=released, slo=self.slo,
            telemetry=telemetry, events=events,
            replica_schedules=sched, shed_schedules=shed,
            policy_schedules=pols, cost_times=times, cost_per_hr=costs,
            replica_timeline=timeline, batch_sizes=ex.batch_sizes())

    def _check_worker_failures(self) -> None:
        """Raise if any worker thread crashed (uncaught exception — an
        injected fault never registers here). Checked at every epoch-
        loop wakeup — a crash sets the wake event, so the run fails
        immediately — and again after drain."""
        self.executor.check_worker_failures("the closed-loop run")

    def _apply_if_due(self, ev: ControlEvent, now: float) -> bool:
        """Scale-ups apply immediately (the executor defers activation to
        ``t_effective`` itself); everything else waits until due."""
        if ev.kind != "up" and ev.t_effective > now + 1e-6:
            return False
        self.executor.apply_control_event(ev)
        return True
