"""Asyncio open-loop ingress: absolute-deadline trace injection.

The serial ``serve_trace`` injector is one blocking loop — at high
rates, per-request Python overhead between sleeps becomes the arrival
process. This frontend replaces it for open-loop experiments at
10–100x that scale: ``clients`` coroutines share one event loop, each
owning a round-robin substream of the trace and sleeping toward the
*absolute* instant ``start + t_arr`` (a Locust-style open-loop rig —
a late injection catches up on the next arrival instead of compounding
drift). Requests are stamped with their nominal arrival, so measured
latency and deadlines are charged against the intended schedule, and
per-request injection lag is recorded (:class:`IngressStats`, also
mirrored into :meth:`PipelineExecutor.injection_stats`).

The executor's worker threads (or worker processes, with
``backend="process"``) are untouched: coroutines only sleep, build
nothing (payloads are pre-built), and call the thread-safe
:meth:`PipelineExecutor.inject`. Completion is awaited after the whole
trace is in, via the executor's starvation-aware drain.
"""

from __future__ import annotations

import asyncio
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.executor import PipelineExecutor, _Request

__all__ = ["AsyncIngress", "IngressStats"]


@dataclasses.dataclass
class IngressStats:
    """Injection fidelity of one open-loop trace replay."""

    lag_s: np.ndarray           # per-request injection lag (seconds)
    injected: int
    clients: int

    @property
    def max_lag_s(self) -> float:
        return float(self.lag_s.max()) if self.lag_s.size else 0.0

    @property
    def p99_lag_s(self) -> float:
        return (float(np.percentile(self.lag_s, 99.0))
                if self.lag_s.size else 0.0)

    @property
    def mean_lag_s(self) -> float:
        return float(self.lag_s.mean()) if self.lag_s.size else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "injected": int(self.injected),
            "clients": int(self.clients),
            "max_lag_s": self.max_lag_s,
            "p99_lag_s": self.p99_lag_s,
            "mean_lag_s": self.mean_lag_s,
        }


class AsyncIngress:
    """Open-loop asyncio frontend over a :class:`PipelineExecutor`.

    Args:
      executor: the (already constructed) executor to inject into.
      clients: number of concurrent client coroutines the trace is
        round-robined across. More clients = less per-arrival work per
        coroutine; the default comfortably sustains hundreds of qps.
    """

    def __init__(self, executor: PipelineExecutor, clients: int = 64):
        if clients < 1:
            raise ValueError("clients must be >= 1")
        self.executor = executor
        self.clients = int(clients)

    def serve_trace(self, arrivals: np.ndarray, payload_fn,
                    time_scale: float = 1.0,
                    timeout_s: float = 300.0,
                    slo_s: Optional[float] = None,
                    ) -> Tuple[np.ndarray, IngressStats]:
        """Drop-in for :meth:`PipelineExecutor.serve_trace`, returning
        ``(latencies, IngressStats)``. Semantics match the serial
        injector (nominal-arrival stamps, release-on-timeout, starved-
        stage fast release, worker-failure surfacing) — only the
        injection engine differs."""
        ex = self.executor
        arrivals = np.asarray(arrivals, dtype=np.float64) * time_scale
        n = int(arrivals.size)
        payloads = [payload_fn(i) for i in range(n)]
        deadlines = (arrivals + slo_s * time_scale if slo_s is not None
                     else np.full(n, np.inf))
        reqs: List[Optional[_Request]] = [None] * n
        lags = np.zeros(n, dtype=np.float64)
        ex.start_run()
        asyncio.run(self._drive(arrivals, payloads, deadlines, reqs, lags))
        ex._note_injection_lags(lags)
        stats = IngressStats(lag_s=lags, injected=n,
                             clients=min(self.clients, max(n, 1)))
        live = [r for r in reqs if r is not None]
        ex.await_all(live, timeout_s)
        ex.release(live)
        ex.check_worker_failures("the ingress run")
        lat = np.array([
            np.inf if (r is None or r.t_done is None or r.shed
                       or r.cancelled)
            else (r.t_done - r.t_arrival) / time_scale
            for r in reqs])
        return lat, stats

    async def _drive(self, arrivals: np.ndarray, payloads: List[Any],
                     deadlines: np.ndarray,
                     reqs: List[Optional[_Request]],
                     lags: np.ndarray) -> None:
        ex = self.executor
        n = int(arrivals.size)
        if n == 0:
            return
        loop = asyncio.get_running_loop()
        # map executor-clock instants onto the event-loop clock once;
        # every client sleeps toward absolute event-loop deadlines
        off = loop.time() - ex.now()
        k = min(self.clients, n)

        async def client(c: int) -> None:
            for i in range(c, n, k):
                target = arrivals[i] + off
                while True:
                    delay = target - loop.time()
                    if delay <= 0.0:
                        break
                    await asyncio.sleep(delay)
                req = _Request(i, float(arrivals[i]), payloads[i],
                               float(deadlines[i]))
                reqs[i] = req
                ex.inject(req)
                lags[i] = ex.now() - arrivals[i]

        await asyncio.gather(*(client(c) for c in range(k)))
