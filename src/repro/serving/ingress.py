"""Asyncio open-loop ingress: absolute-deadline trace injection.

The serial ``serve_trace`` injector is one blocking loop — at high
rates, per-request Python overhead between sleeps becomes the arrival
process. This frontend replaces it for open-loop experiments at
10–100x that scale: ``clients`` coroutines share one event loop, each
owning a round-robin substream of the trace and sleeping toward the
*absolute* instant ``start + t_arr`` (a Locust-style open-loop rig —
a late injection catches up on the next arrival instead of compounding
drift). Requests are stamped with their nominal arrival, so measured
latency and deadlines are charged against the intended schedule, and
per-request injection lag is recorded (:class:`IngressStats`, also
mirrored into :meth:`PipelineExecutor.injection_stats`).

The executor's worker threads (or worker processes, with
``backend="process"``) are untouched: coroutines only sleep, build
nothing (payloads are pre-built), and call the thread-safe
:meth:`PipelineExecutor.inject`. Completion is awaited after the whole
trace is in, via the executor's starvation-aware drain.
"""

from __future__ import annotations

import asyncio
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.executor import PipelineExecutor, _Request

__all__ = ["AsyncIngress", "IngressStats", "PayloadRing"]


class PayloadRing:
    """Reusable pre-registered payload buffers for trace injection.

    A million-query tensor trace cannot materialize a million payloads
    up front; building a fresh array per arrival puts the allocator on
    the injection hot path instead. This ring pre-builds a small pool
    of payload buffers ONCE and hands them out round-robin — an O(1)
    ``payload_fn`` for ``serve_trace(..., prebuild=False)`` on either
    injector. The same buffer objects recur across requests, which is
    exactly what the zero-copy data plane wants: the dispatcher encodes
    them straight into the slab, so no per-request payload allocation
    happens anywhere on the injection path.

    The ring must be deep enough that a buffer is not rewritten by the
    caller while an earlier request still references it; with read-only
    replay traces (the common case) any depth >= 1 is safe because the
    serving stack never mutates request payloads.
    """

    def __init__(self, slots: List[Any]):
        if not slots:
            raise ValueError("PayloadRing needs at least one slot")
        self._slots = slots

    @classmethod
    def filled(cls, build_fn: Callable[[int], Any],
               slots: int = 8) -> "PayloadRing":
        """Pre-build `slots` payloads with ``build_fn(slot_index)``."""
        return cls([build_fn(i) for i in range(int(slots))])

    def __len__(self) -> int:
        return len(self._slots)

    def __call__(self, i: int) -> Any:
        return self._slots[i % len(self._slots)]


@dataclasses.dataclass
class IngressStats:
    """Injection fidelity of one open-loop trace replay."""

    lag_s: np.ndarray           # per-request injection lag (seconds)
    injected: int
    clients: int

    @property
    def max_lag_s(self) -> float:
        return float(self.lag_s.max()) if self.lag_s.size else 0.0

    @property
    def p99_lag_s(self) -> float:
        return (float(np.percentile(self.lag_s, 99.0))
                if self.lag_s.size else 0.0)

    @property
    def mean_lag_s(self) -> float:
        return float(self.lag_s.mean()) if self.lag_s.size else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "injected": int(self.injected),
            "clients": int(self.clients),
            "max_lag_s": self.max_lag_s,
            "p99_lag_s": self.p99_lag_s,
            "mean_lag_s": self.mean_lag_s,
        }


class AsyncIngress:
    """Open-loop asyncio frontend over a :class:`PipelineExecutor`.

    Args:
      executor: the (already constructed) executor to inject into.
      clients: number of concurrent client coroutines the trace is
        round-robined across. More clients = less per-arrival work per
        coroutine; the default comfortably sustains hundreds of qps.
    """

    def __init__(self, executor: PipelineExecutor, clients: int = 64):
        if clients < 1:
            raise ValueError("clients must be >= 1")
        self.executor = executor
        self.clients = int(clients)

    def serve_trace(self, arrivals: np.ndarray, payload_fn,
                    time_scale: float = 1.0,
                    timeout_s: float = 300.0,
                    slo_s: Optional[float] = None,
                    prebuild: bool = True,
                    ) -> Tuple[np.ndarray, IngressStats]:
        """Drop-in for :meth:`PipelineExecutor.serve_trace`, returning
        ``(latencies, IngressStats)``. Semantics match the serial
        injector (nominal-arrival stamps, release-on-timeout, starved-
        stage fast release, worker-failure surfacing) — only the
        injection engine differs. ``prebuild=False`` calls
        ``payload_fn(i)`` at injection time — pair with a
        :class:`PayloadRing` so the fn stays O(1)."""
        ex = self.executor
        arrivals = np.asarray(arrivals, dtype=np.float64) * time_scale
        n = int(arrivals.size)
        payloads = ([payload_fn(i) for i in range(n)] if prebuild
                    else payload_fn)
        deadlines = (arrivals + slo_s * time_scale if slo_s is not None
                     else np.full(n, np.inf))
        reqs: List[Optional[_Request]] = [None] * n
        lags = np.zeros(n, dtype=np.float64)
        ex.start_run()
        asyncio.run(self._drive(arrivals, payloads, deadlines, reqs, lags))
        ex._note_injection_lags(lags)
        stats = IngressStats(lag_s=lags, injected=n,
                             clients=min(self.clients, max(n, 1)))
        live = [r for r in reqs if r is not None]
        ex.await_all(live, timeout_s)
        ex.release(live)
        ex.check_worker_failures("the ingress run")
        lat = np.array([
            np.inf if (r is None or r.t_done is None or r.shed
                       or r.cancelled)
            else (r.t_done - r.t_arrival) / time_scale
            for r in reqs])
        return lat, stats

    async def _drive(self, arrivals: np.ndarray, payloads: Any,
                     deadlines: np.ndarray,
                     reqs: List[Optional[_Request]],
                     lags: np.ndarray) -> None:
        ex = self.executor
        n = int(arrivals.size)
        if n == 0:
            return
        loop = asyncio.get_running_loop()
        # map executor-clock instants onto the event-loop clock once;
        # every client sleeps toward absolute event-loop deadlines
        off = loop.time() - ex.now()
        k = min(self.clients, n)
        # prebuild=True hands a list (index it); prebuild=False hands
        # the payload_fn itself (call it at injection time)
        get = (payloads.__getitem__ if isinstance(payloads, list)
               else payloads)

        async def client(c: int) -> None:
            for i in range(c, n, k):
                target = arrivals[i] + off
                while True:
                    delay = target - loop.time()
                    if delay <= 0.0:
                        break
                    await asyncio.sleep(delay)
                req = _Request(i, float(arrivals[i]), get(i),
                               float(deadlines[i]))
                reqs[i] = req
                ex.inject(req)
                lags[i] = ex.now() - arrivals[i]

        await asyncio.gather(*(client(c) for c in range(k)))
