"""Typed zero-copy slab codec for the process-backed serving data plane.

The PR 9 transport pickled every batch through the shared-memory slab:
``pickle.dumps`` (copy 1) -> slab write (copy 2) -> ``bytes(view)``
(copy 3) -> ``pickle.loads`` (copy 4), per direction. At tensor payload
sizes the data plane, not the model, becomes the bottleneck stage. This
module replaces serialization with a *typed header + raw bytes* layout
so array payloads cross the slab with exactly one copy per direction
and are **consumed as zero-copy numpy views** on the receiving side.

Slot layout (one "slot" = one ring buffer inside the slab)::

    +--------+---------------------+--------- 64-byte aligned ---------+
    | header | record table        | raw tensor bytes ...              |
    +--------+---------------------+-----------------------------------+

    header  : magic u32 | kind u8 | count u32 | nrec u32 | data_end u64
    record  : dtype 16s | flags u8 | ndim u8 | pad 6x | shape 8*u64
              | offset u64 | nbytes u64

Two kinds:

* ``KIND_TYPED`` — every payload is a ``np.ndarray``: the record table
  gives (dtype, shape, offset) per item and the bytes live in the slot.
  A homogeneous batch (same dtype+shape) collapses to ONE stacked
  record (``FLAG_STACKED``): the encoder assembles the batch directly
  into a single ``(n, *shape)`` slab view (``np.stack(..., out=view)``,
  the vectorized in-slab assembly path) and the decoder hands back the
  rows as views of one block.
* ``KIND_PICKLE`` — the fallback lane for anything that is not an
  array (or an array the typed lane cannot express, e.g. object/
  structured dtypes): ``pickle.dumps`` written after the header.
  Non-standard-but-fixed-width dtypes (``ml_dtypes.bfloat16``,
  ``float8_*``) stay on the typed lane — they are encoded by *name*
  and resolved through :data:`_EXT_DTYPES` on decode.

A batch that does not fit the slot raises :class:`SlotOverflow` (the
pre-pickled bytes ride on the exception so the chunked-slab fallback in
:mod:`repro.serving.procpool` never pickles twice).

Decoding with ``copy=False`` returns views aliasing the slot — the
zero-copy worker-side path; ``copy=True`` materializes owned arrays
(the dispatcher-side path: the slot is reused for the next batch as
soon as ownership hands back, so responses must not alias it).

Every encode/decode updates a :class:`DataplaneStats`, the accounting
``benchmarks/bench_dataplane.py`` reports as bytes-copied-per-request.
"""

from __future__ import annotations

import dataclasses
import pickle
import struct
from typing import Any, List, Optional, Sequence

import numpy as np

__all__ = [
    "DataplaneStats",
    "SlotOverflow",
    "decode_batch",
    "encode_batch",
    "slot_capacity",
]

MAGIC = 0x0DA7A1A7
KIND_TYPED = 1
KIND_PICKLE = 2
FLAG_STACKED = 1

_ALIGN = 64
MAX_NDIM = 8
_DTYPE_CHARS = 16

_HEADER = struct.Struct("<IBIIQ")                 # magic kind count nrec end
_RECORD = struct.Struct(f"<{_DTYPE_CHARS}sBB6x{MAX_NDIM}QQQ")


class SlotOverflow(Exception):
    """The batch does not fit the slot; ``data`` carries the pickled
    bytes when the pickle lane already serialized (chunked fallback
    reuses them instead of pickling twice)."""

    def __init__(self, needed: int, capacity: int,
                 data: Optional[bytes] = None):
        super().__init__(f"batch needs {needed} B > slot capacity "
                         f"{capacity} B")
        self.needed = needed
        self.capacity = capacity
        self.data = data


@dataclasses.dataclass
class DataplaneStats:
    """Per-channel transport accounting (one endpoint's view)."""

    typed_batches: int = 0          # batches on the typed zero-copy lane
    pickle_batches: int = 0         # batches on the pickle fallback lane
    chunk_messages: int = 0         # oversize chunk hops through the slab
    inline_messages: int = 0        # legacy oversize inline-pipe hops
    bytes_copied: int = 0           # raw bytes memcpy'd into/out of slabs
    pickle_bytes: int = 0           # bytes serialized through pickle
    payload_bytes: int = 0          # logical tensor bytes transported

    def add(self, other: "DataplaneStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _ext_dtypes() -> dict:
    """Name -> dtype for fixed-width extension dtypes (ml_dtypes ships
    with jax; absence just narrows the typed lane to standard dtypes)."""
    out: dict = {}
    try:
        import ml_dtypes
    except ImportError:                           # pragma: no cover
        return out
    for name in ("bfloat16", "float8_e4m3fn", "float8_e5m2", "float4_e2m1fn",
                 "float8_e4m3", "float8_e3m4", "int4", "uint4"):
        t = getattr(ml_dtypes, name, None)
        if t is not None:
            out[name] = np.dtype(t)
    return out


_EXT_DTYPES = _ext_dtypes()


def _dtype_token(dt: np.dtype) -> Optional[bytes]:
    """Round-trippable <= 16-char token for `dt`, or None (pickle lane).

    Standard dtypes use ``dt.str`` (endianness included); extension
    dtypes whose ``.str`` degrades to a raw void (e.g. bfloat16 ->
    ``<V2``) are encoded by *name* and resolved via the registry."""
    if dt.hasobject or dt.names is not None or dt.itemsize == 0:
        return None
    try:
        if np.dtype(dt.str) == dt:
            tok = dt.str
        else:
            raise TypeError
    except TypeError:
        if _EXT_DTYPES.get(dt.name) != dt:
            return None
        tok = dt.name
    raw = tok.encode("ascii")
    return raw if len(raw) <= _DTYPE_CHARS else None


def _resolve_dtype(token: bytes) -> np.dtype:
    tok = token.rstrip(b"\x00").decode("ascii")
    try:
        dt = np.dtype(tok)
        if dt.name != tok or tok in _EXT_DTYPES:
            # name-coded extension dtype shadowed by a builtin parse
            dt = _EXT_DTYPES.get(tok, dt)
        return dt
    except TypeError:
        return _EXT_DTYPES[tok]


def _align(n: int) -> int:
    return (n + _ALIGN - 1) & ~(_ALIGN - 1)


def slot_capacity(slot: memoryview) -> int:
    return len(slot)


def _typed_plan(payloads: Sequence[Any]):
    """Classify the batch for the typed lane: list of contiguous-layout
    (dtype, shape, nbytes) specs, or None -> pickle lane."""
    if not payloads:
        return None
    specs = []
    for p in payloads:
        if not isinstance(p, np.ndarray):
            return None
        tok = _dtype_token(p.dtype)
        if tok is None or p.ndim > MAX_NDIM:
            return None
        specs.append((p, tok))
    return specs


def _slot_view(slot: memoryview, dt: np.dtype, shape, offset: int):
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    return np.frombuffer(slot, dtype=dt, count=count,
                         offset=offset).reshape(shape)


def encode_batch(slot: memoryview, payloads: Sequence[Any],
                 stats: Optional[DataplaneStats] = None,
                 typed: bool = True,
                 guard: Optional[np.ndarray] = None) -> int:
    """Write one batch into `slot`; returns bytes used.

    ``typed=False`` forces the pickle lane (the legacy-transport compat
    mode). ``guard`` is a uint8 view over the slot's memory: any payload
    aliasing it (a worker echoing its zero-copy input views back as
    outputs) is copied out first, so the in-place header/data writes can
    never corrupt bytes they are still reading. Raises
    :class:`SlotOverflow` when the batch cannot fit.
    """
    cap = len(slot)
    specs = _typed_plan(payloads) if typed else None
    if specs is None:
        data = pickle.dumps(payloads, protocol=pickle.HIGHEST_PROTOCOL)
        need = _HEADER.size + len(data)
        if need > cap:
            raise SlotOverflow(need, cap, data=data)
        _HEADER.pack_into(slot, 0, MAGIC, KIND_PICKLE, len(payloads), 0,
                          need)
        slot[_HEADER.size:need] = data
        if stats is not None:
            stats.pickle_batches += 1
            stats.pickle_bytes += len(data)
            stats.bytes_copied += len(data)
        return need

    n = len(specs)
    first, first_tok = specs[0]
    stacked = (n > 1 and all(
        tok == first_tok and p.shape == first.shape for p, tok in specs))
    nrec = 1 if stacked else n
    data_off = _align(_HEADER.size + nrec * _RECORD.size)
    total_payload = sum(p.nbytes for p, _ in specs)
    need = data_off + total_payload
    if need > cap:
        raise SlotOverflow(need, cap)

    if guard is not None:
        payload_arrs = [p for p, _ in specs]
        for i, p in enumerate(payload_arrs):
            # bounds-overlap check only (never the exact-overlap
            # solver); a false positive just costs one defensive copy
            if p.nbytes and np.may_share_memory(p, guard):
                payload_arrs[i] = p.copy()
        specs = [(p, tok) for p, (_, tok) in zip(payload_arrs, specs)]

    off = data_off
    if stacked:
        shape = (n,) + first.shape
        _RECORD.pack_into(
            slot, _HEADER.size, first_tok, FLAG_STACKED, len(shape),
            *shape, *((0,) * (MAX_NDIM - len(shape))), off, total_payload)
        view = _slot_view(slot, specs[0][0].dtype, shape, off)
        np.stack([p for p, _ in specs], out=view)
        off += total_payload
    else:
        rec_off = _HEADER.size
        for p, tok in specs:
            _RECORD.pack_into(
                slot, rec_off, tok, 0, p.ndim, *p.shape,
                *((0,) * (MAX_NDIM - p.ndim)), off, p.nbytes)
            if p.nbytes:
                view = _slot_view(slot, p.dtype, p.shape, off)
                np.copyto(view, p, casting="no")
            off += p.nbytes
            rec_off += _RECORD.size
    _HEADER.pack_into(slot, 0, MAGIC, KIND_TYPED, n, nrec, off)
    if stats is not None:
        stats.typed_batches += 1
        stats.bytes_copied += total_payload
        stats.payload_bytes += total_payload
    return need


def decode_batch(slot: memoryview, copy: bool,
                 stats: Optional[DataplaneStats] = None) -> List[Any]:
    """Read one batch out of `slot`.

    ``copy=False`` returns arrays aliasing the slot (the worker-side
    zero-copy path — valid only while this endpoint owns the buffer);
    ``copy=True`` returns owned arrays (the dispatcher-side path)."""
    magic, kind, count, nrec, end = _HEADER.unpack_from(slot, 0)
    if magic != MAGIC:
        raise ValueError(f"corrupt slot header (magic {magic:#x})")
    if kind == KIND_PICKLE:
        data = bytes(slot[_HEADER.size:end])
        if stats is not None:
            stats.bytes_copied += len(data)
            stats.pickle_bytes += len(data)
        return pickle.loads(data)

    out: List[Any] = []
    rec_off = _HEADER.size
    for _ in range(nrec):
        tok, flags, ndim, *rest = _RECORD.unpack_from(slot, rec_off)
        shape = tuple(rest[:ndim])
        off, nbytes = rest[MAX_NDIM], rest[MAX_NDIM + 1]
        dt = _resolve_dtype(tok)
        view = _slot_view(slot, dt, shape, off)
        if copy:
            view = view.copy()
            if stats is not None:
                stats.bytes_copied += nbytes
        if stats is not None:
            stats.payload_bytes += nbytes
        if flags & FLAG_STACKED:
            # rows: views of one block, no copy. Indexed with `...` so
            # 0-d rows stay ndarrays (plain iteration would scalar-ify)
            out.extend(view[i, ...] for i in range(view.shape[0]))
        else:
            out.append(view)
        rec_off += _RECORD.size
    return out
