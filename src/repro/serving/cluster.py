"""Live-cluster simulation: serve a trace with a high-frequency tuner in
the loop (§5, §7.1-7.3).

The Tuner's decisions are a pure function of the ingress arrival process
(traffic envelopes + plan-time constants), so the full scaling schedule is
computed by streaming the trace through the tuner first; the resulting
per-stage replica schedules are then handed to the unified simulation
engine (:mod:`repro.sim` — the same core behind the Estimator and the
Planner search), which simulates every queue/batch/replica interaction.
Replica activation delay (5 s) and scale-down draining are modeled inside
the engine, and per-stage queueing policies (EDF, SLO-aware shedding)
apply to live runs exactly as they do to planning simulations.

Outputs include the per-query latencies AND the cost timeline (replica
counts integrate to $-cost over the run), which is what Figs. 6/7/10-12
plot.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control import CostAccounting, replica_cost_timeline
from repro.core.pipeline import Pipeline, PipelineConfig
from repro.core.profiler import ProfileStore
from repro.serving.frontends import FRONTENDS, Frontend
from repro.sim import SimEngine, SimResult


@dataclasses.dataclass
class LiveRunResult(CostAccounting):
    sim: SimResult
    slo: float
    # cost timeline: (times, $/hr at that time); integrate for total $
    # (total_cost/mean_cost_per_hr come from the shared CostAccounting
    # mixin; degenerate empty timelines cost 0).
    cost_times: np.ndarray
    cost_per_hr: np.ndarray
    replica_timeline: Dict[str, List[Tuple[float, int]]]

    @property
    def miss_rate(self) -> float:
        return self.sim.slo_miss_rate(self.slo)

    @property
    def attainment(self) -> float:
        return 1.0 - self.miss_rate

    def _cost_t_end_default(self) -> float:
        return float(self.sim.arrival.max()) if self.sim.arrival.size else 0.0


class LiveClusterSim:
    """Simulate live serving of `arrivals` under a scaling controller."""

    def __init__(self, pipeline: Pipeline, profiles: ProfileStore,
                 config: PipelineConfig, slo: float,
                 frontend: Frontend = FRONTENDS["clipper"]):
        self.pipeline = pipeline
        self.profiles = profiles
        self.config = config
        self.slo = slo
        self.frontend = frontend
        self.engine = SimEngine(pipeline, profiles,
                                rpc_delay_s=frontend.hop_delay_s)

    def _cost_timeline(
        self,
        schedules: Dict[str, Sequence[Tuple[float, int]]],
        t_end: float,
    ) -> Tuple[np.ndarray, np.ndarray, Dict[str, List[Tuple[float, int]]]]:
        # shared with the closed-loop runner so open- and closed-loop
        # cost comparisons integrate the same step function
        return replica_cost_timeline(self.pipeline, self.config,
                                     schedules, t_end)

    def run(
        self,
        arrivals: np.ndarray,
        schedule_fn: Optional[Callable[[np.ndarray], Dict[str, List[Tuple[float, int]]]]] = None,
    ) -> LiveRunResult:
        """Serve the trace; `schedule_fn(arrivals)` produces the scaling
        schedule (e.g. `run_tuner_offline` partial). None = static config."""
        arrivals = np.asarray(arrivals, dtype=np.float64)
        schedules = schedule_fn(arrivals) if schedule_fn is not None else {}
        # slo_s feeds per-query deadlines to deadline-aware stage policies
        # (edf / slo-drop); the paper's fifo stages ignore it.
        sim = self.engine.simulate(self.config, arrivals,
                                   replica_schedules=schedules or None,
                                   slo_s=self.slo)
        t_end = float(arrivals.max()) if arrivals.size else 0.0
        times, costs, timeline = self._cost_timeline(schedules, t_end)
        return LiveRunResult(sim, self.slo, times, costs, timeline)
