from repro.serving.cluster import LiveClusterSim, LiveRunResult  # noqa: F401
from repro.serving.frontends import FRONTENDS, Frontend  # noqa: F401
