from repro.serving.cluster import LiveClusterSim, LiveRunResult  # noqa: F401
from repro.serving.executor import PipelineExecutor  # noqa: F401
from repro.serving.frontends import FRONTENDS, Frontend  # noqa: F401
from repro.serving.ingress import AsyncIngress, IngressStats  # noqa: F401
from repro.serving.loop import LiveControlLoop, LiveLoopResult  # noqa: F401
from repro.serving.procpool import (  # noqa: F401
    ProcessReplicaPool,
    ProcReplica,
    ReplicaDead,
)
