from repro.serving.cluster import LiveClusterSim, LiveRunResult  # noqa: F401
from repro.serving.dataplane import (  # noqa: F401
    DataplaneStats,
    SlotOverflow,
    decode_batch,
    encode_batch,
)
from repro.serving.executor import PipelineExecutor  # noqa: F401
from repro.serving.frontends import FRONTENDS, Frontend  # noqa: F401
from repro.serving.ingress import (  # noqa: F401
    AsyncIngress,
    IngressStats,
    PayloadRing,
)
from repro.serving.loop import LiveControlLoop, LiveLoopResult  # noqa: F401
from repro.serving.procpool import (  # noqa: F401
    ProcessReplicaPool,
    ProcReplica,
    ReplicaDead,
    StageWorkerError,
    register_worker_fn,
    resolve_worker_fn,
)
