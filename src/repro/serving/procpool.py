"""Process-backed replica pool: worker OS processes behind the LiveQueue.

Breaks the GIL ceiling for the live executor. Batch formation stays
exactly where it was — dispatcher *threads* inside
:class:`~repro.serving.executor.PipelineExecutor` holding the per-stage
``LiveQueue`` under its condition variable — but with
``backend="process"`` each dispatcher is paired with a
:class:`ProcReplica`: a worker process that executes the stage fn, fed
through a shared-memory **ring** plus a control pipe. The
``PipelineExecutor`` / ``LiveControlLoop`` / ``ClosedLoopTuner`` and the
PR 8 fault machinery are unchanged by construction: the queue contract,
retry/hedging, and the AND-join all live parent-side, and an injected
crash SIGKILLs a real OS process (the paired dispatcher observes the
death and requeues every in-flight batch, exactly like the thread
backend's ``kill_pending`` path).

Transport (the zero-copy data plane, ISSUE 10)
----------------------------------------------

The slab is split into ``ring_depth`` equal buffers (default 2 —
double-buffered). Each buffer independently follows the ``handoff``
ownership discipline LOCK01 checks: ownership of buffer *i* alternates
between the two endpoints via the pipe messages that name it — whoever
just received a message for buffer *i* owns it until it sends the next
message naming it. With two buffers the dispatcher assembles the next
batch into buffer B **while the worker computes on buffer A** — the
overlapped dispatch/compute path driven by
``PipelineExecutor._dispatch_loop_proc``.

Message vocabulary (pipe payloads are tiny metadata tuples; tensor
bytes only ever travel through the slab)::

    parent -> child   ("run", buf)          batch encoded in buffer buf
                      ("chunk", tag, buf, nbytes, last)   oversize lane
                      ("ack", buf)          chunk flow control
                      ("quit",)
    child -> parent   ("ready",)            spawn handshake
                      ("ok", buf)           response encoded in-place
                      ("err", buf, repr)    stage fn raised; buf returns
                      ("chunk"/"ack", ...)  oversize lane, symmetric

* ``transport="ring"`` (default): batches are encoded with the typed
  zero-copy codec (:mod:`repro.serving.dataplane`) — array payloads are
  written as raw bytes directly into the slab, the worker computes on
  zero-copy views and writes the response *in place* into the same
  buffer. Non-array payloads ride the in-slab pickle fallback lane. A
  batch larger than one buffer falls back to **chunked-slab** transport
  (pickle bytes streamed through the buffer in capacity-sized hops with
  ``ack`` flow control) — in BOTH directions, requests and responses
  alike.
* ``transport="pickle"``: the PR 9 legacy lane, kept for A/B
  benchmarking — whole-batch pickle through a single-buffer slab, with
  the old inline-pipe fallback for oversize messages.

Because the parent may pipeline ``run`` messages while the child is
mid-chunk (and vice versa), both endpoints keep a pending-message
deque: a message that is not the one currently awaited is queued in
arrival order, never dropped.

Spawn-safe entrypoint
---------------------

``fork`` remains the default start method (stage fns are typically
closures over model state, inherited for free), but the worker
entrypoint :func:`_worker_main` is module-level and the fn argument may
be an importable reference — ``"module:qualname"``, or a name
registered via :func:`register_worker_fn` — so
``ProcessReplicaPool(..., start_method="spawn")`` works on platforms
without fork. With spawn, a plain module-level callable is converted to
its import spec automatically; closures must go through the registry.
"""

from __future__ import annotations

import importlib
import multiprocessing as mp
import pickle
import threading
from collections import deque
from multiprocessing import connection as mp_conn
from multiprocessing import shared_memory
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.dataplane import (
    DataplaneStats,
    SlotOverflow,
    decode_batch,
    encode_batch,
)

__all__ = [
    "DEFAULT_SLAB_BYTES",
    "ProcReplica",
    "ProcessReplicaPool",
    "ReplicaDead",
    "StageWorkerError",
    "register_worker_fn",
    "resolve_worker_fn",
]

DEFAULT_SLAB_BYTES = 1 << 22
TRANSPORTS = ("ring", "pickle")

# Serializes SharedMemory creation + fork across dispatcher threads. A
# fork taken while a sibling spawn holds the multiprocessing resource
# tracker / shm internals mid-operation hands the child a permanently
# locked lock — the child then wedges before its first recv. One spawn
# at a time keeps our own machinery quiescent at every fork point.
_SPAWN_LOCK = threading.Lock()


class ReplicaDead(Exception):
    """The worker process died (crash injection, OOM, hard exit) while a
    batch was in flight — the dispatcher requeues and retires."""


class StageWorkerError(Exception):
    """The stage fn raised *inside* the worker process; carries the
    child-side repr. The replica itself is still healthy."""


# -- picklable fn registry (spawn-safe entrypoint) ---------------------------

_WORKER_FNS: Dict[str, Callable] = {}


def register_worker_fn(name: str, fn: Callable) -> Callable:
    """Register `fn` under `name` for :class:`ProcReplica`/pool
    construction by reference. For ``start_method="spawn"`` the fn must
    be importable (module-level) so the child can resolve it; closures
    are accepted but only work under fork."""
    _WORKER_FNS[name] = fn
    return fn


def resolve_worker_fn(ref: Union[str, Callable]) -> Callable:
    """Resolve a worker-fn reference: a callable passes through; a
    registered name looks up :func:`register_worker_fn`; a
    ``"module:qualname"`` spec imports."""
    if callable(ref):
        return ref
    if ref in _WORKER_FNS:
        return _WORKER_FNS[ref]
    if ":" in ref:
        mod_name, qual = ref.split(":", 1)
        obj = importlib.import_module(mod_name)
        for part in qual.split("."):
            obj = getattr(obj, part)
        if not callable(obj):
            raise TypeError(f"worker fn spec {ref!r} is not callable")
        return obj
    raise KeyError(f"unknown worker fn reference {ref!r}")


def _import_spec(fn: Callable) -> Optional[str]:
    """``module:qualname`` for a module-level callable, else None."""
    mod = getattr(fn, "__module__", None)
    qual = getattr(fn, "__qualname__", None)
    if not mod or not qual or "<" in qual:
        return None
    spec = f"{mod}:{qual}"
    try:
        if resolve_worker_fn(spec) is fn:
            return spec
    except Exception:  # noqa: BLE001 — unimportable => no spec
        pass
    return None


def _fn_ref_for_ctx(fn: Union[str, Callable], ctx) -> Union[str, Callable]:
    """What to hand the child process: under fork, the callable itself
    (inherited); under spawn, prefer an importable spec — a registered
    name is translated so the child need not share our registry."""
    start = ctx.get_start_method() if hasattr(ctx, "get_start_method") \
        else "fork"
    resolved = resolve_worker_fn(fn)
    if start == "fork":
        return resolved
    spec = _import_spec(resolved)
    if spec is not None:
        return spec
    # last resort: the callable must pickle (Process.start raises
    # loudly otherwise — better than silently serving the wrong fn)
    return resolved


def _scale_payloads(payloads: Sequence, scale=1) -> List:
    """Module-level demo stage fn (importable: spawn tests/benches)."""
    return [p * scale for p in payloads]


# -- the ring channel ---------------------------------------------------------


class _RingChannel:
    """One endpoint of the shared-memory ring + its pipe.

    Buffer ownership is never locked — it alternates between the two
    processes via the pipe protocol, per buffer: whoever just received
    a message naming buffer *i* owns it until it sends the next message
    naming it. LOCK01 enforces this as the ``handoff`` discipline with
    per-buffer guards: the buffers may only be touched from functions
    annotated as protocol participants.
    """

    def __init__(self, shm: shared_memory.SharedMemory, conn,
                 depth: int = 2, transport: str = "ring") -> None:
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}")
        if depth < 1:
            raise ValueError("ring depth must be >= 1")
        self._conn = conn
        self.transport = transport
        self.depth = depth
        per = len(shm.buf) // depth
        if per < 64:
            # even the chunk lane (raw byte windows) needs some room
            raise ValueError(
                f"slab of {len(shm.buf)} B too small for depth {depth}")
        self._bufs = [shm.buf[i * per:(i + 1) * per]   # guarded-by: handoff(_conn, buf=*)
                      for i in range(depth)]
        # uint8 aliases of the buffers, for overlap (self-alias) checks
        self._guards = [np.frombuffer(b, dtype=np.uint8)  # guarded-by: handoff(_conn, buf=*)
                        for b in self._bufs]
        self._pend: deque = deque()    # out-of-turn messages, FIFO
        self.stats = DataplaneStats()

    # -- raw pipe layer ----------------------------------------------------
    def _recv_raw(self, sentinel=None, timeout=None):  # holds-lock: handoff(_conn, buf=*)
        """One pipe message; with `sentinel` (a process sentinel fd),
        raise :class:`ReplicaDead` if the peer dies first. `timeout`
        returns None on expiry when `sentinel` is None, and raises
        ReplicaDead with a sentinel (an alive-but-silent peer past the
        bound is wedged — the spawn-handshake case)."""
        if sentinel is not None:
            while True:
                ready = mp_conn.wait([self._conn, sentinel],
                                     timeout=timeout)
                if self._conn in ready:
                    break
                if not ready:
                    raise ReplicaDead(
                        "worker process unresponsive within timeout")
                # the process died — drain any final message it managed
                # to flush before declaring the replica dead
                if not self._conn.poll(0.05):
                    raise ReplicaDead("worker process died mid-batch")
        elif timeout is not None:
            if not self._conn.poll(timeout):
                return None
        try:
            return self._conn.recv()
        except (EOFError, OSError) as exc:
            raise ReplicaDead("worker pipe closed") from exc

    def _recv_match(self, want: Tuple[str, ...], sentinel=None,
                    timeout=None):  # holds-lock: handoff(_conn, buf=*)
        """Next message whose tag is in `want`; anything else (a
        pipelined ``run``/``ok`` arriving while we await an ``ack``) is
        queued in arrival order. Returns None on poll timeout."""
        for i, msg in enumerate(self._pend):
            if msg[0] in want:
                del self._pend[i]
                return msg
        while True:
            msg = self._recv_raw(sentinel=sentinel, timeout=timeout)
            if msg is None:
                return None
            if msg[0] in want:
                return msg
            self._pend.append(msg)

    def poll(self, timeout: float, want: Tuple[str, ...]) -> bool:  # holds-lock: handoff(_conn, buf=*)
        """True if a `want` message is available (pending or arriving
        within `timeout`); non-matching arrivals are queued."""
        if any(m[0] in want for m in self._pend):
            return True
        while True:
            if not self._conn.poll(timeout):
                return False
            try:
                msg = self._conn.recv()
            except (EOFError, OSError) as exc:
                raise ReplicaDead("worker pipe closed") from exc
            if msg[0] in want:
                self._pend.appendleft(msg)
                return True
            self._pend.append(msg)
            timeout = 0.0

    def send_ctl(self, *msg) -> None:  # holds-lock: handoff(_conn, buf=*)
        self._conn.send(msg)

    # -- batch transport ---------------------------------------------------
    def send_batch(self, tag: str, buf: int, payloads: Sequence,
                   sentinel=None) -> None:  # holds-lock: handoff(_conn, buf=*)
        """Encode one batch into buffer `buf` (which this endpoint must
        own) and hand ownership to the peer. Oversize batches fall back
        to the chunked-slab lane (``transport="ring"``) or the legacy
        inline pipe (``transport="pickle"``) — both directions use the
        same fallback, requests and responses alike."""
        slot = self._bufs[buf]
        try:
            encode_batch(slot, payloads, self.stats,
                         typed=self.transport == "ring",
                         guard=self._guards[buf])
            self._conn.send((tag, buf))
            return
        except SlotOverflow as ov:
            data = ov.data if ov.data is not None else pickle.dumps(
                payloads, protocol=pickle.HIGHEST_PROTOCOL)
        if self.transport == "pickle":
            self.stats.inline_messages += 1
            self.stats.pickle_bytes += len(data)
            self._conn.send(("inline", tag, buf, data))
            return
        self.stats.pickle_bytes += len(data)
        cap = len(slot)
        n = len(data)
        sent = 0
        while True:
            k = min(cap, n - sent)
            slot[:k] = data[sent:sent + k]
            self.stats.bytes_copied += k
            self.stats.chunk_messages += 1
            sent += k
            last = sent >= n
            self._conn.send(("chunk", tag, buf, k, last))
            if last:
                return
            # flow control: the peer owns the buffer until it copied
            # the chunk out and acked it back
            if self._recv_match(("ack",), sentinel=sentinel) is None:
                raise ReplicaDead("peer vanished mid-chunk")

    def _recv_chunked(self, first, sentinel=None):  # holds-lock: handoff(_conn, buf=*)
        """Reassemble a chunked message starting at `first`; returns
        ``(tag, buf, obj)``. Ownership of `buf` lands on this endpoint
        once the last chunk is copied out."""
        _, tag, buf, k, last = first
        slot = self._bufs[buf]
        parts = bytearray()
        while True:
            parts += slot[:k]
            self.stats.bytes_copied += k
            self.stats.chunk_messages += 1
            if last:
                break
            self._conn.send(("ack", buf))
            nxt = self._recv_match(("chunk",), sentinel=sentinel)
            _, tag, buf, k, last = nxt
        self.stats.pickle_bytes += len(parts)
        return tag, buf, pickle.loads(bytes(parts))

    def recv_batch(self, want: Tuple[str, ...], sentinel=None,
                   timeout=None, copy: bool = False):  # holds-lock: handoff(_conn, buf=*)
        """Receive the next batch-level message whose (reassembled) tag
        is in `want`. Returns ``(tag, buf, obj)`` — `buf`/`obj` are None
        for control messages — or None on poll timeout. ``copy``
        selects owned arrays (dispatcher side) vs zero-copy slot views
        (worker side)."""
        tags = tuple(want) + ("chunk", "inline")
        msg = self._recv_match(tags, sentinel=sentinel, timeout=timeout)
        if msg is None:
            return None
        if msg[0] == "chunk":
            return self._recv_chunked(msg, sentinel=sentinel)
        if msg[0] == "inline":
            _, tag, buf, data = msg
            self.stats.inline_messages += 1
            self.stats.pickle_bytes += len(data)
            return tag, buf, pickle.loads(data)
        tag = msg[0]
        if tag in ("run", "ok"):
            buf = msg[1]
            return tag, buf, decode_batch(self._bufs[buf], copy=copy,
                                          stats=self.stats)
        if tag == "err":
            return tag, msg[1], msg[2]
        return tag, None, None          # ready / quit

    def close(self) -> None:           # holds-lock: handoff(_conn, buf=*)
        """Relinquish this endpoint: drop the slab views, close the
        pipe. Views must be released before the SharedMemory segment
        can close (exported-pointer guard)."""
        self._guards = []
        self._bufs = []
        self._pend.clear()
        try:
            self._conn.close()
        except OSError:
            pass


# -- worker-process entrypoint ------------------------------------------------


def _worker_main(shm_name: str, conn, peer_conn,
                 fn_ref: Union[str, Callable], transport: str = "ring",
                 depth: int = 2) -> None:
    """Module-level worker entrypoint (spawn-safe): serve run requests
    until quit/EOF. `fn_ref` is a callable (fork) or an importable
    reference resolved here (spawn)."""
    if peer_conn is not None:
        try:
            peer_conn.close()          # drop the inherited parent end
        except OSError:
            pass
    fn = resolve_worker_fn(fn_ref)
    # NOTE on the resource tracker: this attach re-registers the
    # segment, but both fork and spawn children share the PARENT's
    # tracker process (spawn passes tracker_fd through preparation
    # data), where the re-register is a set-dup no-op — the parent's
    # unlink in ProcReplica.close() stays the single cleanup point.
    # Do NOT unregister here: that would strip the shared cache entry.
    shm = shared_memory.SharedMemory(name=shm_name)
    chan = _RingChannel(shm, conn, depth=depth, transport=transport)
    try:
        # fork-safety handshake: forking a thread-heavy parent (e.g.
        # once JAX has warmed its internal pools) can deadlock the child
        # on a lock some unforked thread held. Announcing readiness
        # exercises the allocator + pipe path first thing, so a wedged
        # child is detected at spawn instead of eating a batch
        try:
            chan.send_ctl("ready")
        except (OSError, ReplicaDead):
            return
        while True:
            try:
                msg = chan.recv_batch(("run", "quit"), copy=False)
            except ReplicaDead:        # parent closed its end
                break
            tag, buf, payloads = msg
            if tag == "quit":
                break
            try:
                outs = list(fn(payloads))
            except BaseException as exc:  # noqa: BLE001 — report, keep serving
                try:
                    chan.send_ctl("err", buf,
                                  f"{type(exc).__name__}: {exc}")
                except (OSError, ReplicaDead):
                    break
                continue
            try:
                # respond in place: the response overwrites the request
                # buffer we own; outputs aliasing it (echoed input
                # views) are copy-guarded inside the encoder
                chan.send_batch("ok", buf, outs)
            except (OSError, ReplicaDead):
                break
    finally:
        chan.close()
        try:
            shm.close()
        except BufferError:            # a stage fn leaked a slot view
            pass


class ProcReplica:
    """One worker process + its shared-memory ring. Owned by a single
    dispatcher thread (the only caller of :meth:`submit`/:meth:`collect`
    /:meth:`run`/:meth:`close`); :meth:`kill` may be called concurrently
    by the fault driver / control plane.

    The ring pipelines up to ``ring_depth`` batches: :meth:`submit`
    encodes into a free buffer and hands it to the worker without
    waiting; :meth:`collect` blocks for (or polls) the oldest
    outstanding response. :meth:`run` is the synchronous convenience
    wrapper (submit + collect) used by tests and profiling.
    """

    def __init__(self, fn: Union[str, Callable],
                 slab_bytes: int = DEFAULT_SLAB_BYTES, ctx=None,
                 ready_timeout_s: float = 5.0,
                 transport: str = "ring",
                 ring_depth: int = 2) -> None:
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}")
        ctx = ctx or mp.get_context("fork")
        depth = 1 if transport == "pickle" else max(1, int(ring_depth))
        self.transport = transport
        self.depth = depth
        fn_ref = _fn_ref_for_ctx(fn, ctx)
        with _SPAWN_LOCK:
            self._shm = shared_memory.SharedMemory(create=True,
                                                   size=int(slab_bytes))
            parent_end, child_end = ctx.Pipe()
            self._chan = _RingChannel(self._shm, parent_end, depth=depth,
                                      transport=transport)
            self._proc = ctx.Process(
                target=_worker_main,
                args=(self._shm.name, child_end, parent_end, fn_ref,
                      transport, depth),
                daemon=True)
            self._proc.start()
        child_end.close()              # child's end lives in the child now
        self._free: deque = deque(range(depth))
        self._inflight: deque = deque()
        self._close_once = threading.Lock()
        self._closed = False           # guarded-by: _close_once
        self.busy = False              # crash-victim hint; racy by design
        # consume the child's ready handshake within a bound: a child
        # that never says ready is wedged (fork of a multithreaded
        # parent) — reap it here so it can never join the fleet
        try:
            msg = self._chan.recv_batch(
                ("ready",), sentinel=self._proc.sentinel,
                timeout=ready_timeout_s)
            ok = msg is not None and msg[0] == "ready"
        except ReplicaDead:
            ok = False
        if not ok:
            self.close()
            raise ReplicaDead("worker process failed the spawn handshake")

    @property
    def pid(self) -> Optional[int]:
        return self._proc.pid

    def alive(self) -> bool:
        return self._proc.is_alive()

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def transport_stats(self) -> DataplaneStats:
        return self._chan.stats

    def submit(self, payloads: Sequence) -> int:
        """Encode one batch into a free ring buffer and hand it to the
        worker without waiting for the result. Returns the buffer index.
        Raises :class:`ReplicaDead` if the process is gone and
        ``RuntimeError`` if no buffer is free (caller must
        :meth:`collect` first)."""
        if not self._free:
            raise RuntimeError("ring full: collect before submitting")
        if not self._proc.is_alive():
            raise ReplicaDead("worker process already dead")
        buf = self._free[0]
        try:
            self._chan.send_batch("run", buf, list(payloads),
                                  sentinel=self._proc.sentinel)
        except (BrokenPipeError, OSError) as exc:
            raise ReplicaDead("worker pipe broken on send") from exc
        self._free.popleft()
        self._inflight.append(buf)
        return buf

    def collect(self, timeout: Optional[float] = None) -> Optional[List]:
        """Receive the oldest outstanding response. Returns the output
        list, or None if `timeout` elapses with no response yet.

        Raises :class:`ReplicaDead` if the process dies under the batch
        (the caller requeues, mirroring the thread backend's killed
        path) and :class:`StageWorkerError` for child-side fn errors.
        """
        if not self._inflight:
            raise RuntimeError("nothing in flight to collect")
        if timeout is not None:
            if not self._chan.poll(timeout, ("ok", "err", "chunk",
                                             "inline")):
                if not self._proc.is_alive():
                    raise ReplicaDead("worker process died mid-batch")
                return None
        msg = self._chan.recv_batch(("ok", "err"),
                                    sentinel=self._proc.sentinel,
                                    copy=True)
        tag, buf, obj = msg
        expected = self._inflight.popleft()
        self._free.append(buf if buf is not None else expected)
        if tag == "ok":
            return obj
        raise StageWorkerError(obj)

    def run(self, payloads: Sequence) -> List:
        """Execute one batch synchronously (submit + collect)."""
        while self._inflight:          # drain any pipelined stragglers
            self.collect()
        self.submit(payloads)
        out = self.collect()
        assert out is not None
        return out

    def kill(self) -> None:
        """SIGKILL the worker — the injected-crash path. A real OS
        process dies; any in-flight batch surfaces as ReplicaDead in
        the paired dispatcher."""
        if self._proc.is_alive():
            self._proc.kill()

    def close(self) -> None:
        """Graceful retire: ask the child to quit, reap it, free the
        slab. Idempotent and safe to race (dispatcher exit vs pool
        shutdown)."""
        with self._close_once:
            if self._closed:
                return
            self._closed = True
        try:
            if self._proc.is_alive():
                self._chan.send_ctl("quit")
        except (BrokenPipeError, OSError):
            pass
        self._proc.join(timeout=2.0)
        if self._proc.is_alive():
            self._proc.kill()
            self._proc.join(timeout=2.0)
        self._chan.close()
        self._shm.close()
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass


class ProcessReplicaPool:
    """Per-stage registry of live :class:`ProcReplica` workers.

    The executor's dispatcher threads spawn/close members through this
    pool; the fault driver calls :meth:`kill` to take down real
    processes at scheduled instants (busy victims first, so crash
    injection exercises the in-flight requeue path whenever possible,
    matching the thread backend's semantics where only a dispatching
    worker could consume a kill). Transport stats of retired members
    accumulate so :meth:`stats` reports the whole pool lifetime.
    """

    def __init__(self, fn: Union[str, Callable],
                 slab_bytes: int = DEFAULT_SLAB_BYTES,
                 start_method: str = "fork",
                 transport: str = "ring",
                 ring_depth: int = 2) -> None:
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}")
        self._fn = fn
        self._slab_bytes = int(slab_bytes)
        self._ctx = mp.get_context(start_method)
        self._transport = transport
        self._ring_depth = int(ring_depth)
        self._plock = threading.Lock()
        self._members: List[ProcReplica] = []   # guarded-by: _plock
        self._retired_stats = DataplaneStats()  # guarded-by: _plock

    def spawn(self) -> ProcReplica:
        last: Optional[ReplicaDead] = None
        for _ in range(3):             # a wedged fork is retryable
            try:
                rep = ProcReplica(self._fn, self._slab_bytes, self._ctx,
                                  transport=self._transport,
                                  ring_depth=self._ring_depth)
            except ReplicaDead as exc:
                last = exc
                continue
            with self._plock:
                self._members.append(rep)
            return rep
        raise RuntimeError(
            f"could not spawn a healthy worker process: {last}")

    def discard(self, rep: ProcReplica) -> None:
        """Forget a member (dispatcher exit path); caller closes it.
        Its transport stats roll into the pool accumulator."""
        with self._plock:
            if rep in self._members:
                self._members.remove(rep)
                self._retired_stats.add(rep.transport_stats())

    def kill(self, n: int) -> int:
        """SIGKILL up to ``n`` live members, busy ones first. Returns
        the number actually signalled."""
        with self._plock:
            live = [m for m in self._members if m.alive()]
            victims = sorted(live, key=lambda m: not m.busy)[: max(0, n)]
        for v in victims:
            v.kill()
        return len(victims)

    def alive_count(self) -> int:
        with self._plock:
            return sum(1 for m in self._members if m.alive())

    def pids(self) -> List[int]:
        with self._plock:
            return [m.pid for m in self._members if m.alive()]

    def stats(self) -> DataplaneStats:
        """Pool-lifetime transport accounting: live members + retired."""
        out = DataplaneStats()
        with self._plock:
            out.add(self._retired_stats)
            for m in self._members:
                out.add(m.transport_stats())
        return out

    def close_all(self) -> None:
        with self._plock:
            members, self._members = self._members, []
            for m in members:
                self._retired_stats.add(m.transport_stats())
        for m in members:
            m.close()
