"""Process-backed replica pool: worker OS processes behind the LiveQueue.

Breaks the GIL ceiling for the live executor. Batch formation stays
exactly where it was — dispatcher *threads* inside
:class:`~repro.serving.executor.PipelineExecutor` holding the per-stage
``LiveQueue`` under its condition variable — but with
``backend="process"`` each dispatcher is paired with a
:class:`ProcReplica`: a forked worker process that executes the stage
fn, fed through a shared-memory request slab plus a control pipe. The
``PipelineExecutor`` / ``LiveControlLoop`` / ``ClosedLoopTuner`` and the
PR 8 fault machinery are unchanged by construction: the queue contract,
retry/hedging, and the AND-join all live parent-side, and an injected
crash SIGKILLs a real OS process (the paired dispatcher observes the
death and requeues the in-flight batch, exactly like the thread
backend's ``kill_pending`` path).

Transport protocol (one slab + one pipe per replica, strictly
request/response so slab ownership alternates — the ``handoff``
discipline LOCK01 checks):

* parent pickles ``("run", payloads)`` into the slab and sends
  ``("slab", nbytes)`` over the pipe; messages larger than the slab fall
  back to an inline ``("inline", bytes)`` pipe message;
* the child replies ``("ok", outs)`` / ``("err", repr)`` the same way;
* the parent waits on ``[pipe, process.sentinel]`` simultaneously, so a
  SIGKILL mid-batch surfaces as :class:`ReplicaDead` immediately rather
  than hanging the dispatcher.

The fork start method is required: stage fns are closures over model
state (not picklable), and fork inherits them for free. Fns that hold
accelerator handles should be constructed fork-safe (e.g. init JAX
lazily inside the fn); the benches use numpy/sleep LUT fns.
"""

from __future__ import annotations

import multiprocessing as mp
import pickle
import threading
from multiprocessing import connection as mp_conn
from multiprocessing import shared_memory
from typing import Callable, List, Optional, Sequence

__all__ = [
    "DEFAULT_SLAB_BYTES",
    "ProcReplica",
    "ProcessReplicaPool",
    "ReplicaDead",
    "StageWorkerError",
]

DEFAULT_SLAB_BYTES = 1 << 20

# Serializes SharedMemory creation + fork across dispatcher threads. A
# fork taken while a sibling spawn holds the multiprocessing resource
# tracker / shm internals mid-operation hands the child a permanently
# locked lock — the child then wedges before its first recv. One spawn
# at a time keeps our own machinery quiescent at every fork point.
_SPAWN_LOCK = threading.Lock()


class ReplicaDead(Exception):
    """The worker process died (crash injection, OOM, hard exit) while a
    batch was in flight — the dispatcher requeues and retires."""


class StageWorkerError(Exception):
    """The stage fn raised *inside* the worker process; carries the
    child-side repr. The replica itself is still healthy."""


class _SlabChannel:
    """One endpoint of the shared-memory request slab + its pipe.

    Slab ownership is never locked — it alternates between the two
    processes via the pipe protocol: whoever just received a pipe
    message owns the slab until it sends the next one. LOCK01 enforces
    this as the ``handoff`` discipline: the buffer may only be touched
    from functions annotated as protocol participants.
    """

    def __init__(self, shm: shared_memory.SharedMemory, conn) -> None:
        self._conn = conn
        self._buf = shm.buf            # guarded-by: handoff(_conn)

    def send(self, obj) -> None:       # holds-lock: handoff(_conn)
        data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(data) <= len(self._buf):
            self._buf[: len(data)] = data
            self._conn.send(("slab", len(data)))
        else:                          # oversize: inline pipe fallback
            self._conn.send(("inline", data))

    def recv(self, sentinel=None, timeout=None):  # holds-lock: handoff(_conn)
        """Receive one message; with ``sentinel`` (a process sentinel
        fd), raise :class:`ReplicaDead` if the peer dies first. A
        ``timeout`` (spawn handshake only) bounds the wait: expiry also
        raises ReplicaDead — an alive-but-silent child is wedged."""
        if sentinel is not None:
            while True:
                ready = mp_conn.wait([self._conn, sentinel],
                                     timeout=timeout)
                if self._conn in ready:
                    break
                if not ready:
                    raise ReplicaDead(
                        "worker process unresponsive within timeout")
                # the process died — drain any final message it managed
                # to flush before declaring the replica dead
                if not self._conn.poll(0.05):
                    raise ReplicaDead("worker process died mid-batch")
        try:
            tag, val = self._conn.recv()
        except (EOFError, OSError) as exc:
            raise ReplicaDead("worker pipe closed") from exc
        if tag == "slab":
            return pickle.loads(bytes(self._buf[:val]))
        return pickle.loads(val)

    def close(self) -> None:           # holds-lock: handoff(_conn)
        """Relinquish this endpoint: drop the slab view, close the pipe."""
        self._buf = None
        try:
            self._conn.close()
        except OSError:
            pass


def _child_main(shm_name: str, conn, peer_conn,
                fn: Callable[[Sequence], Sequence]) -> None:
    """Worker-process entrypoint: serve run requests until quit/EOF."""
    try:
        peer_conn.close()              # drop the inherited parent end
    except OSError:
        pass
    shm = shared_memory.SharedMemory(name=shm_name)
    chan = _SlabChannel(shm, conn)
    try:
        # fork-safety handshake: forking a thread-heavy parent (e.g.
        # once JAX has warmed its internal pools) can deadlock the child
        # on a lock some unforked thread held. Announcing readiness
        # exercises the allocator + pickle + pipe path first thing, so a
        # wedged child is detected at spawn instead of eating a batch
        try:
            chan.send(("ready", None))
        except (OSError, ReplicaDead):
            return
        while True:
            try:
                msg = chan.recv()
            except ReplicaDead:        # parent closed its end
                break
            if msg[0] == "quit":
                break
            try:
                outs = list(fn(msg[1]))
            except BaseException as exc:  # noqa: BLE001 — report, keep serving
                try:
                    chan.send(("err", f"{type(exc).__name__}: {exc}"))
                except (OSError, ReplicaDead):
                    break
                continue
            try:
                chan.send(("ok", outs))
            except (OSError, ReplicaDead):
                break
    finally:
        chan.close()
        shm.close()


class ProcReplica:
    """One worker process + its slab. Owned by a single dispatcher
    thread (the only caller of :meth:`run`/:meth:`close`); :meth:`kill`
    may be called concurrently by the fault driver / control plane."""

    def __init__(self, fn: Callable[[Sequence], Sequence],
                 slab_bytes: int = DEFAULT_SLAB_BYTES, ctx=None,
                 ready_timeout_s: float = 2.0) -> None:
        ctx = ctx or mp.get_context("fork")
        with _SPAWN_LOCK:
            self._shm = shared_memory.SharedMemory(create=True,
                                                   size=int(slab_bytes))
            parent_end, child_end = ctx.Pipe()
            self._chan = _SlabChannel(self._shm, parent_end)
            self._proc = ctx.Process(
                target=_child_main,
                args=(self._shm.name, child_end, parent_end, fn),
                daemon=True)
            self._proc.start()
        child_end.close()              # child's end lives in the child now
        self._close_once = threading.Lock()
        self._closed = False           # guarded-by: _close_once
        self.busy = False              # crash-victim hint; racy by design
        # consume the child's ready handshake within a bound: a child
        # that never says ready is wedged (fork of a multithreaded
        # parent) — reap it here so it can never join the fleet
        try:
            msg = self._chan.recv(sentinel=self._proc.sentinel,
                                  timeout=ready_timeout_s)
            ok = msg[0] == "ready"
        except ReplicaDead:
            ok = False
        if not ok:
            self.close()
            raise ReplicaDead("worker process failed the spawn handshake")

    @property
    def pid(self) -> Optional[int]:
        return self._proc.pid

    def alive(self) -> bool:
        return self._proc.is_alive()

    def run(self, payloads: Sequence) -> List:
        """Execute one batch in the worker process.

        Raises :class:`ReplicaDead` if the process dies under the batch
        (the caller requeues, mirroring the thread backend's killed
        path) and :class:`StageWorkerError` for child-side fn errors.
        """
        if not self._proc.is_alive():
            raise ReplicaDead("worker process already dead")
        try:
            self._chan.send(("run", list(payloads)))
        except (BrokenPipeError, OSError) as exc:
            raise ReplicaDead("worker pipe broken on send") from exc
        msg = self._chan.recv(sentinel=self._proc.sentinel)
        if msg[0] == "ok":
            return msg[1]
        raise StageWorkerError(msg[1])

    def kill(self) -> None:
        """SIGKILL the worker — the injected-crash path. A real OS
        process dies; any in-flight batch surfaces as ReplicaDead in
        the paired dispatcher."""
        if self._proc.is_alive():
            self._proc.kill()

    def close(self) -> None:
        """Graceful retire: ask the child to quit, reap it, free the slab.
        Idempotent and safe to race (dispatcher exit vs pool shutdown)."""
        with self._close_once:
            if self._closed:
                return
            self._closed = True
        try:
            if self._proc.is_alive():
                self._chan.send(("quit", None))
        except (BrokenPipeError, OSError):
            pass
        self._proc.join(timeout=2.0)
        if self._proc.is_alive():
            self._proc.kill()
            self._proc.join(timeout=2.0)
        self._chan.close()
        self._shm.close()
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass


class ProcessReplicaPool:
    """Per-stage registry of live :class:`ProcReplica` workers.

    The executor's dispatcher threads spawn/close members through this
    pool; the fault driver calls :meth:`kill` to take down real
    processes at scheduled instants (busy victims first, so crash
    injection exercises the in-flight requeue path whenever possible,
    matching the thread backend's semantics where only a dispatching
    worker could consume a kill).
    """

    def __init__(self, fn: Callable[[Sequence], Sequence],
                 slab_bytes: int = DEFAULT_SLAB_BYTES,
                 start_method: str = "fork") -> None:
        self._fn = fn
        self._slab_bytes = int(slab_bytes)
        self._ctx = mp.get_context(start_method)
        self._plock = threading.Lock()
        self._members: List[ProcReplica] = []   # guarded-by: _plock

    def spawn(self) -> ProcReplica:
        last: Optional[ReplicaDead] = None
        for _ in range(3):             # a wedged fork is retryable
            try:
                rep = ProcReplica(self._fn, self._slab_bytes, self._ctx)
            except ReplicaDead as exc:
                last = exc
                continue
            with self._plock:
                self._members.append(rep)
            return rep
        raise RuntimeError(
            f"could not spawn a healthy worker process: {last}")

    def discard(self, rep: ProcReplica) -> None:
        """Forget a member (dispatcher exit path); caller closes it."""
        with self._plock:
            if rep in self._members:
                self._members.remove(rep)

    def kill(self, n: int) -> int:
        """SIGKILL up to ``n`` live members, busy ones first. Returns
        the number actually signalled."""
        with self._plock:
            live = [m for m in self._members if m.alive()]
            victims = sorted(live, key=lambda m: not m.busy)[: max(0, n)]
        for v in victims:
            v.kill()
        return len(victims)

    def alive_count(self) -> int:
        with self._plock:
            return sum(1 for m in self._members if m.alive())

    def pids(self) -> List[int]:
        with self._plock:
            return [m.pid for m in self._members if m.alive()]

    def close_all(self) -> None:
        with self._plock:
            members, self._members = self._members, []
        for m in members:
            m.close()
