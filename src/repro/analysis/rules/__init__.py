"""Rule registry for the repro invariant analyzer."""

from __future__ import annotations

from typing import Dict, List, Type

from repro.analysis.core import Rule
from repro.analysis.rules.det import Det01
from repro.analysis.rules.evt import Evt01
from repro.analysis.rules.jax_purity import Jax01
from repro.analysis.rules.key import Key01
from repro.analysis.rules.lock import Lock01

ALL_RULES: List[Type[Rule]] = [Det01, Key01, Lock01, Evt01, Jax01]

RULES_BY_ID: Dict[str, Type[Rule]] = {r.id: r for r in ALL_RULES}

__all__ = ["ALL_RULES", "RULES_BY_ID",
           "Det01", "Key01", "Lock01", "Evt01", "Jax01"]
