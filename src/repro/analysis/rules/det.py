"""DET01 — determinism of the simulation core.

The planner is only trustworthy because a ``TraceSession`` is a pure
function of (trace, configuration): cone-memoized re-simulation must be
bit-identical to a fresh run. Wall-clock reads and global-state RNG
break that silently, so inside ``repro.sim``, ``repro.core``,
``repro.workload`` and ``repro.faults`` (fault replay must be
bit-identical under one seed) this rule bans:

* wall-clock calls — ``time.time``/``perf_counter``/``monotonic`` (and
  ``_ns`` variants), ``datetime.now``/``utcnow``/``today``;
* legacy global-RNG numpy calls — any ``np.random.<fn>()`` other than
  ``default_rng`` (module-level numpy RNG state is shared and
  call-order dependent);
* unseeded generators — ``np.random.default_rng()`` with no arguments;
* stdlib ``random.<fn>()`` module-level calls (same global-state
  problem) when the module imports ``random``.

Passing an explicit seed (``default_rng(seed)``) is the blessed idiom —
exactly what ``SimEngine.edge_draws`` does so routing draws are frozen
across the whole candidate search.
"""

from __future__ import annotations

import ast
from typing import Iterable, Sequence

from repro.analysis.core import Rule
from repro.analysis.findings import Finding
from repro.analysis.source import ModuleSource, dotted_name

DETERMINISTIC_PACKAGES = ("repro/sim/", "repro/core/", "repro/workload/",
                          "repro/faults/")

WALL_CLOCK = {
    "time.time", "time.time_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today", "date.today",
}

# stdlib `random` module-level functions (all share one hidden Random())
STDLIB_RANDOM = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "expovariate",
    "betavariate", "seed", "getrandbits", "triangular", "vonmisesvariate",
    "paretovariate", "weibullvariate", "lognormvariate", "random_bytes",
}

_NUMPY_ROOTS = ("np.random.", "numpy.random.")


class Det01(Rule):
    id = "DET01"
    title = ("no wall-clock or unseeded/global-state RNG in the "
             "simulation core (repro.sim / repro.core / repro.workload)")

    def check(self, modules: Sequence[ModuleSource]) -> Iterable[Finding]:
        for mod in modules:
            if not mod.in_package(*DETERMINISTIC_PACKAGES):
                continue
            imports_random = any(
                isinstance(n, ast.Import)
                and any(a.name == "random" for a in n.names)
                for n in ast.walk(mod.tree))
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                if name in WALL_CLOCK:
                    yield self.finding(
                        mod, node,
                        f"wall-clock call {name}() in the deterministic "
                        f"simulation core — results must be a pure "
                        f"function of (trace, config); take times from "
                        f"the trace or a parameter")
                    continue
                for root in _NUMPY_ROOTS:
                    if name.startswith(root):
                        tail = name[len(root):]
                        if tail == "default_rng":
                            if not node.args and not node.keywords:
                                yield self.finding(
                                    mod, node,
                                    "np.random.default_rng() without an "
                                    "explicit seed — pass a seed so "
                                    "repeat simulations are bit-"
                                    "identical")
                        elif "." not in tail and tail[:1].islower():
                            yield self.finding(
                                mod, node,
                                f"global-state RNG call np.random.{tail}"
                                f"() — use a seeded np.random."
                                f"default_rng(seed) generator instead")
                        break
                else:
                    if (imports_random and name.startswith("random.")
                            and name.count(".") == 1
                            and name.split(".")[1] in STDLIB_RANDOM):
                        yield self.finding(
                            mod, node,
                            f"stdlib global-state RNG call {name}() — "
                            f"use a seeded np.random.default_rng(seed)")
