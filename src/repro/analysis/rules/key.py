"""KEY01 — cache-key completeness (the PR 6 stale-cone bug class).

``TraceSession`` memoizes per-stage outcomes on the stage's
configuration cone; if any configuration knob is missing from the cone
key, two different configurations collide on one cache entry and the
planner silently scores stale results (exactly the backend-missing-
from-cache-keys bug PR 6 fixed). This rule walks the defining ASTs and
the key-function ASTs and cross-checks them:

1. every dataclass field of ``StageConfig`` (repro/core/pipeline.py)
   must be read (``self.<field>``) inside ``StageConfig.key()``;
2. the schedule key helpers in repro/sim/engine.py (``_sched_key``,
   ``_shed_key``, ``_policy_key``, ``_fault_key``) must fold EVERY
   component of the event tuples they iterate: a comprehension binding
   ``(t, d)`` must use both names in the emitted element, and the
   unpack arity must match the event arity of the corresponding
   schedule class — ``ReplicaPool``/``ShedMarginSchedule``/
   ``PolicySchedule`` in repro/core/policy.py, ``FaultSchedule``
   (4-component ``(kind, t0, t1, value)`` events) in
   repro/faults/schedule.py;
3. ``TraceSession._stage_key`` must token the backend
   (``self.backend``), call ``StageConfig.key()`` and all four
   schedule-key helpers; the percentile caches (``percentile``,
   ``class_percentile``) must also carry ``self.backend``.

The rule is silent when a registry file is absent from the scanned set
(fixture trees check one file at a time), but a present file missing
its registered definitions is a finding — deleting ``key()`` must not
pass the checker.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.core import Rule
from repro.analysis.findings import Finding
from repro.analysis.source import ModuleSource

PIPELINE_FILE = "repro/core/pipeline.py"
ENGINE_FILE = "repro/sim/engine.py"
POLICY_FILE = "repro/core/policy.py"
FAULTS_FILE = "repro/faults/schedule.py"

# engine schedule-key helper -> (class carrying the event stream,
# fallback event arity when its defining file is absent, defining file)
SCHEDULE_KEYS = {
    "_sched_key": ("ReplicaPool", 2, POLICY_FILE),
    "_shed_key": ("ShedMarginSchedule", 2, POLICY_FILE),
    "_policy_key": ("PolicySchedule", 2, POLICY_FILE),
    "_fault_key": ("FaultSchedule", 4, FAULTS_FILE),
}

# TraceSession methods whose cache keys must carry the backend token
BACKEND_KEYED = ("_stage_key", "percentile", "class_percentile")


def _find_class(mod: ModuleSource, name: str) -> Optional[ast.ClassDef]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _find_method(cls: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _self_attrs_read(fn: ast.FunctionDef) -> Set[str]:
    """Attribute names read off the first parameter (``self.<x>``)."""
    if not fn.args.args:
        return set()
    self_name = fn.args.args[0].arg
    out: Set[str] = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == self_name):
            out.add(node.attr)
    return out


def _dataclass_fields(cls: ast.ClassDef) -> List[ast.AnnAssign]:
    out = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                          ast.Name):
            ann = ast.unparse(node.annotation)
            if "ClassVar" not in ann:
                out.append(node)
    return out


def _tuple_unpack_names(target: ast.AST) -> Optional[List[str]]:
    if isinstance(target, (ast.Tuple, ast.List)):
        names = []
        for elt in target.elts:
            if isinstance(elt, ast.Name):
                names.append(elt.id)
            else:
                return None
        return names
    return None


def _event_arity(policy_mod: ModuleSource, cls_name: str) -> Optional[int]:
    """Widest event-tuple unpack arity used by a schedule class — the
    number of components a corresponding key function must fold."""
    cls = _find_class(policy_mod, cls_name)
    if cls is None:
        return None
    arity: Optional[int] = None
    for node in ast.walk(cls):
        targets: List[ast.AST] = []
        if isinstance(node, ast.comprehension):
            targets.append(node.target)
        elif isinstance(node, ast.Assign):
            targets.extend(node.targets)
        elif isinstance(node, ast.For):
            targets.append(node.target)
        for t in targets:
            names = _tuple_unpack_names(t)
            if names:
                arity = max(arity or 0, len(names))
    return arity


class Key01(Rule):
    id = "KEY01"
    title = ("cache-key completeness: every StageConfig field and every "
             "schedule-event component must reach the cone cache keys")

    def check(self, modules: Sequence[ModuleSource]) -> Iterable[Finding]:
        by_suffix: Dict[str, ModuleSource] = {}
        for m in modules:
            for suffix in (PIPELINE_FILE, ENGINE_FILE, POLICY_FILE,
                           FAULTS_FILE):
                if m.relpath.endswith(suffix):
                    by_suffix[suffix] = m
        pipeline = by_suffix.get(PIPELINE_FILE)
        engine = by_suffix.get(ENGINE_FILE)
        if pipeline is not None:
            yield from self._check_stage_config(pipeline)
        if engine is not None:
            yield from self._check_engine(engine, by_suffix)

    # -- StageConfig.key() covers every field -------------------------------
    def _check_stage_config(self, mod: ModuleSource) -> Iterable[Finding]:
        cls = _find_class(mod, "StageConfig")
        if cls is None:
            return
        fields = _dataclass_fields(cls)
        key_fn = _find_method(cls, "key")
        if key_fn is None:
            yield self.finding(
                mod, cls,
                "StageConfig has no key() method — simulation caches "
                "have no config identity to key on")
            return
        read = _self_attrs_read(key_fn)
        for field in fields:
            fname = field.target.id  # type: ignore[union-attr]
            if fname not in read:
                yield self.finding(
                    mod, key_fn,
                    f"StageConfig field {fname!r} is not folded into "
                    f"key() — two configs differing only in {fname!r} "
                    f"collide on one stage-cache entry (the PR 6 "
                    f"stale-cone bug class)")

    # -- engine key helpers + TraceSession backend token --------------------
    def _check_engine(self, engine: ModuleSource,
                      by_suffix: Dict[str, ModuleSource]
                      ) -> Iterable[Finding]:
        fns: Dict[str, ast.FunctionDef] = {
            n.name: n for n in engine.tree.body
            if isinstance(n, ast.FunctionDef)}
        for kname, (cls_name, fallback, src_file) in SCHEDULE_KEYS.items():
            fn = fns.get(kname)
            if fn is None:
                yield Finding(
                    self.id, engine.relpath, 1, 1, "<module>",
                    f"schedule key helper {kname}() is missing — "
                    f"schedules cannot reach the cone cache keys")
                continue
            src = by_suffix.get(src_file)
            expected = fallback
            if src is not None:
                expected = _event_arity(src, cls_name) or fallback
            yield from self._check_key_fn(engine, fn, expected, cls_name)

        session = _find_class(engine, "TraceSession")
        if session is None:
            return
        for mname in BACKEND_KEYED:
            fn = _find_method(session, mname)
            if fn is None:
                continue
            if "backend" not in _self_attrs_read(fn):
                yield self.finding(
                    engine, fn,
                    f"TraceSession.{mname} builds a cache key without "
                    f"the backend token (self.backend) — a parity "
                    f"regression between backends becomes maskable by "
                    f"a cache hit (the PR 6 bug)")
        stage_key = _find_method(session, "_stage_key")
        if stage_key is not None:
            called = set()
            for node in ast.walk(stage_key):
                if isinstance(node, ast.Call):
                    # terminal name, so `config[s].key()` counts too
                    if isinstance(node.func, ast.Attribute):
                        called.add(node.func.attr)
                    elif isinstance(node.func, ast.Name):
                        called.add(node.func.id)
            for required in ("key", *SCHEDULE_KEYS):
                if required not in called:
                    yield self.finding(
                        engine, stage_key,
                        f"TraceSession._stage_key does not call "
                        f"{required}() — that configuration dimension "
                        f"never reaches the cone cache key")

    def _check_key_fn(self, mod: ModuleSource, fn: ast.FunctionDef,
                      expected_arity: int, cls_name: str
                      ) -> Iterable[Finding]:
        comps = [n for n in ast.walk(fn) if isinstance(n, ast.comprehension)]
        if not comps:
            yield self.finding(
                mod, fn,
                f"{fn.name}() has no per-event fold (comprehension) — "
                f"cannot verify every event component reaches the key")
            return
        for comp in comps:
            names = _tuple_unpack_names(comp.target)
            if names is None:
                continue
            if len(names) != expected_arity:
                yield self.finding(
                    mod, fn,
                    f"{fn.name}() unpacks {len(names)} event "
                    f"component(s) but {cls_name} events carry "
                    f"{expected_arity} — a schedule component is "
                    f"invisible to the cache key")
            # the emitted element must use every bound component
            parent = mod.parent.get(comp)
            elt = getattr(parent, "elt", None)
            if elt is None:
                continue
            used = {n.id for n in ast.walk(elt)
                    if isinstance(n, ast.Name)}
            for bound in names:
                if bound != "_" and bound not in used:
                    yield self.finding(
                        mod, fn,
                        f"{fn.name}() binds event component {bound!r} "
                        f"but drops it from the emitted key — two "
                        f"schedules differing only in {bound!r} "
                        f"collide on one cache entry")
