"""LOCK01 — lock discipline in the wall-clock serving layer (PR 5 class).

The executor's shutdown race shipped because nothing tied shared
attributes to the lock that guards them. This rule makes the tie
explicit and machine-checked via two comment annotations:

* ``# guarded-by: <lock>`` on an attribute's initializing assignment
  (``self.attr = ...  # guarded-by: cond``) registers the attribute as
  guarded by the lock attribute named ``<lock>`` (the terminal name of
  a ``threading.Lock``/``Condition``-holding attribute, e.g. ``cond``
  or ``_lock``);
* ``# holds-lock: <lock>[, <lock>...]`` on a ``def`` line declares that
  the function is only ever called with those locks held (the
  Clang-thread-safety ``REQUIRES()`` idiom for private helpers).

Every read/write of a registered attribute (``<base>.<attr>``) must
then occur lexically inside a ``with <expr>:`` whose resolved terminal
attribute name equals the guarding lock (simple aliases like
``cond = st.cond`` are resolved), inside a function annotated
``holds-lock``, or inside the ``__init__`` of the class that declared
the attribute (construction precedes sharing). Everything else is a
finding.

**Handoff guards (cross-process shared memory).** The process-backed
replica pool (:mod:`repro.serving.procpool`) shares request slabs
between processes, where no ``threading`` lock can exist: slab
ownership alternates between the two endpoints via their message pipe
(whoever last *received* owns the slab until it *sends*). The
annotation vocabulary covers this with a guard form:

* ``# guarded-by: handoff(<conn>)`` declares the attribute owned under
  the pipe-handoff protocol of the connection attribute ``<conn>``;
* ``# holds-lock: handoff(<conn>)`` on a ``def`` declares the function
  a protocol participant. Participation is *verified*, not trusted: the
  function body must actually drive the channel (call
  ``<conn>.send/recv/poll/close``) — an annotated function that never
  touches the pipe claims ownership the protocol cannot grant, and is
  itself a finding.

**Per-buffer handoff (ring transport).** The double-buffered ring
splits one slab into independently-owned buffers, each following the
handoff discipline separately (messages name the buffer they hand
over). The guard form grows a buffer selector:

* ``# guarded-by: handoff(<conn>, buf=N)`` — this attribute is buffer
  ``N`` of the ring; ``buf=*`` declares a whole buffer table (each
  element owned per the protocol).
* ``# holds-lock: handoff(<conn>, buf=N)`` — participant for buffer
  ``N`` only; ``buf=*`` — participant for every buffer (the normal
  annotation for ring channel methods, whose messages carry the buffer
  index at runtime).

Satisfaction is ownership-width ordered: a whole-channel
(``handoff(conn)``) or all-buffer (``buf=*``) participant satisfies
any per-buffer guard; a specific ``buf=N`` participant satisfies only
buffer ``N``'s guard — it may not touch the whole table (``buf=*``)
or another buffer. Channel-traffic verification applies to every
form.

Matching is by terminal lock NAME, not full object path — the registry
cannot type-infer which instance ``st`` refers to. That approximation
admits holding the wrong instance's ``cond``, but catches the real
shipped bug class: accesses with NO lock held at all.

Scope: modules under ``repro/serving/`` plus any module that carries
``guarded-by`` annotations (so fixtures and future packages opt in by
annotating).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Rule
from repro.analysis.findings import Finding
from repro.analysis.source import ModuleSource, dotted_name

SERVING_PACKAGE = "repro/serving/"

# a lock token is a dotted lock-attribute name or
# handoff(<conn attr>[, buf=<N|*>])
_LOCK_TOKEN = (r"(?:handoff\([A-Za-z_][\w.]*"
               r"(?:\s*,\s*buf=(?:\d+|\*))?\)|[A-Za-z_][\w.]*)")
GUARD_RE = re.compile(rf"#\s*guarded-by:\s*({_LOCK_TOKEN})")
HOLDS_RE = re.compile(
    rf"#\s*holds-lock:\s*({_LOCK_TOKEN}(?:\s*,\s*{_LOCK_TOKEN})*)")
_HANDOFF_RE = re.compile(
    r"^handoff\(\s*([A-Za-z_][\w.]*)\s*(?:,\s*buf=(\d+|\*)\s*)?\)$")

# the pipe surface that constitutes protocol participation for a
# holds-lock: handoff(<conn>) function
_CHANNEL_CALLS = ("close", "poll", "recv", "recv_bytes", "send",
                  "send_bytes")


def _terminal(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _split_locks(tokens: str) -> List[str]:
    """Split a holds-lock token list on top-level commas only — the
    comma inside ``handoff(conn, buf=N)`` is part of one token."""
    out: List[str] = []
    depth, start = 0, 0
    for i, ch in enumerate(tokens):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(tokens[start:i])
            start = i + 1
    out.append(tokens[start:])
    return [t for t in (tok.strip() for tok in out) if t]


def _norm_lock(tok: str) -> str:
    """Canonical form of one lock token: terminal attribute name, with
    handoff guards normalized to ``handoff(<terminal conn name>)`` /
    ``handoff(<conn>, buf=<N|*>)``."""
    tok = tok.strip()
    m = _HANDOFF_RE.match(tok)
    if m:
        conn = _terminal(m.group(1))
        if m.group(2) is not None:
            return f"handoff({conn}, buf={m.group(2)})"
        return f"handoff({conn})"
    return _terminal(tok)


def _satisfies(guard: str, held: Set[str]) -> bool:
    """Does any held lock satisfy `guard`? Exact name match, plus the
    handoff ownership-width order: a whole-channel or all-buffer
    (``buf=*``) participant owns every buffer in turn and satisfies any
    per-buffer guard; a specific ``buf=N`` participant satisfies only
    buffer N's guard — never the whole table."""
    if guard in held:
        return True
    m = _HANDOFF_RE.match(guard)
    if m is None:
        return False
    conn = _terminal(m.group(1))
    if m.group(2) is None:
        # plain-channel guard (single-slab protocol): an all-buffer
        # ring participant qualifies; a buf=N holder does not
        return f"handoff({conn}, buf=*)" in held
    return (f"handoff({conn})" in held
            or f"handoff({conn}, buf=*)" in held)


def _uses_channel(fn: ast.AST, chan: str,
                  methods: Optional[Dict[str, ast.AST]] = None,
                  _seen: Optional[Set[str]] = None) -> bool:
    """True if `fn`'s body drives the `chan` pipe: a direct
    ``<...chan>.{protocol method}`` call, or delegation — a
    ``self.helper(...)`` call whose same-class helper drives it
    (transitively; the ring channel factors its raw pipe layer into
    ``_recv_raw``-style helpers, and delegating to a participant is
    participation)."""
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _CHANNEL_CALLS):
            base = dotted_name(node.func.value)
            if base is not None and _terminal(base) == chan:
                return True
    if methods:
        seen = _seen if _seen is not None else set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"
                    and node.func.attr in methods
                    and node.func.attr not in seen):
                seen.add(node.func.attr)
                if _uses_channel(methods[node.func.attr], chan,
                                 methods, seen):
                    return True
    return False


def _holds_tokens(mod: ModuleSource, fn: ast.AST) -> List[str]:
    """holds-lock tokens for `fn`, searching every line of its
    signature — a multi-line ``def`` carries the annotation on the
    closing-paren line, not necessarily on ``fn.lineno``."""
    body_start = fn.body[0].lineno if fn.body else fn.lineno + 1
    for line in range(fn.lineno, body_start):
        m = HOLDS_RE.search(mod.comments.get(line, ""))
        if m:
            return _split_locks(m.group(1))
    return []


def _class_methods(mod: ModuleSource,
                   fn: ast.AST) -> Dict[str, ast.AST]:
    """name -> def node for every method of `fn`'s enclosing class
    (empty for module-level functions)."""
    cls = mod.parent.get(fn)
    if not isinstance(cls, ast.ClassDef):
        return {}
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


class _Registry:
    """attr name -> {declaring class (terminal name) -> lock name}."""

    def __init__(self):
        self.guards: Dict[str, Dict[str, str]] = {}

    def declare(self, attr: str, lock: str, cls_qual: str) -> None:
        self.guards.setdefault(attr, {})[_terminal(cls_qual)] = \
            _norm_lock(lock)


def _collect_registry(mod: ModuleSource, reg: _Registry) -> None:
    for node in ast.walk(mod.tree):
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
        else:
            continue
        comment = mod.comments.get(node.lineno, "")
        m = GUARD_RE.search(comment)
        if not m:
            continue
        for t in targets:
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)):
                # `self.attr = ...` inside a method
                scope = mod.scope_of(node)          # e.g. _Stage.__init__
                cls_qual = scope.rsplit(".", 1)[0] if "." in scope else scope
                reg.declare(t.attr, m.group(1), cls_qual)
            elif (isinstance(t, ast.Name)
                  and isinstance(mod.parent.get(node), ast.ClassDef)):
                # dataclass-style class-body field annotation
                reg.declare(t.id, m.group(1), mod.scope_of(node))


def _receiver_class(mod: ModuleSource, node: ast.Attribute) -> Optional[str]:
    """Best-effort terminal class name of the access's base object:
    ``self`` resolves to the enclosing class; a plain name resolves via
    the enclosing functions' parameter annotations. None = unknown
    (checked conservatively against every declaring class)."""
    if not isinstance(node.value, ast.Name):
        return None
    base = node.value.id
    fns = list(mod.enclosing_functions(node))
    if base in ("self", "cls"):
        for fn in fns:
            if fn.args.args and fn.args.args[0].arg == base:
                cls = mod.parent.get(fn)
                if isinstance(cls, ast.ClassDef):
                    return cls.name
    for fn in fns:
        for p in fn.args.args + fn.args.kwonlyargs + fn.args.posonlyargs:
            if p.arg != base or p.annotation is None:
                continue
            ann = p.annotation
            if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
                return _terminal(ann.value.strip("'\""))
            name = dotted_name(ann)
            if name is not None:
                return _terminal(name)
    return None


def _local_aliases(fn: ast.FunctionDef) -> Dict[str, str]:
    """name -> dotted value for simple `name = a.b.c` assignments."""
    out: Dict[str, str] = {}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            val = dotted_name(node.value)
            if val is not None:
                out[node.targets[0].id] = val
    return out


def _held_locks(mod: ModuleSource, node: ast.AST) -> Set[str]:
    """Terminal names of every lock held at `node` (lexical `with`
    blocks, alias-resolved, plus enclosing holds-lock annotations)."""
    held: Set[str] = set()
    fn_chain = list(mod.enclosing_functions(node))
    aliases: Dict[str, str] = {}
    for fn in fn_chain:
        aliases.update(_local_aliases(fn))
        for lock in _holds_tokens(mod, fn):
            held.add(_norm_lock(lock))
    cur: Optional[ast.AST] = mod.parent.get(node)
    while cur is not None:
        if isinstance(cur, ast.With):
            for item in cur.items:
                name = dotted_name(item.context_expr)
                if name is None:
                    continue
                resolved = aliases.get(name, name)
                held.add(_terminal(resolved))
        cur = mod.parent.get(cur)
    return held


class Lock01(Rule):
    id = "LOCK01"
    title = ("guarded-by lock discipline on shared executor/loop "
             "attributes (repro.serving)")

    def check(self, modules: Sequence[ModuleSource]) -> Iterable[Finding]:
        reg = _Registry()
        checked: List[ModuleSource] = []
        for mod in modules:
            has_annotations = any(GUARD_RE.search(c)
                                  for c in mod.comments.values())
            if has_annotations:
                _collect_registry(mod, reg)
            if has_annotations or mod.in_package(SERVING_PACKAGE):
                checked.append(mod)
        for mod in checked:
            yield from self._check_handoff_protocol(mod)
        if not reg.guards:
            return
        for mod in checked:
            yield from self._check_module(mod, reg)

    def _check_handoff_protocol(self, mod: ModuleSource
                                ) -> Iterable[Finding]:
        """A ``holds-lock: handoff(X)`` function claims slab ownership
        granted by the X pipe protocol; the claim is only coherent if
        the function actually participates in that protocol. Verify the
        body drives the channel (send/recv/poll/close on X)."""
        for node in ast.walk(mod.tree):
            if not isinstance(node,
                              (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for tok in _holds_tokens(mod, node):
                hm = _HANDOFF_RE.match(tok)
                if hm is None:
                    continue
                chan = _terminal(hm.group(1))
                if not _uses_channel(node, chan,
                                     _class_methods(mod, node)):
                    yield self.finding(
                        mod, node,
                        f"`holds-lock: handoff({chan})` on {node.name} "
                        f"but its body never drives channel {chan} "
                        f"(no {'/'.join(_CHANNEL_CALLS)} call, directly "
                        f"or via a participating same-class helper) — "
                        f"the annotation claims slab ownership the "
                        f"message protocol cannot grant")

    def _check_module(self, mod: ModuleSource,
                      reg: _Registry) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Attribute):
                continue
            by_cls = reg.guards.get(node.attr)
            if by_cls is None:
                continue
            recv = _receiver_class(mod, node)
            if recv is not None:
                lock = by_cls.get(recv)
                if lock is None:      # same attr name on another class
                    continue
                declaring = recv
            elif len(by_cls) == 1:
                declaring, lock = next(iter(by_cls.items()))
            else:
                # ambiguous receiver over several guarded classes:
                # holding ANY of the candidate locks satisfies the rule
                declaring = "/".join(sorted(by_cls))
                lock = None
            # construction in the declaring class's own __init__
            # precedes sharing
            scope = mod.scope_of(node)
            if any(scope == f"{c}.__init__"
                   or scope.endswith(f".{c}.__init__") for c in by_cls):
                continue
            held = _held_locks(mod, node)
            if lock is not None:
                if _satisfies(lock, held):
                    continue
                locks_msg = lock
            else:
                if any(_satisfies(lk, held) for lk in set(by_cls.values())):
                    continue
                locks_msg = "/".join(sorted(set(by_cls.values())))
            access = "write of" if isinstance(
                node.ctx, (ast.Store, ast.Del)) else "read of"
            base = dotted_name(node.value) or "<expr>"
            if locks_msg.startswith("handoff("):
                yield self.finding(
                    mod, node,
                    f"{access} guarded attribute {base}.{node.attr} "
                    f"outside the {locks_msg} ownership protocol "
                    f"(declared guarded-by {locks_msg} in {declaring}) "
                    f"— shared-memory slab touched by a non-participant")
            else:
                yield self.finding(
                    mod, node,
                    f"{access} guarded attribute {base}.{node.attr} "
                    f"outside `with {locks_msg}` (declared guarded-by "
                    f"{locks_msg} in {declaring}) — the PR 5 executor "
                    f"race class")
