"""EVT01 — control-event streams must be sorted by time (PR 2 class).

``ReplicaPool.apply_events`` and the schedule folds walk their event
list with a monotone cursor: an out-of-order event is silently never
applied, which is exactly the unsorted control-event bug PR 2 fixed.
Whole-program dataflow ("is this list sorted here?") is infeasible, so
the rule pins the burden of proof at the consumer boundaries instead:

1. every schedule class whose ``__init__`` takes an ``events`` stream
   (``ReplicaPool``, ``ShedMarginSchedule``, ``PolicySchedule``, and
   anything shaped like them in the deterministic core) must sort it
   before storing — a ``sorted(events...)`` call or an
   ``<alias>.sort(...)`` statement in ``__init__``;
2. ``fold_control_event`` (the incremental event folder) must re-sort
   after appending — an ``.append``/``.insert`` without any
   ``.sort``/``sorted`` in the same function is a finding;
3. call sites handing a LITERAL event list to a consumer
   (``fold_control_event``, ``apply_events``, or a schedule
   constructor) with statically decreasing timestamps are flagged
   directly — the one case sortedness is decidable at the call site.

Sorting must be time-stable: ``sorted(ev, key=lambda e: e[0])`` keeps
same-timestamp events (e.g. a ``(t,+1),(t,-1)`` churn pair) in arrival
order, where a full-tuple sort would reorder them and change drain
semantics. The rule accepts either spelling but the repo idiom is the
stable one.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Sequence

from repro.analysis.core import Rule
from repro.analysis.findings import Finding
from repro.analysis.source import ModuleSource, dotted_name

CORE_PACKAGES = ("repro/core/", "repro/sim/", "repro/workload/", "repro/")

EVENT_PARAM = "events"
CONSUMERS = {"fold_control_event", "apply_events",
             "ReplicaPool", "ShedMarginSchedule", "PolicySchedule"}


def _calls_sorted_on(fn: ast.FunctionDef, param: str) -> bool:
    """True iff `fn` passes `param` (or an alias of it) through
    ``sorted(...)`` or calls ``.sort()`` on it."""
    aliases = {param}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            names = {n.id for n in ast.walk(node.value)
                     if isinstance(n, ast.Name)}
            if names & aliases:
                aliases.add(node.targets[0].id)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Name) and node.func.id == "sorted":
            names = {n.id for a in node.args for n in ast.walk(a)
                     if isinstance(n, ast.Name)}
            if names & aliases:
                return True
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "sort"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in aliases):
            return True
    return False


def _has_call(fn: ast.FunctionDef, attr_names: Sequence[str]) -> bool:
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in attr_names):
            return True
    return False


def _has_sort(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Name) and node.func.id == "sorted":
            return True
        if isinstance(node.func, ast.Attribute) and node.func.attr == "sort":
            return True
    return False


def _literal_timestamps(node: ast.AST) -> Optional[List[float]]:
    """First components of a literal list/tuple of event tuples, or
    None when the argument is not statically analyzable."""
    if not isinstance(node, (ast.List, ast.Tuple)):
        return None
    ts: List[float] = []
    for elt in node.elts:
        if not isinstance(elt, (ast.Tuple, ast.List)) or not elt.elts:
            return None
        first = elt.elts[0]
        if isinstance(first, ast.UnaryOp) and isinstance(first.op, ast.USub):
            first = first.operand
            sign = -1.0
        else:
            sign = 1.0
        if (isinstance(first, ast.Constant)
                and isinstance(first.value, (int, float))):
            ts.append(sign * float(first.value))
        else:
            return None
    return ts


class Evt01(Rule):
    id = "EVT01"
    title = ("event streams reaching apply_events/fold_control_event "
             "must be provably sorted by time")

    def check(self, modules: Sequence[ModuleSource]) -> Iterable[Finding]:
        for mod in modules:
            if not mod.in_package(*CORE_PACKAGES):
                continue
            yield from self._check_constructors(mod)
            yield from self._check_folders(mod)
            yield from self._check_literal_sites(mod)

    # -- 1. schedule constructors must sort ---------------------------------
    def _check_constructors(self, mod: ModuleSource) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            init = next((n for n in node.body
                         if isinstance(n, ast.FunctionDef)
                         and n.name == "__init__"), None)
            if init is None:
                continue
            params = {a.arg for a in init.args.args}
            params |= {a.arg for a in init.args.kwonlyargs}
            if EVENT_PARAM not in params:
                continue
            if not _calls_sorted_on(init, EVENT_PARAM):
                yield self.finding(
                    mod, init,
                    f"{node.name}.__init__ stores its `{EVENT_PARAM}` "
                    f"stream without sorting it — apply/fold cursors "
                    f"silently skip out-of-order events (the PR 2 bug "
                    f"class); use sorted({EVENT_PARAM}, key=lambda e: "
                    f"e[0]) to stay stable for same-t pairs")

    # -- 2. incremental folders must re-sort after append -------------------
    def _check_folders(self, mod: ModuleSource) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.FunctionDef)
                    and node.name == "fold_control_event"):
                continue
            if (_has_call(node, ("append", "insert"))
                    and not _has_sort(node)):
                yield self.finding(
                    mod, node,
                    "fold_control_event appends to a schedule without "
                    "re-sorting — a late control event lands after "
                    "earlier-times and is skipped by the replay cursor")

    # -- 3. statically decreasing literal event lists -----------------------
    def _check_literal_sites(self, mod: ModuleSource) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None or name.split(".")[-1] not in CONSUMERS:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                ts = _literal_timestamps(arg)
                if ts is None:
                    continue
                if any(b < a for a, b in zip(ts, ts[1:])):
                    yield self.finding(
                        mod, node,
                        f"literal event list passed to "
                        f"{name.split('.')[-1]} has decreasing "
                        f"timestamps {ts} — sort the stream by time "
                        f"before handing it to the consumer")
