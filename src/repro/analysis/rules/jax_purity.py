"""JAX01 — purity of traced bodies (lax.scan steps, Pallas kernels).

JAX traces a body function ONCE and replays the captured computation;
anything the body does on the host side happens at trace time, not at
run time. Closure mutation runs once instead of per-step, Python
``if``/``while`` on a traced value raises ``TracerBoolConversionError``
at best and silently bakes in one branch at worst, and float64
literals upcast against the repo's float32 kernel contract (TPU has no
f64 vector unit; x64 is disabled by default).

The rule finds traced bodies structurally — the function reference in:

* ``lax.scan(body, ...)`` / ``jax.lax.scan`` (arg 0)
* ``lax.while_loop(cond, body, ...)`` (args 0 and 1)
* ``lax.fori_loop(lo, hi, body, ...)`` (arg 2)
* ``lax.map(body, ...)`` (arg 0)
* ``jax.jit(fn)`` / ``jax.vmap(fn)`` (arg 0) and as decorators
* ``pl.pallas_call(kernel, ...)`` (arg 0)

resolving ``functools.partial(fn, ...)`` and plain ``Name`` references
to function defs in the same module. Inside each traced body it flags:

* ``global`` / ``nonlocal`` declarations (closure mutation);
* mutating calls (``.append``/``.extend``/``pop``/…) or subscript
  stores on FREE variables (host-state writes from inside the trace);
* ``print(...)`` (host side effect; use ``jax.debug.print``);
* float64 literals — ``jnp.float64``/``np.float64`` references or
  ``"float64"`` dtype strings;
* Python ``if``/``while`` whose test references a local or parameter
  of a traced function. Free variables of the OUTER factory (compile-
  time flags like ``with_timeout``) stay legal — branching on them
  specializes the trace, which is the intended idiom.

Scope: ``repro/sim/`` and ``repro/kernels/`` — the two places traced
code lives.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Rule
from repro.analysis.findings import Finding
from repro.analysis.source import ModuleSource, dotted_name

JAX_PACKAGES = ("repro/sim/", "repro/kernels/")

# terminal callable name -> indices of traced-function arguments
TRACED_ARGS = {
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "map": (0,),
    "jit": (0,),
    "vmap": (0,),
    "pallas_call": (0,),
    "checkpoint": (0,),
    "remat": (0,),
}

# only treat `map` as traced when it is an attribute call (lax.map),
# never the Python builtin
_ATTR_ONLY = {"map", "scan"}

MUTATING_METHODS = {"append", "extend", "insert", "pop", "remove",
                    "clear", "update", "add", "setdefault", "popitem",
                    "write", "setattr"}

FnDef = ast.FunctionDef


def _resolve_fn(node: ast.AST, local_fns: Dict[str, FnDef]
                ) -> Optional[FnDef]:
    """Resolve an argument expression to a function def in this module:
    a bare Name, a lambda, or functools.partial(fn, ...)."""
    if isinstance(node, ast.Name):
        return local_fns.get(node.id)
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name and name.split(".")[-1] == "partial" and node.args:
            return _resolve_fn(node.args[0], local_fns)
    return None


def _assigned_names(fn: FnDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(
                node.ctx, (ast.Store, ast.Del)):
            out.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)) and node is not fn:
            out.add(node.name)
    return out


def _param_names(fn: FnDef) -> Set[str]:
    a = fn.args
    names = {p.arg for p in a.args + a.kwonlyargs + a.posonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


class Jax01(Rule):
    id = "JAX01"
    title = ("lax.scan bodies and Pallas kernels must be pure: no "
             "closure mutation, host writes, float64 literals, or "
             "Python branching on traced values")

    def check(self, modules: Sequence[ModuleSource]) -> Iterable[Finding]:
        for mod in modules:
            if not mod.in_package(*JAX_PACKAGES):
                continue
            yield from self._check_module(mod)

    def _traced_fns(self, mod: ModuleSource) -> List[FnDef]:
        local_fns: Dict[str, FnDef] = {
            n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, ast.FunctionDef)}
        traced: List[FnDef] = []
        seen: Set[int] = set()

        def mark(fn: Optional[FnDef]) -> None:
            if fn is not None and id(fn) not in seen:
                seen.add(id(fn))
                traced.append(fn)

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name is None:
                    continue
                tail = name.split(".")[-1]
                idxs = TRACED_ARGS.get(tail)
                if idxs is None:
                    continue
                if tail in _ATTR_ONLY and "." not in name:
                    continue
                for i in idxs:
                    if i < len(node.args):
                        mark(_resolve_fn(node.args[i], local_fns))
            elif isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    dname = dotted_name(
                        dec.func if isinstance(dec, ast.Call) else dec)
                    if dname and dname.split(".")[-1] in ("jit", "vmap",
                                                          "checkpoint",
                                                          "remat"):
                        mark(node)
        return traced

    def _check_module(self, mod: ModuleSource) -> Iterable[Finding]:
        traced = self._traced_fns(mod)
        if not traced:
            return
        # locals/params of every traced fn, for the branching check;
        # a nested traced fn also counts its enclosing traced fns
        bound: Dict[int, Set[str]] = {
            id(fn): _param_names(fn) | _assigned_names(fn)
            for fn in traced}
        for fn in traced:
            yield from self._check_body(mod, fn, bound)

    def _check_body(self, mod: ModuleSource, fn: FnDef,
                    bound: Dict[int, Set[str]]) -> Iterable[Finding]:
        own = bound[id(fn)]
        label = f"traced body {fn.name}()"
        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                yield self.finding(
                    mod, node,
                    f"{label} declares "
                    f"{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                    f" {', '.join(node.names)} — closure mutation runs "
                    f"at trace time, once, not per step")
            elif isinstance(node, ast.Call):
                cname = dotted_name(node.func)
                if cname == "print":
                    yield self.finding(
                        mod, node,
                        f"{label} calls print() — host side effect at "
                        f"trace time; use jax.debug.print for runtime "
                        f"values")
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in MUTATING_METHODS
                      and isinstance(node.func.value, ast.Name)
                      and node.func.value.id not in own):
                    yield self.finding(
                        mod, node,
                        f"{label} mutates free variable "
                        f"{node.func.value.id!r} via ."
                        f"{node.func.attr}() — host-state write from "
                        f"inside the trace happens once, at trace time")
            elif isinstance(node, ast.Subscript) and isinstance(
                    node.ctx, (ast.Store, ast.Del)):
                base = node.value
                if isinstance(base, ast.Name) and base.id not in own:
                    yield self.finding(
                        mod, node,
                        f"{label} writes {base.id}[...] on a free "
                        f"variable — host-state write from inside the "
                        f"trace; carry state through the scan carry "
                        f"instead")
            elif isinstance(node, ast.Attribute):
                if node.attr == "float64":
                    yield self.finding(
                        mod, node,
                        f"{label} references float64 — x64 is disabled "
                        f"and the kernel contract is float32; this "
                        f"either upcasts or silently truncates")
            elif (isinstance(node, ast.Constant)
                  and node.value == "float64"):
                yield self.finding(
                    mod, node,
                    f"{label} uses a \"float64\" dtype string — the "
                    f"kernel contract is float32")
            elif isinstance(node, (ast.If, ast.While)):
                # `x is None` / `x is not None` is static under tracing
                # (array-vs-None structure is fixed at trace time) —
                # the standard optional-mask idiom stays legal
                if (isinstance(node.test, ast.Compare)
                        and all(isinstance(op, (ast.Is, ast.IsNot))
                                for op in node.test.ops)):
                    continue
                test_names = {n.id for n in ast.walk(node.test)
                              if isinstance(n, ast.Name)}
                traced_refs = sorted(test_names & own)
                if traced_refs:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield self.finding(
                        mod, node,
                        f"{label} branches with Python `{kind}` on "
                        f"{', '.join(traced_refs)} — traced values "
                        f"cannot drive host control flow; use "
                        f"lax.cond/lax.select (compile-time flags from "
                        f"the enclosing factory are fine)")
