"""Baseline (accepted-findings) file for the analysis pass.

Format — one entry per line, tab-separated, ``#`` comments allowed:

    RULE<TAB>path<TAB>scope<TAB>justification

e.g.::

    DET01\trepro/core/profiler.py\tprofile_model_measured\tprofiler \
measures real wall-clock by design

An entry matches every finding with the same ``(rule, path, scope)``
identity — line numbers are deliberately not part of the identity so a
baseline survives unrelated edits. The justification is mandatory: an
entry without one is a malformed-baseline error, not a suppression (the
same contract as inline ``# analysis: allow`` comments).
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    scope: str
    justification: str

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.scope)

    def render(self) -> str:
        return (f"{self.rule}\t{self.path}\t{self.scope}\t"
                f"{self.justification}")


class BaselineError(ValueError):
    """Malformed baseline file (missing fields / justification)."""


class Baseline:
    """Parsed baseline file; tracks which entries matched a finding so
    stale entries can be reported (a deleted violation should take its
    baseline line with it)."""

    def __init__(self, entries: Optional[List[BaselineEntry]] = None):
        self.entries: Dict[Tuple[str, str, str], BaselineEntry] = {
            e.key: e for e in (entries or [])}
        self._used: set = set()

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        entries: List[BaselineEntry] = []
        for i, raw in enumerate(path.read_text(encoding="utf-8")
                                .splitlines(), start=1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = [p.strip() for p in line.split("\t")]
            if len(parts) < 4 or not all(parts[:4]):
                raise BaselineError(
                    f"{path}:{i}: baseline entries are "
                    f"RULE<TAB>path<TAB>scope<TAB>justification "
                    f"(justification mandatory); got {raw!r}")
            entries.append(BaselineEntry(parts[0], parts[1], parts[2],
                                         "\t".join(parts[3:])))
        return cls(entries)

    def match(self, finding: Finding) -> Optional[BaselineEntry]:
        entry = self.entries.get(finding.key)
        if entry is not None:
            self._used.add(entry.key)
        return entry

    def unused(self) -> List[BaselineEntry]:
        return [e for k, e in sorted(self.entries.items())
                if k not in self._used]
