"""``python -m repro.analysis`` — run the invariant rules over a tree.

Exit codes: 0 clean, 1 findings, 2 usage/baseline errors. ``--json``
emits the full machine-readable report (findings, suppressions, stale
baseline entries) for CI.

The analyzer is pure stdlib (ast/tokenize) on purpose: the CI analysis
job runs it without installing numpy/jax, and it can lint a tree that
does not even import.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.baseline import Baseline, BaselineError
from repro.analysis.core import AnalysisReport, run_analysis
from repro.analysis.rules import ALL_RULES, RULES_BY_ID


def _default_root() -> Path:
    # repo layout: analyzer lives in src/repro/analysis; scanning `src`
    # makes relpaths start at `repro/` (the package the rules scope on)
    here = Path.cwd()
    return here / "src" if (here / "src" / "repro").is_dir() else here


def _default_baseline(root: Path) -> Optional[Path]:
    for cand in (root.parent / "analysis_baseline.txt",
                 root / "analysis_baseline.txt"):
        if cand.is_file():
            return cand
    return None


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant-enforcing static analysis for the "
                    "InferLine repro (determinism, cache-key "
                    "completeness, lock discipline, event sorting, "
                    "JAX purity).")
    p.add_argument("paths", nargs="*", type=Path,
                   help="files or directories to scan "
                        "(default: the whole root)")
    p.add_argument("--root", type=Path, default=None,
                   help="package root used for relative paths and "
                        "package scoping (default: ./src when it holds "
                        "a repro package, else .)")
    p.add_argument("--baseline", type=Path, default=None,
                   help="baseline file of accepted findings (default: "
                        "<root>/../analysis_baseline.txt when present; "
                        "pass a nonexistent path to run without one)")
    p.add_argument("--rules", default=None, metavar="ID[,ID...]",
                   help="comma-separated rule ids to run "
                        "(default: all)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the machine-readable report")
    p.add_argument("--list-rules", action="store_true",
                   help="list rule ids and exit")
    return p


def render_text(report: AnalysisReport) -> str:
    lines: List[str] = [f.render() for f in report.findings]
    if report.findings:
        lines.append("")
    lines.append(
        f"{len(report.findings)} finding(s), "
        f"{len(report.suppressed)} suppressed, "
        f"{report.files_scanned} file(s) scanned, "
        f"rules: {', '.join(report.rules_run)}")
    for e in report.unused_baseline:
        lines.append(f"warning: stale baseline entry {e.rule} "
                     f"{e.path} [{e.scope}] — violation is gone, "
                     f"delete the line")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  {r.title}")
        return 0

    root = (args.root or _default_root()).resolve()
    if not root.is_dir():
        print(f"error: root {root} is not a directory", file=sys.stderr)
        return 2

    if args.rules:
        try:
            rules = [RULES_BY_ID[rid.strip()]()
                     for rid in args.rules.split(",") if rid.strip()]
        except KeyError as e:
            print(f"error: unknown rule {e.args[0]!r} "
                  f"(known: {', '.join(RULES_BY_ID)})", file=sys.stderr)
            return 2
    else:
        rules = [r() for r in ALL_RULES]

    baseline_path = (args.baseline if args.baseline is not None
                     else _default_baseline(root))
    baseline = None
    if baseline_path is not None and Path(baseline_path).is_file():
        try:
            baseline = Baseline.load(Path(baseline_path))
        except BaselineError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    paths = [p if p.is_absolute() else Path.cwd() / p
             for p in args.paths] or None
    report = run_analysis(root, rules, paths=paths, baseline=baseline)

    if args.as_json:
        print(json.dumps(report.as_json(), indent=2))
    else:
        print(render_text(report))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
