"""Parsed-module model shared by every analysis rule.

One :class:`ModuleSource` per file: the AST (with a parent map and
precomputed qualnames), the raw comment table from ``tokenize`` (rules
parse their own annotations out of it, e.g. LOCK01's ``# guarded-by:``),
and the inline-suppression table (``# analysis: allow RULE — why``).

Everything here is pure stdlib — the analyzer must be runnable in a CI
job with no third-party installs.
"""

from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

# Inline suppression: `# analysis: allow DET01 — justification`.
# The justification is MANDATORY: a bare allow does not suppress (the
# finding stands, annotated), so every silenced invariant carries its
# why next to the code.
ALLOW_RE = re.compile(
    r"#\s*analysis:\s*allow\s+([A-Z]+\d+)\s*(?:[-—:]\s*(\S.*))?")


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def names_in(node: ast.AST) -> set:
    """All bare Name identifiers referenced under `node`."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class ModuleSource:
    """One parsed source file plus the lookup tables rules need."""

    def __init__(self, path: Path, relpath: str, text: str):
        self.path = path
        self.relpath = relpath      # posix, relative to the scan root
        self.text = text
        self.tree = ast.parse(text, filename=str(path))
        # parent links + enclosing-scope qualnames, one walk
        self.parent: Dict[ast.AST, ast.AST] = {}
        self.qualname: Dict[ast.AST, str] = {self.tree: "<module>"}
        stack: List[Tuple[ast.AST, str]] = [(self.tree, "")]
        while stack:
            node, prefix = stack.pop()
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                    q = f"{prefix}.{child.name}" if prefix else child.name
                    self.qualname[child] = q
                    stack.append((child, q))
                else:
                    stack.append((child, prefix))
        # comment table: line -> comment text (incl. leading '#')
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenizeError:  # pragma: no cover - ast parsed OK
            pass
        # inline suppressions: line -> {rule: justification}
        self.allow: Dict[int, Dict[str, str]] = {}
        for line, comment in self.comments.items():
            m = ALLOW_RE.search(comment)
            if m and m.group(2):
                self.allow.setdefault(line, {})[m.group(1)] = m.group(2)
        # line -> first line of the innermost statement covering it, so a
        # suppression on a multi-line statement's first line covers the
        # whole span
        self.stmt_start: Dict[int, int] = {}
        spans: Dict[int, int] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.stmt) and node.end_lineno is not None:
                size = node.end_lineno - node.lineno
                for ln in range(node.lineno, node.end_lineno + 1):
                    if ln not in spans or size < spans[ln]:
                        spans[ln] = size
                        self.stmt_start[ln] = node.lineno

    # -- scope helpers ------------------------------------------------------
    def scope_of(self, node: ast.AST) -> str:
        """Dotted qualname of the nearest enclosing def/class."""
        cur: Optional[ast.AST] = node
        while cur is not None:
            q = self.qualname.get(cur)
            if q is not None:
                return q
            cur = self.parent.get(cur)
        return "<module>"

    def enclosing_functions(self, node: ast.AST
                            ) -> Iterator[ast.FunctionDef]:
        """Innermost-first chain of enclosing function definitions."""
        cur = self.parent.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield cur
            cur = self.parent.get(cur)

    def suppression(self, rule: str, line: int) -> Optional[str]:
        """Justification of an inline allow covering (rule, line) —
        trailing on the line, on the statement's first line, or in the
        comment block immediately above the statement."""
        start = self.stmt_start.get(line, line)
        candidates = [line, start]
        ln = start - 1
        while ln in self.comments:      # comment block above the stmt
            candidates.append(ln)
            ln -= 1
        for ln in candidates:
            just = self.allow.get(ln, {}).get(rule)
            if just:
                return just
        return None

    def in_package(self, *prefixes: str) -> bool:
        return any(self.relpath.startswith(p) for p in prefixes)


def load_module(path: Path, root: Path) -> ModuleSource:
    text = path.read_text(encoding="utf-8")
    rel = path.relative_to(root).as_posix()
    return ModuleSource(path, rel, text)
