"""Checker framework: rule base class, the runner, and the report.

The pass is a custom AST analyzer for THIS repo's invariants — the bug
classes that previously shipped and were fixed after the fact:

* unsorted control-event streams (PR 2)  -> EVT01
* executor shared-state races (PR 5)     -> LOCK01
* stale cone-cache keys (PR 6)           -> KEY01

plus the two standing determinism contracts the planner's trust rests
on: no wall-clock / unseeded RNG in the simulation core (DET01) and
pure ``lax.scan`` bodies / Pallas kernels (JAX01).

Rules receive EVERY parsed module at once (several rules cross-check
definitions in one file against usage in another) and yield
:class:`~repro.analysis.findings.Finding` objects. The runner applies
inline suppressions and the repo baseline, and packages the result.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.baseline import Baseline, BaselineEntry
from repro.analysis.findings import Finding
from repro.analysis.source import ModuleSource, load_module


class Rule:
    """One invariant checker. Subclasses set ``id``/``title`` and
    implement :meth:`check` over the full module set."""

    id: str = "RULE00"
    title: str = ""

    def check(self, modules: Sequence[ModuleSource]) -> Iterable[Finding]:
        raise NotImplementedError

    # convenience for subclasses
    def finding(self, mod: ModuleSource, node: ast.AST, message: str
                ) -> Finding:
        return Finding(self.id, mod.relpath, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0) + 1,
                       mod.scope_of(node), message)


@dataclasses.dataclass
class SuppressedFinding:
    finding: Finding
    justification: str
    via: str                      # "inline" | "baseline"

    def as_json(self) -> Dict[str, object]:
        out = self.finding.as_json()
        out["justification"] = self.justification
        out["via"] = self.via
        return out


@dataclasses.dataclass
class AnalysisReport:
    findings: List[Finding]
    suppressed: List[SuppressedFinding]
    unused_baseline: List[BaselineEntry]
    files_scanned: int
    rules_run: List[str]

    @property
    def ok(self) -> bool:
        return not self.findings

    def as_json(self) -> Dict[str, object]:
        return {
            "version": 1,
            "ok": self.ok,
            "files_scanned": self.files_scanned,
            "rules_run": self.rules_run,
            "findings": [f.as_json() for f in self.findings],
            "suppressed": [s.as_json() for s in self.suppressed],
            "unused_baseline": [
                {"rule": e.rule, "path": e.path, "scope": e.scope,
                 "justification": e.justification}
                for e in self.unused_baseline
            ],
        }


def collect_modules(root: Path,
                    paths: Optional[Sequence[Path]] = None
                    ) -> Tuple[List[ModuleSource], List[str]]:
    """Parse every ``.py`` file under `paths` (default: all of `root`).

    Returns (modules, parse_errors) — a syntax error in one file must
    not hide findings in the rest of the tree.
    """
    files: List[Path] = []
    for p in (paths or [root]):
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    modules: List[ModuleSource] = []
    errors: List[str] = []
    for f in files:
        if "__pycache__" in f.parts:
            continue
        try:
            modules.append(load_module(f, root))
        except (SyntaxError, UnicodeDecodeError, ValueError) as e:
            errors.append(f"{f}: {e}")
    return modules, errors


def run_analysis(root: Path,
                 rules: Sequence[Rule],
                 paths: Optional[Sequence[Path]] = None,
                 baseline: Optional[Baseline] = None) -> AnalysisReport:
    modules, errors = collect_modules(root, paths)
    baseline = baseline or Baseline()
    findings: List[Finding] = []
    suppressed: List[SuppressedFinding] = []
    by_rel = {m.relpath: m for m in modules}
    for rule in rules:
        for f in rule.check(modules):
            mod = by_rel.get(f.path)
            just = mod.suppression(f.rule, f.line) if mod else None
            if just is not None:
                suppressed.append(SuppressedFinding(f, just, "inline"))
                continue
            entry = baseline.match(f)
            if entry is not None:
                suppressed.append(
                    SuppressedFinding(f, entry.justification, "baseline"))
                continue
            findings.append(f)
    for err in errors:
        findings.append(Finding("PARSE", "<errors>", 1, 1, "<module>", err))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return AnalysisReport(findings, suppressed, baseline.unused(),
                          len(modules), [r.id for r in rules])
