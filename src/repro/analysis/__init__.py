"""Invariant-enforcing static analysis for the InferLine repro.

Pure-stdlib AST rules that pin the repo's standing invariants — each
one backed by a bug class that previously shipped:

* DET01 — no wall-clock / unseeded RNG in the simulation core
* KEY01 — cache-key completeness for the cone caches (PR 6)
* LOCK01 — guarded-by lock discipline in repro.serving (PR 5)
* EVT01 — control-event streams provably sorted (PR 2)
* JAX01 — pure lax.scan bodies and Pallas kernels

Run ``python -m repro.analysis`` (see ``--help``); suppress a finding
inline with ``# analysis: allow RULE — justification`` or in
``analysis_baseline.txt``.
"""

from repro.analysis.baseline import Baseline, BaselineEntry, BaselineError
from repro.analysis.core import (AnalysisReport, Rule, SuppressedFinding,
                                 collect_modules, run_analysis)
from repro.analysis.findings import Finding
from repro.analysis.rules import ALL_RULES, RULES_BY_ID

__all__ = [
    "ALL_RULES", "RULES_BY_ID", "AnalysisReport", "Baseline",
    "BaselineEntry", "BaselineError", "Finding", "Rule",
    "SuppressedFinding", "collect_modules", "run_analysis",
]
