"""Finding objects for the invariant-enforcing static-analysis pass.

A :class:`Finding` is one rule violation anchored to a ``file:line``
span. Findings are plain data — the CLI renders them as text or JSON,
and the suppression machinery (inline ``# analysis: allow`` comments and
the repo baseline file) matches on their identity fields.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``scope`` is the dotted qualname of the enclosing class/function
    (``<module>`` at module level) — together with ``rule`` and ``path``
    it forms the line-number-stable identity the baseline file matches
    on, so baselined findings survive unrelated edits to the same file.
    """

    rule: str          # e.g. "DET01"
    path: str          # root-relative posix path, e.g. "repro/sim/engine.py"
    line: int
    col: int
    scope: str         # dotted qualname of the enclosing def/class
    message: str

    @property
    def key(self):
        """Baseline identity (line numbers deliberately excluded)."""
        return (self.rule, self.path, self.scope)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.scope}] {self.message}")

    def as_json(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "scope": self.scope,
            "message": self.message,
        }
