"""Assigned-architecture registry: ``get_arch(name)`` / ``--arch <id>``.

Each module exports ``ARCH`` (the exact assigned config) and ``SMOKE``
(a reduced same-family variant for CPU smoke tests). ``llama3.2-1b-sw``
is the sliding-window variant that unlocks the long_500k decode shape for
one dense architecture (DESIGN.md §4).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

_MODULES = {
    "whisper-small": "whisper_small",
    "granite-34b": "granite_34b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "pixtral-12b": "pixtral_12b",
    "qwen2-72b": "qwen2_72b",
    "xlstm-125m": "xlstm_125m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama3.2-1b": "llama3_2_1b",
    "llama3.2-1b-sw": "llama3_2_1b",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "llama3.2-1b-sw"]


def _load(name: str):
    try:
        mod = _MODULES[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{mod}")


def get_arch(name: str) -> ArchConfig:
    mod = _load(name)
    if name == "llama3.2-1b-sw":
        return mod.ARCH_SW
    return mod.ARCH


def get_smoke(name: str) -> ArchConfig:
    mod = _load(name)
    if name == "llama3.2-1b-sw":
        return mod.SMOKE_SW
    return mod.SMOKE


def all_archs() -> Dict[str, ArchConfig]:
    return {name: get_arch(name) for name in ARCH_IDS}
