"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409].

Decoder backbone only (the assignment's carve-out): 40L d_model=5120 32H
(GQA kv=8, head_dim=128) d_ff=14336 vocab=131072. The ViT frontend is a
stub — ``input_specs`` provides 1024 precomputed patch embeddings that a
learned projector maps into d_model and prepends to the text tokens.
"""

from repro.models.config import ArchConfig, dense_segments, scale_down

ARCH = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    segments=dense_segments(40),
    rope_theta=1000000.0,
    num_image_tokens=1024,
)

SMOKE = scale_down(ARCH)
