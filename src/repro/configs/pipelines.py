"""The paper's four pipeline motifs (Fig. 2) bound to assigned archs.

Each motif is a Pipeline whose stages reference assigned architectures;
per-stage ModelSpecs are derived analytically from the ArchConfig (FLOPs /
weight bytes / activation and TP-collective traffic per query), so the
Profiler's analytic backend prices each (model, hardware, batch) point
without hardware. A "query" at a stage is one inference at that stage's
native input size (`seq_in` tokens scored, classification-style).

Hardware menus are capacity-filtered: a model only lists accelerator
slices whose aggregate HBM holds its bf16 weights (the planner's §9
total-latency-ordering assumption still holds on the filtered menu).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import get_arch
from repro.core.hardware import HARDWARE_MENU, HBM_BYTES
from repro.core.pipeline import SOURCE, Edge, Pipeline, Stage
from repro.core.profiler import (
    ModelSpec,
    ProfileStore,
    profile_model_analytic,
)

BYTES_PER_PARAM = 2  # bf16 serving


def arch_model_spec(arch_id: str, seq_in: int,
                    name: Optional[str] = None) -> ModelSpec:
    """Analytic per-query workload description for one assigned arch."""
    cfg = get_arch(arch_id)
    flops = cfg.flops_per_token(seq_in) * seq_in
    weight_bytes = cfg.active_param_count() * BYTES_PER_PARAM
    act_bytes = 4 * seq_in * cfg.d_model * BYTES_PER_PARAM
    # TP traffic: 2 all-reduces per layer of the (seq, d_model) activation
    coll = 2 * cfg.num_layers * seq_in * cfg.d_model * BYTES_PER_PARAM
    return ModelSpec(
        name or arch_id,
        flops_per_query=float(flops),
        weight_bytes=float(weight_bytes),
        act_bytes_per_query=float(act_bytes),
        collective_bytes_per_query=float(coll),
    )


def transform_spec(name: str, flops: float = 2e9) -> ModelSpec:
    """Non-parallelizable basic data transform (paper Fig. 3 preprocess)."""
    return ModelSpec(name, flops_per_query=flops, weight_bytes=1e6,
                     act_bytes_per_query=1e6, parallelizable=False)


def _resident_bytes(arch_id: str) -> float:
    """All weights must be HBM-resident to serve (not just active)."""
    return get_arch(arch_id).param_count() * BYTES_PER_PARAM


def hardware_menu_for(spec: ModelSpec,
                      resident_bytes: Optional[float] = None
                      ) -> Tuple[str, ...]:
    """Capacity-filtered hardware options for one model."""
    if not spec.parallelizable:
        return ("cpu-1",)
    need = resident_bytes if resident_bytes is not None else \
        spec.weight_bytes
    out = []
    for h in HARDWARE_MENU:
        if h.chips == 0:
            out.append(h.name)            # host DRAM holds anything
        elif need <= 0.9 * h.chips * HBM_BYTES:
            out.append(h.name)
    return tuple(out)


@dataclasses.dataclass
class BoundPipeline:
    pipeline: Pipeline
    profiles: ProfileStore


def _build(name: str,
           stages: Sequence[Tuple[str, ModelSpec, Optional[float]]],
           edges: List[Edge]) -> BoundPipeline:
    """stages: (stage_name, spec, resident_bytes or None)."""
    store = ProfileStore()
    st: Dict[str, Stage] = {}
    for sname, spec, resident in stages:
        menu = hardware_menu_for(spec, resident)
        store.add(profile_model_analytic(spec, hardware_options=menu))
        st[sname] = Stage(sname, spec.name, menu)
    return BoundPipeline(Pipeline(name, st, edges), store)


# ---------------------------------------------------------------- motifs

def image_processing() -> BoundPipeline:
    """preprocess -> VLM classification (Fig. 2a)."""
    prep = transform_spec("preprocess")
    cls = arch_model_spec("pixtral-12b", seq_in=1024 + 16, name="classify")
    return _build(
        "image-processing",
        [("preprocess", prep, None),
         ("classify", cls, _resident_bytes("pixtral-12b"))],
        [Edge(SOURCE, "preprocess"), Edge("preprocess", "classify")],
    )


def video_monitoring() -> BoundPipeline:
    """detect -> {vehicle, person(+audio transcribe)} conditionals
    (Fig. 2b, inspired by VideoStorm)."""
    detect = arch_model_spec("pixtral-12b", seq_in=1024 + 16, name="detect")
    vehicle = arch_model_spec("llama3.2-1b", seq_in=256, name="vehicle_id")
    person = arch_model_spec("phi3-mini-3.8b", seq_in=256, name="person_id")
    plate = arch_model_spec("granite-moe-1b-a400m", seq_in=128,
                            name="plate_ocr")
    audio = arch_model_spec("whisper-small", seq_in=448, name="transcribe")
    return _build(
        "video-monitoring",
        [("detect", detect, _resident_bytes("pixtral-12b")),
         ("vehicle_id", vehicle, _resident_bytes("llama3.2-1b")),
         ("person_id", person, _resident_bytes("phi3-mini-3.8b")),
         ("plate_ocr", plate, _resident_bytes("granite-moe-1b-a400m")),
         ("transcribe", audio, _resident_bytes("whisper-small"))],
        [Edge(SOURCE, "detect"),
         Edge(SOURCE, "transcribe"),
         Edge("detect", "vehicle_id", probability=0.4),
         Edge("detect", "person_id", probability=0.3),
         Edge("vehicle_id", "plate_ocr", probability=0.5)],
    )


def social_media() -> BoundPipeline:
    """lang-id -> (translate?) -> categorize, + image branch (Fig. 2c)."""
    lang = arch_model_spec("xlstm-125m", seq_in=128, name="lang_id")
    translate = arch_model_spec("qwen2-72b", seq_in=256, name="translate")
    img = arch_model_spec("pixtral-12b", seq_in=1024 + 16, name="img_cls")
    cat = arch_model_spec("llama3.2-1b", seq_in=256, name="categorize")
    return _build(
        "social-media",
        [("lang_id", lang, _resident_bytes("xlstm-125m")),
         ("translate", translate, _resident_bytes("qwen2-72b")),
         ("img_cls", img, _resident_bytes("pixtral-12b")),
         ("categorize", cat, _resident_bytes("llama3.2-1b"))],
        [Edge(SOURCE, "lang_id"),
         Edge(SOURCE, "img_cls", probability=0.5),
         Edge("lang_id", "translate", probability=0.4),
         Edge("translate", "categorize"),
         Edge("lang_id", "categorize", probability=0.6),
         Edge("img_cls", "categorize")],
    )


def tf_cascade() -> BoundPipeline:
    """fast model -> slow model when uncertain (Fig. 2d)."""
    fast = arch_model_spec("llama3.2-1b", seq_in=256, name="fast")
    slow = arch_model_spec("granite-34b", seq_in=256, name="slow")
    return _build(
        "tf-cascade",
        [("fast", fast, _resident_bytes("llama3.2-1b")),
         ("slow", slow, _resident_bytes("granite-34b"))],
        [Edge(SOURCE, "fast"), Edge("fast", "slow", probability=0.2)],
    )


MOTIFS = {
    "image-processing": image_processing,
    "video-monitoring": video_monitoring,
    "social-media": social_media,
    "tf-cascade": tf_cascade,
}


def get_motif(name: str) -> BoundPipeline:
    try:
        return MOTIFS[name]()
    except KeyError:
        raise KeyError(f"unknown motif {name!r}; have {sorted(MOTIFS)}")
