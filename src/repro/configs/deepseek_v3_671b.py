"""deepseek-v3-671b [moe] — MLA + 1 shared/256 routed top-8 + MTP
[arXiv:2412.19437].

61L d_model=7168 128H vocab=129280. MLA: q_lora 1536, kv_lora 512,
qk_nope 128, qk_rope 64, v_head 128 (the latent cache is the serving
memory win). FFN: first 3 layers dense (hidden 18432, per the paper);
remaining 58 layers MoE with 256 routed experts (hidden 2048 — the
assignment's d_ff) top-8 plus 1 shared expert. MTP depth 1.
"""

from repro.models.config import ArchConfig, Block, Segment, scale_down

ARCH = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    segments=(
        Segment((Block("attn", "dense"),), 3),
        Segment((Block("attn", "moe"),), 58),
    ),
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    mtp_depth=1,
)

SMOKE = scale_down(ARCH)
