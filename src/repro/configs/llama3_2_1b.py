"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256, head_dim 64,
RoPE + SwiGLU, tied embeddings. ``ARCH_SW`` is the sliding-window (8192)
variant used for the long_500k decode shape (DESIGN.md §4).
"""

import dataclasses

from repro.models.config import ArchConfig, dense_segments, scale_down

ARCH = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    segments=dense_segments(16),
    rope_theta=500000.0,
    tie_embeddings=True,
)

ARCH_SW = dataclasses.replace(ARCH, name="llama3.2-1b-sw",
                              sliding_window=8192)

SMOKE = scale_down(ARCH)
SMOKE_SW = dataclasses.replace(scale_down(ARCH_SW), sliding_window=64)
