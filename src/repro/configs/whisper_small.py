"""whisper-small [audio] — enc-dec with stubbed conv frontend
[arXiv:2212.04356].

Transformer backbone only: 12L encoder + 12L decoder, d_model=768 12H
(kv=12, MHA) d_ff=3072 vocab=51865. The mel-spectrogram + conv feature
extractor is a stub: ``input_specs`` provides precomputed frame features
(F, 128) which a learned projector lifts to d_model (sinusoidal positions
on the encoder). Deviation noted in DESIGN.md: the decoder uses RoPE
instead of whisper's learned absolute embeddings.
"""

from repro.models.config import ArchConfig, Block, Segment, scale_down

ARCH = ArchConfig(
    name="whisper-small",
    family="encdec",
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    segments=(Segment((Block("attn", "dense"),), 12),),
    encoder_segments=(Segment((Block("attn", "dense"),), 12),),
    encoder_max_frames=1500,
)

SMOKE = scale_down(ARCH)
