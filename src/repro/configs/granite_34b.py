"""granite-34b [dense] — MQA code model (non-gated GELU MLP) [arXiv:2405.04324].

88L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
"""

from repro.models.config import ArchConfig, dense_segments, scale_down

ARCH = ArchConfig(
    name="granite-34b",
    family="dense",
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    segments=dense_segments(88),
    act="gelu",
)

SMOKE = scale_down(ARCH)
