"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H vocab=50304, d_ff=0 (cells carry their own
projections). Pattern: 3 mLSTM (chunkwise-parallel matrix memory)
followed by 1 sLSTM (sequential scalar memory), repeated 3x — an xLSTM
[7:1]-style mix at 12-layer scale. Fully recurrent => long_500k capable.
"""

from repro.models.config import ArchConfig, Block, Segment, scale_down

_PATTERN = (
    Block("mlstm", "none"),
    Block("mlstm", "none"),
    Block("mlstm", "none"),
    Block("slstm", "none"),
)

ARCH = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    segments=(Segment(_PATTERN, 3),),
    tie_embeddings=True,
)

SMOKE = scale_down(ARCH)
