"""qwen2-72b [dense] — GQA with QKV bias [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""

from repro.models.config import ArchConfig, dense_segments, scale_down

ARCH = ArchConfig(
    name="qwen2-72b",
    family="dense",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    segments=dense_segments(80),
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = scale_down(ARCH)
