"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave + MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) vocab=65536. Period of 8 layers: one
attention layer (index 4) + seven Mamba layers; MoE (16 experts, top-2,
hidden 24576 = the assignment's d_ff) on every other layer, dense MLP on
the rest. 9 periods = 72 layers. Attention is a 12.5% minority => the
long_500k decode shape runs natively (KV cache only for 9 layers).
"""

from repro.models.config import ArchConfig, Block, Segment, scale_down

_PATTERN = tuple(
    Block("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

ARCH = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    segments=(Segment(_PATTERN, 9),),
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)

SMOKE = scale_down(ARCH)
