"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) vocab=49155, MoE every layer:
32 experts, top-8, expert hidden 512 (the assignment's d_ff).
"""

from repro.models.config import ArchConfig, Block, Segment, scale_down

ARCH = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    segments=(Segment((Block("attn", "moe"),), 24),),
    num_experts=32,
    num_experts_per_tok=8,
    moe_d_ff=512,
    tie_embeddings=True,
)

SMOKE = scale_down(ARCH)
