"""Quickstart: provision an ML prediction pipeline with InferLine.

Plans the paper's Social Media pipeline (bound to the assigned
architectures) against a synthetic bursty workload, deploys it to the
discrete-event cluster, and serves a held-out trace with the
high-frequency Tuner in the loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.pipelines import get_motif
from repro.core.estimator import Estimator
from repro.core.planner import Planner
from repro.core.tuner import Tuner, TunerPlanInfo, run_tuner_offline
from repro.serving.cluster import LiveClusterSim
from repro.workload.generator import gamma_trace, rate_ramp_trace

SLO = 0.15          # 150 ms end-to-end P99 target
LAMBDA, CV = 120.0, 1.5


def main() -> None:
    bound = get_motif("social-media")
    pipe, profiles = bound.pipeline, bound.profiles
    print(f"pipeline: {pipe.name}  stages: {list(pipe.stages)}")
    print(f"scale factors: { {k: round(v, 2) for k, v in pipe.scale_factors().items()} }\n")

    # --- low-frequency planning (Profiler -> Estimator -> Planner) -------
    sample = gamma_trace(LAMBDA, CV, duration_s=60, seed=0)
    planner = Planner(pipe, profiles)
    plan = planner.plan(sample, SLO)
    print("planner result:")
    print(plan.describe(), "\n")
    assert plan.feasible

    # --- deploy + serve with the high-frequency Tuner ---------------------
    est = Estimator(pipe, profiles)
    info = TunerPlanInfo.from_plan(pipe, plan.config, profiles, sample,
                                   est.service_time(plan.config))
    live = rate_ramp_trace(LAMBDA, 2 * LAMBDA, CV, pre_s=30, ramp_s=30,
                           post_s=60, seed=1)
    sim = LiveClusterSim(pipe, profiles, plan.config, SLO)
    static = sim.run(live)
    tuned = sim.run(live, schedule_fn=lambda arr: run_tuner_offline(
        Tuner(info), arr))

    print(f"live serving of a {LAMBDA}->{2*LAMBDA} qps ramp:")
    print(f"  static plan : miss={static.miss_rate:7.4f} "
          f"mean cost=${static.mean_cost_per_hr():.2f}/hr")
    print(f"  with Tuner  : miss={tuned.miss_rate:7.4f} "
          f"mean cost=${tuned.mean_cost_per_hr():.2f}/hr")
    print(f"  tuner scale events: "
          f"{sum(len(v) for v in tuned.replica_timeline.values())}")


if __name__ == "__main__":
    main()
