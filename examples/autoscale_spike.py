"""Traffic-spike autoscaling walkthrough (the Fig. 6 scenario, small).

Serves an AutoScale-derived real-workload trace through the Video
Monitoring pipeline and prints a timeline of the Tuner's decisions:
which envelope window tripped, which stages scaled, and the cost curve
— the mechanics of §5 made visible.

Run:  PYTHONPATH=src python examples/autoscale_spike.py
"""

import numpy as np

from repro.configs.pipelines import get_motif
from repro.core.envelope import TrafficEnvelope
from repro.core.estimator import Estimator
from repro.core.planner import Planner
from repro.core.tuner import Tuner, TunerPlanInfo, run_tuner_offline
from repro.serving.cluster import LiveClusterSim
from repro.workload.traces import autoscale_derived_trace, split_plan_serve

SLO = 0.2
MAX_QPS = 80.0


def main() -> None:
    bound = get_motif("video-monitoring")
    pipe, profiles = bound.pipeline, bound.profiles

    trace = autoscale_derived_trace("big_spike", max_qps=MAX_QPS, seed=7)
    plan_trace, serve_trace = split_plan_serve(trace, 0.25)
    print(f"trace: {trace.size} queries over {trace.max():.0f}s "
          f"(plan on first 25%)\n")

    plan = Planner(pipe, profiles).plan(plan_trace, SLO)
    print("planned configuration:")
    print(plan.describe(), "\n")

    est = Estimator(pipe, profiles)
    info = TunerPlanInfo.from_plan(pipe, plan.config, profiles, plan_trace,
                                   est.service_time(plan.config))
    print("planned traffic envelope (multi-timescale, §5):")
    print(info.planned_envelope.describe(), "\n")

    tuner = Tuner(info)
    sim = LiveClusterSim(pipe, profiles, plan.config, SLO)
    run = sim.run(serve_trace,
                  schedule_fn=lambda arr: run_tuner_offline(tuner, arr))

    print("tuner events (first 12):")
    for t, kind, stage, delta in tuner.events[:12]:
        print(f"  t={t:7.1f}s  {kind:4s}  {stage:12s}  {delta:+d}")
    print(f"  ... {len(tuner.events)} total\n")

    # live envelope at the spike peak vs plan
    peak_t = serve_trace[np.argmax(np.convolve(
        np.histogram(serve_trace, bins=int(serve_trace.max()))[0],
        np.ones(5), "same"))]
    recent = serve_trace[(serve_trace > peak_t - 60) & (serve_trace <= peak_t)]
    live_env = TrafficEnvelope.from_trace(recent, info.service_time_s)
    exceeded, r_max = info.planned_envelope.exceeded_by(live_env)
    print(f"envelope at spike peak (t={peak_t:.0f}s): exceeded={exceeded} "
          f"r_max={r_max:.1f} qps\n")

    print(f"result: attainment={run.attainment*100:.2f}%  "
          f"total=${run.total_cost():.2f}  "
          f"mean=${run.mean_cost_per_hr():.2f}/hr")
    static = sim.run(serve_trace)
    print(f"static would be: attainment={static.attainment*100:.2f}%  "
          f"mean=${static.mean_cost_per_hr():.2f}/hr")


if __name__ == "__main__":
    main()
