"""End-to-end driver: serve REAL JAX models with a closed-loop Tuner.

The paper's kind is a serving system, so the end-to-end example deploys
actual jitted models (reduced variants of two assigned architectures) on
this host with the real thread-pool executor:

  1. measured-profile both models with the Profiler's wall-clock backend,
  2. plan the two-stage cascade with the Planner against the profile,
  3. deploy the planned config to PipelineExecutor (real centralized
     policy-aware batched queues + replica threads),
  4. serve a Poisson trace of batched requests and report latency vs the
     Estimator's prediction (Fig. 8 fidelity),
  5. close the loop: a traffic spike hits the running pipeline and the
     ClosedLoopTuner — the same controller used in co-simulation —
     scales the real replica fleet through the LiveControlLoop.

Run:  PYTHONPATH=src python examples/serve_real_models.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.estimator import Estimator
from repro.core.pipeline import linear_pipeline
from repro.core.planner import Planner
from repro.core.profiler import ProfileStore, profile_model_measured
from repro.core.tuner import ClosedLoopTuner, TunerPlanInfo
from repro.models import build_model
from repro.serving.executor import PipelineExecutor
from repro.serving.loop import LiveControlLoop
from repro.workload.generator import gamma_trace

SEQ = 32
SLO = 0.25          # 250 ms end-to-end on this CPU host
LAMBDA = 30.0       # queries/s


def make_stage(arch_id: str):
    """Build a reduced model + a jitted batch scoring function."""
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    @jax.jit
    def score(tokens):
        logits, _ = model.forward(params, {"tokens": tokens})
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        # cascade payload: shift the window and append the prediction so
        # the downstream stage receives the same (SEQ,) token shape
        return jnp.concatenate([tokens[:, 1:], nxt[:, None]], axis=1)

    def run_batch(payloads):
        # pad to the next power-of-two bucket: variable batch sizes
        # would trigger a fresh XLA compile per size (seconds each) and
        # collapse the pipeline — bucketing is standard serving practice
        n = len(payloads)
        bucket = 1
        while bucket < n:
            bucket *= 2
        tokens = jnp.stack([jnp.asarray(p, jnp.int32) for p in payloads]
                           + [jnp.zeros((SEQ,), jnp.int32)] * (bucket - n))
        out = jax.block_until_ready(score(tokens))
        return [np.asarray(o) for o in out[:n]]

    def profile_fn(b):
        toks = jnp.ones((b, SEQ), jnp.int32)
        jax.block_until_ready(score(toks))

    def warmup(max_batch: int = 128):
        bkt = 1
        while bkt <= max_batch:
            profile_fn(bkt)
            bkt *= 2

    return cfg, run_batch, profile_fn, warmup


def main() -> None:
    print("building models (xlstm-125m-smoke -> llama3.2-1b-smoke cascade)")
    cfg_a, run_a, prof_a, warm_a = make_stage("xlstm-125m")
    cfg_b, run_b, prof_b, warm_b = make_stage("llama3.2-1b")

    print("profiling (measured wall-clock backend) ...")
    store = ProfileStore()
    store.add(profile_model_measured("stage_a", prof_a,
                                     batch_sizes=(1, 2, 4, 8, 16)))
    store.add(profile_model_measured("stage_b", prof_b,
                                     batch_sizes=(1, 2, 4, 8, 16)))
    for mid in ("stage_a", "stage_b"):
        p = store.get(mid)
        print(f"  {mid}: lat(b=1)={p.batch_latency('cpu-1', 1)*1e3:.1f}ms "
              f"lat(b=8)={p.batch_latency('cpu-1', 8)*1e3:.1f}ms "
              f"max_thru={p.max_throughput('cpu-1'):.1f} qps")

    pipe = linear_pipeline("cascade", ["stage_a", "stage_b"],
                           {"stage_a": ["cpu-1"], "stage_b": ["cpu-1"]})
    sample = gamma_trace(LAMBDA, 1.0, 20, seed=0)
    plan = Planner(pipe, store).plan(sample, SLO)
    print("\nplanned configuration:")
    print(plan.describe())
    if not plan.feasible:
        raise SystemExit("infeasible on this host; lower LAMBDA")

    print("\nwarming batch buckets (pow2 up to 128) ...")
    warm_a()
    warm_b()

    print("deploying to the real executor and serving 15 s of traffic...")
    solo = {s: store.get(pipe.stages[s].model_id).batch_latency("cpu-1", 1)
            for s in pipe.stages}
    ex = PipelineExecutor(pipe, plan.config, {
        "stage_a": run_a, "stage_b": run_b,
    }, solo_latency_s=solo)
    live = gamma_trace(LAMBDA, 1.0, 15, seed=1)
    payload = lambda i: jnp.ones((SEQ,), jnp.int32) * (i % 50)  # noqa: E731
    lat = ex.serve_trace(live, payload, slo_s=SLO)

    est = Estimator(pipe, store)
    predicted = est.simulate(plan.config, live)
    print(f"\nserved {lat.size} queries:")
    print(f"  measured  p50={np.percentile(lat, 50)*1e3:7.1f}ms  "
          f"p99={np.percentile(lat, 99)*1e3:7.1f}ms  "
          f"miss={float((lat > SLO).mean()):.4f}")
    print(f"  estimator p50={predicted.percentile(50)*1e3:7.1f}ms  "
          f"p99={predicted.p99*1e3:7.1f}ms (Fig. 8 fidelity check)")
    print(f"  mean batch sizes: "
          f"{ {k: round(v, 1) for k, v in ex.batch_stats().items()} }")

    # ---- close the loop on the running pipeline -------------------------
    # the ClosedLoopTuner drives REAL threads through the same
    # step(EpochTelemetry) interface it uses in co-simulation; a 3x
    # traffic spike should scale the fleet up, then drain it back down
    print("\nclosed loop: 3x spike against the live executor ...")
    service = est.service_time(plan.config)
    info = TunerPlanInfo.from_plan(pipe, plan.config, store,
                                   gamma_trace(LAMBDA, 1.0, 60, seed=2),
                                   service)
    tuner = ClosedLoopTuner(info, max_replicas=4)
    loop = LiveControlLoop(ex, SLO, epoch_s=1.0, service_time_s=service)
    # the tail outlives DOWNSCALE_HYSTERESIS_S so the drain-and-retire
    # half of the lifecycle shows up too
    spike = np.concatenate([
        gamma_trace(LAMBDA, 1.0, 8, seed=3),
        8.0 + gamma_trace(3 * LAMBDA, 0.7, 5, seed=4),
        13.0 + gamma_trace(LAMBDA, 1.0, 17, seed=5)])
    run = loop.run(spike, tuner, payload)
    print(f"  served {run.latency.size} queries, "
          f"miss={run.miss_rate:.4f}, released={run.released}")
    for ev in run.events:
        print(f"  t={ev.t:5.1f}s  {ev.kind:6s} {ev.stage:16s} "
              f"value={ev.value:+.0f}")
    for stage, tl in run.replica_timeline.items():
        print(f"  {stage} replicas: " +
              " -> ".join(f"{c}@{t:.0f}s" for t, c in tl))
    ex.shutdown()


if __name__ == "__main__":
    main()
