"""Train any assigned architecture (reduced variant) on synthetic data.

Demonstrates the training substrate end-to-end on CPU: config -> model
-> data pipeline -> AdamW -> checkpoint save/restore. ~20M-parameter
reduced variants train a few hundred steps in minutes; loss decreases on
the learnable bigram corpus.

Run:  PYTHONPATH=src python examples/train_arch.py --arch llama3.2-1b \
          --steps 200
"""

import argparse
import os

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models import build_model
from repro.train import checkpoint
from repro.train.data import batches
from repro.train.optimizer import AdamW
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="artifacts/example_ckpt.npz")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"arch={args.arch} (reduced)  params={n/1e6:.1f}M  "
          f"layers={cfg.num_layers}  d_model={cfg.d_model}")

    trainer = Trainer(model, AdamW(lr=args.lr), log_every=20)
    data = batches(cfg, args.batch, args.seq, seed=0, steps=args.steps)
    params, opt_state, losses = trainer.fit(params, data, args.steps)

    print(f"\nloss: first10={np.mean(losses[:10]):.4f}  "
          f"last10={np.mean(losses[-10:]):.4f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "did not learn"

    os.makedirs(os.path.dirname(args.ckpt), exist_ok=True)
    checkpoint.save(args.ckpt, params)
    restored = checkpoint.restore(args.ckpt, params)
    leaves_a = jax.tree_util.tree_leaves(params)
    leaves_b = jax.tree_util.tree_leaves(restored)
    assert all(np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
               for a, b in zip(leaves_a, leaves_b))
    print(f"checkpoint round-trip OK -> {args.ckpt}")


if __name__ == "__main__":
    main()
