"""Workload generators: statistical properties of synthetic traces."""

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.workload.generator import (
    cv_ramp_trace,
    empirical_rate,
    gamma_trace,
    rate_ramp_trace,
    time_varying_trace,
)
from repro.workload.traces import autoscale_derived_trace, split_plan_serve


def _cv(arr):
    gaps = np.diff(arr)
    return gaps.var() / gaps.mean() ** 2


def test_gamma_trace_rate():
    arr = gamma_trace(lam=200.0, cv=1.0, duration_s=120.0, seed=0)
    rate = arr.size / 120.0
    assert rate == pytest.approx(200.0, rel=0.05)


@pytest.mark.parametrize("cv", [0.5, 1.0, 4.0])
def test_gamma_trace_cv(cv):
    arr = gamma_trace(lam=100.0, cv=cv, duration_s=600.0, seed=1)
    assert _cv(arr) == pytest.approx(cv, rel=0.15)


def test_gamma_trace_sorted_and_bounded():
    arr = gamma_trace(lam=50.0, cv=2.0, duration_s=30.0, seed=2)
    assert np.all(np.diff(arr) >= 0)
    assert arr.min() >= 0 and arr.max() < 30.0


def test_zero_rate():
    assert gamma_trace(0.0, 1.0, 10.0).size == 0


def test_rate_ramp_rates():
    arr = rate_ramp_trace(50, 200, 1.0, pre_s=60, ramp_s=30, post_s=60,
                          seed=3)
    head = arr[arr < 50]
    tail = arr[arr > 100]
    r_head = head.size / 50.0
    r_tail = tail.size / 50.0
    assert r_head == pytest.approx(50, rel=0.2)
    assert r_tail == pytest.approx(200, rel=0.2)


def test_cv_ramp_preserves_rate():
    arr = cv_ramp_trace(100, 1.0, 4.0, pre_s=60, ramp_s=30, post_s=60,
                        seed=4)
    head = arr[arr < 60]
    tail = arr[arr > 90]
    assert head.size / 60.0 == pytest.approx(100, rel=0.15)
    assert tail.size / 60.0 == pytest.approx(100, rel=0.15)
    assert _cv(tail) > _cv(head)


def test_autoscale_trace_peak_rescaled():
    arr = autoscale_derived_trace("big_spike", max_qps=300.0, seed=5)
    rates = empirical_rate(arr, window_s=30.0)
    assert rates.max() == pytest.approx(300.0, rel=0.2)
    assert arr.size > 1000


def test_autoscale_unknown_shape():
    with pytest.raises(KeyError):
        autoscale_derived_trace("ghost")


def test_split_plan_serve():
    arr = np.arange(0, 100, 0.5)
    head, tail = split_plan_serve(arr, 0.25)
    assert head.max() < 25.0
    assert tail.min() >= 0.0  # rebased
    assert head.size + tail.size == arr.size


@given(st.floats(min_value=5, max_value=300),
       st.floats(min_value=0.3, max_value=5.0),
       st.integers(min_value=0, max_value=10))
@settings(max_examples=25, deadline=None)
def test_gamma_trace_properties(lam, cv, seed):
    arr = gamma_trace(lam, cv, 20.0, seed=seed)
    assert np.all(np.diff(arr) >= 0)
    assert arr.size == pytest.approx(lam * 20.0, rel=0.5, abs=30)
