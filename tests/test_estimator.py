"""Estimator: discrete-event simulation against analytically-known cases."""

import numpy as np
import pytest

from repro.core.estimator import DEFAULT_RPC_DELAY_S, Estimator, _simulate_stage
from repro.core.pipeline import (
    SOURCE,
    Edge,
    Pipeline,
    PipelineConfig,
    Stage,
    StageConfig,
    linear_pipeline,
)
from repro.core.profiler import ModelProfile, ProfileStore


def _const_profile(model_id: str, latency: float, hw: str = "cpu-1",
                   batches=(1, 2, 4, 8, 16, 32)):
    """Batch-size-independent latency (pure service-time stage)."""
    return ModelProfile(model_id, {(hw, b): latency for b in batches},
                        tuple(batches))


def _linear_profile(model_id: str, per_query: float, hw: str = "cpu-1",
                    batches=(1, 2, 4, 8, 16, 32)):
    """Latency proportional to batch (serial stage)."""
    return ModelProfile(model_id, {(hw, b): per_query * b for b in batches},
                        tuple(batches))


def _single_stage(latency: float = 0.01, linear: bool = False):
    pipe = linear_pipeline("one", ["m"], {"m": ["cpu-1"]})
    store = ProfileStore()
    prof = _linear_profile("m", latency) if linear else _const_profile(
        "m", latency)
    store.add(prof)
    return pipe, store


def test_idle_system_latency_is_service_time():
    """Widely-spaced arrivals: latency == batch-1 latency + rpc hops."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", 1, 1)})
    arrivals = np.arange(10) * 10.0  # far apart
    res = est.simulate(cfg, arrivals)
    expect = 0.01 + 2 * DEFAULT_RPC_DELAY_S  # in-hop + reply-hop
    np.testing.assert_allclose(res.latency, expect, rtol=1e-9)


def test_queueing_delay_single_server():
    """Burst of N at t=0, batch=1, 1 replica: query i waits i*service."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", 1, 1)})
    arrivals = np.zeros(5)
    res = est.simulate(cfg, arrivals)
    lat = np.sort(res.latency)
    base = 2 * DEFAULT_RPC_DELAY_S
    np.testing.assert_allclose(
        lat, base + 0.01 * np.arange(1, 6), rtol=1e-9)


def test_batching_absorbs_burst():
    """Same burst with batch=8: one batch, everyone done at once."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", 8, 1)})
    arrivals = np.zeros(5)
    res = est.simulate(cfg, arrivals)
    assert res.latency.max() == pytest.approx(
        0.01 + 2 * DEFAULT_RPC_DELAY_S, rel=1e-9)
    assert list(res.per_stage_batches["s0_m"]) == [5]


def test_replication_divides_queueing():
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    arrivals = np.zeros(6)
    cfg1 = PipelineConfig({"s0_m": StageConfig("cpu-1", 1, 1)})
    cfg3 = PipelineConfig({"s0_m": StageConfig("cpu-1", 1, 3)})
    p99_1 = est.simulate(cfg1, arrivals).p99
    p99_3 = est.simulate(cfg3, arrivals).p99
    assert p99_3 < p99_1


def test_two_stage_latency_adds():
    pipe = linear_pipeline("two", ["a", "b"], {"a": ["cpu-1"], "b": ["cpu-1"]})
    store = ProfileStore()
    store.add(_const_profile("a", 0.01))
    store.add(_const_profile("b", 0.02))
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_a": StageConfig("cpu-1", 1, 1),
                          "s1_b": StageConfig("cpu-1", 1, 1)})
    res = est.simulate(cfg, np.array([0.0, 50.0]))
    expect = 0.01 + 0.02 + 3 * DEFAULT_RPC_DELAY_S
    np.testing.assert_allclose(res.latency, expect, rtol=1e-9)


def test_conditional_routing_skips_stage():
    """p=0.5 branch: ~half the queries pay the expensive stage."""
    stages = {"gate": Stage("gate", "gate", ("cpu-1",)),
              "heavy": Stage("heavy", "heavy", ("cpu-1",))}
    edges = [Edge(SOURCE, "gate"), Edge("gate", "heavy", probability=0.5)]
    pipe = Pipeline("cond", stages, edges)
    store = ProfileStore()
    store.add(_const_profile("gate", 0.001))
    store.add(_const_profile("heavy", 0.1))
    est = Estimator(pipe, store, seed=7)
    cfg = PipelineConfig({"gate": StageConfig("cpu-1", 1, 4),
                          "heavy": StageConfig("cpu-1", 1, 4)})
    arrivals = np.arange(200) * 1.0
    res = est.simulate(cfg, arrivals)
    frac_heavy = float((res.latency > 0.05).mean())
    assert 0.35 < frac_heavy < 0.65
    # routing is deterministic across repeat simulations (fixed seed)
    res2 = est.simulate(cfg, arrivals)
    np.testing.assert_array_equal(res.latency, res2.latency)


def test_and_join_waits_for_both_parents():
    stages = {"fast": Stage("fast", "fast", ("cpu-1",)),
              "slow": Stage("slow", "slow", ("cpu-1",)),
              "join": Stage("join", "join", ("cpu-1",))}
    edges = [Edge(SOURCE, "fast"), Edge(SOURCE, "slow"),
             Edge("fast", "join"), Edge("slow", "join")]
    pipe = Pipeline("join", stages, edges)
    store = ProfileStore()
    store.add(_const_profile("fast", 0.001))
    store.add(_const_profile("slow", 0.05))
    store.add(_const_profile("join", 0.001))
    est = Estimator(pipe, store)
    cfg = PipelineConfig({s: StageConfig("cpu-1", 1, 1) for s in stages})
    res = est.simulate(cfg, np.array([0.0]))
    # join cannot start before the slow branch delivers
    assert res.latency[0] >= 0.05 + 0.001


def test_service_time_longest_path(social_pipeline):
    pipe, store = social_pipeline
    est = Estimator(pipe, store)
    cfg = PipelineConfig({s: StageConfig("tpu-v5e-1", 1, 1)
                          for s in pipe.stages})
    st = est.service_time(cfg)
    manual = sum(
        store.get(m).batch_latency("tpu-v5e-1", 1)
        for m in ("lang_id", "translate", "categorize"))
    assert st == pytest.approx(manual + 4 * DEFAULT_RPC_DELAY_S)


def test_dynamic_replica_add_event():
    """A replica added mid-burst speeds the tail of the queue."""
    ready = np.zeros(10)
    order = np.arange(10)
    lut = np.array([0.0, 1.0])  # batch-1 only, 1 s
    done_static, _ = _simulate_stage(ready, order, lut, 1, 1)
    done_scaled, _ = _simulate_stage(ready, order, lut, 1, 1,
                                     replica_events=[(2.0, +1)])
    assert done_scaled.max() < done_static.max()


def test_dynamic_replica_remove_event():
    ready = np.arange(10) * 0.1
    order = np.arange(10)
    lut = np.array([0.0, 0.5])
    done_2, _ = _simulate_stage(ready, order, lut, 1, 2)
    done_dropped, _ = _simulate_stage(ready, order, lut, 1, 2,
                                      replica_events=[(0.2, -1)])
    assert done_dropped.max() >= done_2.max()


def test_lut_clamp_no_bogus_extrapolation():
    """A max_batch above the profiled LUT range must clamp batch formation
    to the profiled range, not extrapolate a linear-through-origin latency
    (the seed scaled lut[-1] * b / (len - 1), wildly wrong for
    constant-latency stages)."""
    ready = np.zeros(6)
    order = np.arange(6)
    lut = np.array([0.0, 0.01, 0.012])    # profiled up to batch 2 only
    done, batches = _simulate_stage(ready, order, lut, 8, 1)
    assert batches.max() <= 2              # never forms an unprofiled batch
    # 3 batches of 2 back-to-back, all latencies straight from the LUT
    np.testing.assert_allclose(np.sort(done),
                               np.repeat(0.012 * np.arange(1, 4), 2))


def test_lut_too_short_rejected():
    with pytest.raises(ValueError):
        _simulate_stage(np.zeros(3), np.arange(3), np.array([0.0]), 4, 1)


def test_windowed_miss_rate_shapes():
    pipe, store = _single_stage(0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", 1, 1)})
    res = est.simulate(cfg, np.arange(100) * 0.1)
    edges, rates = res.windowed_miss_rate(slo=0.02, window_s=1.0)
    assert edges.shape == rates.shape
    assert np.nanmax(rates) <= 1.0 and np.nanmin(rates) >= 0.0


def test_timeout_batching_tradeoff():
    """Beyond-paper timeout batching: larger batches (throughput) at the
    cost of head latency; zero timeout reproduces the paper's greedy
    batching exactly."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    arrivals = np.arange(200) * 0.004      # 250 qps, spaced
    greedy = PipelineConfig({"s0_m": StageConfig("cpu-1", 8, 1)})
    held = PipelineConfig(
        {"s0_m": StageConfig("cpu-1", 8, 1, timeout_s=0.05)})
    rg = est.simulate(greedy, arrivals)
    rh = est.simulate(held, arrivals)
    assert rh.per_stage_batches["s0_m"].mean() > \
        rg.per_stage_batches["s0_m"].mean()
    # head latency grows by at most the timeout (plus service)
    assert rh.latency.max() <= rg.latency.max() + 0.05 + 0.01 + 1e-9
    # explicit zero-timeout config is bit-identical to the default
    z = PipelineConfig({"s0_m": StageConfig("cpu-1", 8, 1, timeout_s=0.0)})
    np.testing.assert_array_equal(est.simulate(z, arrivals).latency,
                                  rg.latency)


def test_timeout_batching_full_batch_cuts_wait_short():
    """If max_batch queries arrive before the timeout, dispatch at fill."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    arrivals = np.arange(8) * 0.001       # all 8 within 7 ms
    cfg = PipelineConfig(
        {"s0_m": StageConfig("cpu-1", 8, 1, timeout_s=1.0)})
    res = est.simulate(cfg, arrivals)
    assert list(res.per_stage_batches["s0_m"]) == [8]
    # dispatched at the 8th arrival (7 ms), not at the 1 s timeout
    assert res.latency.max() < 0.05


# ---------------------------------------------------------------- properties

from _hyp import given, settings, st  # hypothesis or deterministic fallback


arrivals_st = st.lists(
    st.floats(min_value=0.0, max_value=30.0, allow_nan=False),
    min_size=1, max_size=120,
).map(lambda xs: np.sort(np.asarray(xs)))


@given(arrivals_st, st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=40, deadline=None)
def test_latency_lower_bound(arr, replicas, batch):
    """No query finishes faster than its batch-1 service + rpc hops."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", batch, replicas)})
    res = est.simulate(cfg, arr)
    assert res.latency.min() >= 0.01 + 2 * DEFAULT_RPC_DELAY_S - 1e-12


@given(arrivals_st, st.floats(min_value=0.1, max_value=100.0))
@settings(max_examples=40, deadline=None)
def test_time_shift_invariance(arr, shift):
    """Shifting every arrival by a constant shifts nothing in latency."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", 4, 2)})
    a = est.simulate(cfg, arr).latency
    b = est.simulate(cfg, arr + shift).latency
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12)


@given(arrivals_st)
@settings(max_examples=40, deadline=None)
def test_all_queries_complete(arr):
    """Every query gets a finite completion with >=1 replica."""
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", 2, 1)})
    res = est.simulate(cfg, arr)
    assert np.isfinite(res.latency).all()
    assert res.num_queries == arr.size


@given(arrivals_st, st.integers(min_value=1, max_value=16))
@settings(max_examples=40, deadline=None)
def test_batch_sizes_respect_max(arr, batch):
    pipe, store = _single_stage(latency=0.01)
    est = Estimator(pipe, store)
    cfg = PipelineConfig({"s0_m": StageConfig("cpu-1", batch, 1)})
    res = est.simulate(cfg, arr)
    bs = res.per_stage_batches["s0_m"]
    assert bs.max() <= batch
    assert bs.sum() == arr.size
