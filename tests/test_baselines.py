"""Coarse-grained and DS2 baselines (paper §6, Fig. 14)."""

import numpy as np
import pytest

from repro.baselines.coarse_grained import (
    CGPlanner,
    CGTuner,
    run_cg_tuner_offline,
)
from repro.baselines.ds2 import DS2Tuner, run_ds2
from repro.core.estimator import Estimator
from repro.core.planner import Planner
from repro.serving.cluster import LiveClusterSim
from repro.workload.generator import gamma_trace, rate_ramp_trace

SLO = 0.15


def test_cg_peak_provisions_more_than_mean(image_pipeline, bursty_trace):
    pipe, store = image_pipeline
    cg = CGPlanner(pipe, store)
    mean = cg.plan(bursty_trace, SLO, strategy="mean")
    peak = cg.plan(bursty_trace, SLO, strategy="peak")
    assert peak.unit_replicas >= mean.unit_replicas
    assert peak.cost_per_hr >= mean.cost_per_hr


def test_cg_uniform_batch_and_replicas(image_pipeline, sample_trace):
    """CG treats the pipeline as one unit: same batch & replicas per stage."""
    pipe, store = image_pipeline
    plan = CGPlanner(pipe, store).plan(sample_trace, SLO, strategy="peak")
    batches = {c.batch_size for c in plan.config.stage_configs.values()}
    replicas = {c.replicas for c in plan.config.stage_configs.values()}
    assert len(batches) == 1 and len(replicas) == 1


def test_cg_peak_meets_slo(image_pipeline, sample_trace):
    pipe, store = image_pipeline
    plan = CGPlanner(pipe, store).plan(sample_trace, SLO, strategy="peak")
    est = Estimator(pipe, store)
    assert est.simulate(plan.config, sample_trace).slo_miss_rate(SLO) < 0.02


def test_cg_infeasible_slo():
    from repro.core.profiler import ModelSpec, ProfileStore, \
        profile_model_analytic
    from repro.core.pipeline import linear_pipeline
    pipe = linear_pipeline("p", ["m"])
    store = ProfileStore()
    store.add(profile_model_analytic(ModelSpec("m", 1e12, 1e9, 1e8)))
    plan = CGPlanner(pipe, store).plan(np.arange(10.0), slo=1e-5)
    assert not plan.feasible


def test_cg_tuner_reacts_slower_than_inferline(image_pipeline):
    """Fig. 7: CG tuning reacts on rate only, with longer activation."""
    pipe, store = image_pipeline
    sample = gamma_trace(150, 1.0, 60, seed=0)
    plan = CGPlanner(pipe, store).plan(sample, SLO, strategy="mean")
    tuner = CGTuner(plan)
    ramp = rate_ramp_trace(150, 300, 1.0, pre_s=30, ramp_s=20, post_s=60,
                           seed=1)
    sched = run_cg_tuner_offline(tuner, pipe, ramp)
    ups = [t for evs in sched.values() for t, d in evs if d > 0]
    assert ups, "CG tuner must eventually scale up"
    # whole-unit replication: every stage scales identically
    lens = {len(v) for v in sched.values()}
    assert len(lens) == 1


def test_ds2_provisions_for_average(image_pipeline):
    """DS2 jumps to rate-proportional parallelism with no burst slack."""
    pipe, store = image_pipeline
    hw = {s: "tpu-v5e-1" for s in pipe.stages}
    hw = {s: ("cpu-1" if "prep" in s else "tpu-v5e-1") for s in pipe.stages}
    tuner = DS2Tuner(pipe, store, hw)
    smooth = gamma_trace(100, 1.0, 120, seed=2)
    result = run_ds2(tuner, store, smooth, slo=SLO)
    assert result.miss_rate < 0.1  # fine under uniform load


def test_ds2_misses_slo_under_bursty(image_pipeline):
    """Fig. 14a: as CV grows DS2's miss rate climbs; InferLine stays low."""
    pipe, store = image_pipeline
    hw = {s: ("cpu-1" if "prep" in s else "tpu-v5e-1") for s in pipe.stages}
    bursty = gamma_trace(100, 4.0, 120, seed=3)
    ds2 = run_ds2(DS2Tuner(pipe, store, hw), store, bursty, slo=SLO)

    sample = gamma_trace(100, 4.0, 60, seed=4)
    il = Planner(pipe, store).plan(sample, SLO)
    est = Estimator(pipe, store)
    il_miss = est.simulate(il.config, bursty).slo_miss_rate(SLO)
    assert ds2.miss_rate > il_miss
