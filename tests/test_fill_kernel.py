"""Vectorized batch-fill kernel: bit-identity property tests.

The blocked kernel (repro.sim.queueing) commits whole regimes of batches
with numpy scans; its contract is EXACT reproduction of the frozen seed
loop — same IEEE-754 completion times, same batch decomposition — across
policies, timeouts, replica schedules, and batch sizes. The frozen
oracle is ``repro.sim.golden.golden_simulate_stage`` for fifo, and an
inline copy of the pre-hoist loop for slo-drop (whose satellite change
was a pure native-list hoist).

Property tests run via the tests/_hyp.py shim (hypothesis if installed,
a seeded deterministic fallback otherwise).
"""

import heapq

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or deterministic fallback

import repro.sim.queueing as queueing
from repro.sim.golden import golden_simulate_stage
from repro.sim.queueing import simulate_stage

_FAR_FUTURE = 1e18


class _forced_blocks:
    """Drop the short-fill gate so block paths fire on small traces too
    (production only attempts blocks past _BLOCK_THRESHOLD queries).
    A context manager rather than a fixture so the hypothesis-shim
    property tests (zero-arg wrappers) can use it."""

    _KNOBS = {"_BLOCK_THRESHOLD": 0, "_BLOCK_MIN": 8,
              "_MIN_COMMIT": 4, "_BURST_MIN": 4}

    def __enter__(self):
        self._saved = {k: getattr(queueing, k) for k in self._KNOBS}
        for k, v in self._KNOBS.items():
            setattr(queueing, k, v)

    def __exit__(self, *exc):
        for k, v in self._saved.items():
            setattr(queueing, k, v)


# --------------------------------------------------------------------- helpers

def _make_trace(seed: int, n: int, burstiness: int, tie_frac: float
                ) -> np.ndarray:
    """Sorted arrivals with tunable tie density (tie runs are exactly
    what the underload block run-length-encodes, so sweep them hard)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(burstiness, 1), n)
    # zero out a fraction of gaps -> exact float ties
    gaps[rng.random(n) < tie_frac] = 0.0
    arr = np.cumsum(gaps)
    arr -= arr[0]
    return arr


def _make_lut(seed: int, max_b: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    base = float(rng.uniform(1e-4, 0.05))
    slope = float(rng.uniform(0.0, 0.01))
    # occasionally constant-latency (slope 0) LUTs: the over-block's
    # equal-progression merge must handle them too
    return np.array([0.0] + [base + slope * b for b in range(1, max_b + 1)])


def _make_schedule(seed: int, t_end: float):
    rng = np.random.default_rng(seed + 2)
    n_ev = int(rng.integers(0, 5))
    if n_ev == 0:
        return None
    evs = sorted((float(rng.uniform(0.0, max(t_end, 1e-6))),
                  int(rng.choice([-1, 1]))) for _ in range(n_ev))
    return evs


# ------------------------------------------------------------- fifo vs golden

@settings(max_examples=120, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),   # seed
       st.integers(min_value=1, max_value=400),      # n queries
       st.integers(min_value=1, max_value=6),        # replicas
       st.integers(min_value=0, max_value=7),        # batch exponent (2^e)
       st.integers(min_value=0, max_value=2),        # timeout mode
       st.integers(min_value=1, max_value=200),      # burstiness (rate)
       st.integers(min_value=0, max_value=9))        # tie density decile
def test_fifo_bit_identical_to_golden(seed, n, replicas, b_exp, tmode,
                                      burstiness, tie_dec):
    """The blocked kernel == the frozen seed loop, bit for bit, across
    batch sizes x replica counts x timeouts x replica schedules x tie
    structures (both regimes and both block fast paths get exercised)."""
    max_batch = 2 ** b_exp
    lut = _make_lut(seed, max(max_batch, 1))
    ready = _make_trace(seed, n, burstiness, tie_dec / 10.0)
    timeout_s = (0.0, 0.005, 0.5)[tmode]
    sched = _make_schedule(seed, float(ready[-1])) if seed % 3 == 0 else None
    want_done, want_batches = golden_simulate_stage(
        ready, np.arange(n), lut, max_batch, replicas, sched, timeout_s)
    # default path (short fill -> lean scalar) AND forced block path
    for force in (False, True):
        if force:
            with _forced_blocks():
                done, batches, dropped = simulate_stage(
                    "fifo", ready, lut, max_batch, replicas, sched,
                    timeout_s)
        else:
            done, batches, dropped = simulate_stage(
                "fifo", ready, lut, max_batch, replicas, sched, timeout_s)
        np.testing.assert_array_equal(done, want_done)
        np.testing.assert_array_equal(batches, want_batches)
        assert not dropped.any()


def test_fifo_saturated_full_batches_match_golden():
    """Pure backlog (the over-block path): one burst, many full batches."""
    with _forced_blocks():
        for replicas in (1, 2, 5):
            for max_batch in (1, 4, 8):
                n = 503
                ready = np.zeros(n)
                lut = _make_lut(7, max_batch)
                done, batches, _ = simulate_stage(
                    "fifo", ready, lut, max_batch, replicas)
                want_done, want_batches = golden_simulate_stage(
                    ready, np.arange(n), lut, max_batch, replicas)
                np.testing.assert_array_equal(done, want_done)
                np.testing.assert_array_equal(batches, want_batches)


def test_fifo_long_trace_block_paths_match_golden():
    """Long mixed trace: block commits, scalar bursts, and backoff all
    fire (n >> block size) and still match the seed loop exactly."""
    rng = np.random.default_rng(3)
    n = 60_000
    # alternating calm / overloaded phases force regime interleaving
    gaps = np.where(rng.random(n) < 0.5,
                    rng.exponential(1 / 400.0, n),
                    rng.exponential(1 / 40.0, n))
    gaps[rng.random(n) < 0.3] = 0.0
    ready = np.cumsum(gaps)
    lut = np.array([0.0, 0.004, 0.006, 0.007, 0.008, 0.009])
    for max_batch, replicas, timeout in ((4, 2, 0.0), (1, 3, 0.0),
                                         (5, 1, 0.01)):
        done, batches, _ = simulate_stage(
            "fifo", ready, lut, max_batch, replicas, None, timeout)
        want_done, want_batches = golden_simulate_stage(
            ready, np.arange(n), lut, max_batch, replicas, None, timeout)
        np.testing.assert_array_equal(done, want_done)
        np.testing.assert_array_equal(batches, want_batches)


def test_fifo_dynamic_schedule_blocks_match_golden():
    """Replica events gate the blocks (no block may cross an event)."""
    rng = np.random.default_rng(11)
    n = 8_000
    ready = np.cumsum(rng.exponential(1 / 150.0, n))
    ready[1000:1200] = ready[1000]            # tie burst mid-trace
    lut = np.array([0.0, 0.01, 0.015, 0.018])
    t_end = float(ready[-1])
    sched = sorted([(t_end * 0.2, 1), (t_end * 0.4, -1), (t_end * 0.6, 2),
                    (t_end * 0.8, -1)])
    with _forced_blocks():
        for replicas in (1, 3):
            done, batches, _ = simulate_stage(
                "fifo", ready, lut, 2, replicas, sched)
            want_done, want_batches = golden_simulate_stage(
                ready, np.arange(n), lut, 2, replicas, sched)
            np.testing.assert_array_equal(done, want_done)
            np.testing.assert_array_equal(batches, want_batches)


# ----------------------------------------------------- slo-drop hoist oracle

def _slo_drop_reference(ready, latency_lut, max_batch, replicas, deadline):
    """The pre-hoist slo_drop loop (numpy scalar indexing), verbatim —
    the regression oracle for the native-list satellite change."""
    k = ready.shape[0]
    done = np.empty(k, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    eff_batch = min(int(max_batch), latency_lut.shape[0] - 1)
    solo_lat = latency_lut[1]
    free = [0.0] * replicas
    heapq.heapify(free)
    batches = []
    ptr = 0
    while ptr < k:
        f = heapq.heappop(free)
        r0 = ready[ptr]
        start = r0 if r0 > f else f
        take = []
        i = ptr
        while i < k and ready[i] <= start and len(take) < eff_batch:
            if deadline[i] < start + solo_lat:
                dropped[i] = True
                done[i] = np.inf
            else:
                take.append(i)
            i += 1
        ptr = i
        if not take:
            heapq.heappush(free, f)
            continue
        b = len(take)
        end = start + latency_lut[b]
        done[take] = end
        batches.append(b)
        heapq.heappush(free, end)
    return done, np.asarray(batches, dtype=np.int64), dropped


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=250),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=9))
def test_slo_drop_hoist_bit_identical(seed, n, replicas, max_batch, tie_dec):
    """Native-list hoist == the original numpy-scalar loop, bit for bit,
    including drop decisions on deadline boundaries."""
    ready = _make_trace(seed, n, 50, tie_dec / 10.0)
    lut = _make_lut(seed, max_batch)
    rng = np.random.default_rng(seed + 3)
    deadline = ready + rng.uniform(0.0, 0.2, n)
    done, batches, dropped = simulate_stage(
        "slo-drop", ready, lut, max_batch, replicas, deadline=deadline)
    want = _slo_drop_reference(ready, lut, max_batch, replicas, deadline)
    np.testing.assert_array_equal(done, want[0])
    np.testing.assert_array_equal(batches, want[1])
    np.testing.assert_array_equal(dropped, want[2])


# ------------------------------------------------- degenerate / edge inputs

def test_empty_and_singleton_traces():
    lut = np.array([0.0, 0.01])
    for n in (0, 1):
        ready = np.zeros(n)
        done, batches, dropped = simulate_stage("fifo", ready, lut, 4, 2)
        want_done, want_batches = golden_simulate_stage(
            ready, np.arange(n), lut, 4, 2)
        if n:
            np.testing.assert_array_equal(done, want_done)
        assert done.shape == (n,) and dropped.shape == (n,)


def test_zero_replicas_static():
    ready = np.array([0.0, 0.1])
    lut = np.array([0.0, 0.01])
    done, batches, _ = simulate_stage("fifo", ready, lut, 2, 0)
    assert (done == _FAR_FUTURE).all()
    assert batches.size == 0


def test_zero_latency_lut_stays_exact():
    """lut[b] == 0 disables the over-block (degenerate progressions) but
    must still match the seed loop through the scalar path."""
    ready = np.zeros(100)
    lut = np.array([0.0, 0.0, 0.0])
    with _forced_blocks():
        for max_batch, replicas in ((1, 1), (2, 3)):
            done, batches, _ = simulate_stage("fifo", ready, lut,
                                              max_batch, replicas)
            want_done, want_batches = golden_simulate_stage(
                ready, np.arange(100), lut, max_batch, replicas)
            np.testing.assert_array_equal(done, want_done)
            np.testing.assert_array_equal(batches, want_batches)
