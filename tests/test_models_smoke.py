"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED same-family variant
(<=2 pattern units, d_model<=256, <=4 experts) and runs forward + one
train step on CPU, asserting output shapes and no NaNs. Decode-path
consistency (prefill + step == full forward) covers the cache logic.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, get_smoke
from repro.models import build_model
from repro.models.config import scale_down
from repro.train.optimizer import AdamW
from repro.train.trainer import make_train_step

ALL = ARCH_IDS + ["llama3.2-1b-sw"]


def _batch(cfg, key, b=2, t=16):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(ks[1], (b, 8, 128))
    if cfg.num_image_tokens:
        batch["image_feats"] = jax.random.normal(
            ks[2], (b, cfg.num_image_tokens, 1024))
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for aid in ALL:
        cfg = get_smoke(aid)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        out[aid] = (cfg, model, params)
    return out


@pytest.mark.parametrize("aid", ALL)
def test_forward_shapes_and_finite(built, aid):
    cfg, model, params = built[aid]
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("aid", ALL)
def test_one_train_step(built, aid):
    cfg, model, params = built[aid]
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    opt_state = opt.init(params)
    batch = _batch(cfg, jax.random.PRNGKey(2))
    new_params, new_state, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("aid", ALL)
def test_decode_matches_forward(built, aid):
    """Prefill + stepwise decode reproduces full-forward logits."""
    cfg, model, params = built[aid]
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, T), 0,
                              cfg.vocab_size)
    batch = _batch(cfg, jax.random.PRNGKey(4), b=1, t=T)
    batch["tokens"] = toks
    full, _ = model.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = toks[:, : T - 3]
    npfx = cfg.num_image_tokens or 0
    lg, cache = model.prefill(params, pre, smax=T + npfx)
    np.testing.assert_allclose(lg[:, -1], full[:, T - 4], atol=5e-4,
                               rtol=1e-3)
    for i in range(T - 3, T):
        lg, cache = model.decode_step(params, toks[:, i : i + 1],
                                      jnp.int32(i + npfx), cache)
        np.testing.assert_allclose(lg[:, 0], full[:, i], atol=5e-4,
                                   rtol=1e-3)


@pytest.mark.parametrize("aid", ALL)
def test_loss_decreases_over_steps(built, aid):
    """5 steps on one repeated batch must reduce the loss (overfit check)."""
    cfg, model, params = built[aid]
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, opt))
    state = opt.init(params)
    batch = _batch(cfg, jax.random.PRNGKey(5))
    losses = []
    for _ in range(5):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "whisper-small": dict(d_model=768, num_heads=12, num_kv_heads=12,
                              d_ff=3072, vocab_size=51865, layers=12),
        "granite-34b": dict(d_model=6144, num_heads=48, num_kv_heads=1,
                            d_ff=24576, vocab_size=49152, layers=88),
        "deepseek-v3-671b": dict(d_model=7168, num_heads=128,
                                 num_kv_heads=128, vocab_size=129280,
                                 layers=61),
        "phi3-mini-3.8b": dict(d_model=3072, num_heads=32, num_kv_heads=32,
                               d_ff=8192, vocab_size=32064, layers=32),
        "pixtral-12b": dict(d_model=5120, num_heads=32, num_kv_heads=8,
                            d_ff=14336, vocab_size=131072, layers=40),
        "qwen2-72b": dict(d_model=8192, num_heads=64, num_kv_heads=8,
                          d_ff=29568, vocab_size=152064, layers=80),
        "xlstm-125m": dict(d_model=768, num_heads=4, vocab_size=50304,
                           layers=12),
        "jamba-1.5-large-398b": dict(d_model=8192, num_heads=64,
                                     num_kv_heads=8, d_ff=24576,
                                     vocab_size=65536, layers=72),
        "granite-moe-1b-a400m": dict(d_model=1024, num_heads=16,
                                     num_kv_heads=8, vocab_size=49155,
                                     layers=24),
        "llama3.2-1b": dict(d_model=2048, num_heads=32, num_kv_heads=8,
                            d_ff=8192, vocab_size=128256, layers=16),
    }
    for aid, ex in expect.items():
        cfg = get_arch(aid)
        assert cfg.d_model == ex["d_model"], aid
        assert cfg.num_heads == ex["num_heads"], aid
        assert cfg.vocab_size == ex["vocab_size"], aid
        assert cfg.num_layers == ex["layers"], aid
        if "num_kv_heads" in ex:
            assert cfg.num_kv_heads == ex["num_kv_heads"], aid
        if "d_ff" in ex:
            assert cfg.d_ff == ex["d_ff"], aid


def test_moe_configs():
    ds = get_arch("deepseek-v3-671b")
    assert ds.num_experts == 256 and ds.num_experts_per_tok == 8
    assert ds.num_shared_experts == 1 and ds.use_mla and ds.mtp_depth == 1
    ja = get_arch("jamba-1.5-large-398b")
    assert ja.num_experts == 16 and ja.num_experts_per_tok == 2
    gm = get_arch("granite-moe-1b-a400m")
    assert gm.num_experts == 32 and gm.num_experts_per_tok == 8


def test_param_counts_in_expected_range():
    """Total parameter counts land near the advertised sizes."""
    expect_b = {
        "granite-34b": (30, 40),
        "deepseek-v3-671b": (600, 740),
        "phi3-mini-3.8b": (3.3, 4.4),
        "pixtral-12b": (10, 14),
        "qwen2-72b": (63, 80),
        "jamba-1.5-large-398b": (340, 440),
        "llama3.2-1b": (0.9, 1.6),
        "xlstm-125m": (0.09, 0.2),
    }
    for aid, (lo, hi) in expect_b.items():
        n = get_arch(aid).param_count() / 1e9
        assert lo <= n <= hi, f"{aid}: {n:.2f}B outside [{lo},{hi}]"


def test_long_context_support_flags():
    assert not get_arch("llama3.2-1b").supports_long_context()
    assert get_arch("llama3.2-1b-sw").supports_long_context()
    assert get_arch("xlstm-125m").supports_long_context()
    assert get_arch("jamba-1.5-large-398b").supports_long_context()
    assert not get_arch("qwen2-72b").supports_long_context()


def test_scale_down_bounds():
    for aid in ARCH_IDS:
        cfg = scale_down(get_arch(aid))
        assert cfg.d_model <= 512
        assert cfg.num_experts <= 4
        assert cfg.num_layers <= 8
