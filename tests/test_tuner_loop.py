"""Closed-loop Tuner co-simulation: property-tested control invariants.

A feedback controller is exactly the kind of code that silently drifts,
so the Tuner's contract is pinned down as properties (via ``tests/_hyp``,
hypothesis or the deterministic fallback):

1. scale-up replica targets are monotone in the violating rate r_max;
2. at ``r_max == lambda_plan`` the Tuner recovers exactly the planned
   replica counts (the §5 identity);
3. no scale-down ever fires within ``DOWNSCALE_HYSTERESIS_S`` of a
   replica-configuration change — under *arbitrary* (adversarial)
   telemetry streams;
4. closed-loop replica counts never fall below 1.

Plus the loop-level equivalence guards: the epoch-stepped driver with
the open-loop adapter reproduces ``run_tuner_offline``'s precomputed
schedule exactly, and closed-loop telemetry is causally consistent with
the final one-shot simulation.
"""

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core.envelope import TrafficEnvelope
from repro.core.estimator import Estimator
from repro.core.planner import Planner
from repro.core.tuner import (
    DOWNSCALE_HYSTERESIS_S,
    ClosedLoopTuner,
    OpenLoopTunerController,
    Tuner,
    TunerPlanInfo,
    run_tuner_offline,
)
from repro.sim import ControlLoopSession, NoOpController
from repro.sim.result import EpochTelemetry, StageTelemetry
from repro.workload.generator import gamma_trace

SLO = 0.15


# -------------------------------------------------------------- synthetic

def _plan_info(lam, mus, ks, scales, service_time_s=0.05):
    """TunerPlanInfo built directly from (rate, throughputs, planned
    replicas, scale factors) with the §5 rho identity."""
    stages = [f"m{i}" for i in range(len(mus))]
    mu = {s: float(m) for s, m in zip(stages, mus)}
    k = {s: int(v) for s, v in zip(stages, ks)}
    sf = {s: float(v) for s, v in zip(stages, scales)}
    rho = {s: max(lam * sf[s] / (k[s] * mu[s]), 1e-6) for s in stages}
    arr = np.arange(0, 2.0, 1.0 / max(lam, 1.0))
    env = TrafficEnvelope.from_trace(arr, service_time_s)
    return TunerPlanInfo(env, mu, rho, sf, k, service_time_s)


_plan_strategy = dict(
    lam=st.floats(min_value=5.0, max_value=2000.0),
    mus=st.lists(st.floats(min_value=0.5, max_value=500.0),
                 min_size=1, max_size=5),
    ks=st.lists(st.integers(min_value=1, max_value=64),
                min_size=5, max_size=5),
    scales=st.lists(st.floats(min_value=0.05, max_value=1.0),
                    min_size=5, max_size=5),
)


@settings(max_examples=60, deadline=None)
@given(_plan_strategy["lam"], _plan_strategy["mus"], _plan_strategy["ks"],
       _plan_strategy["scales"],
       st.floats(min_value=0.0, max_value=5000.0),
       st.floats(min_value=0.0, max_value=5000.0))
def test_scale_up_monotone_in_rmax(lam, mus, ks, scales, r1, r2):
    """Property 1: r1 <= r2  =>  k(r1) <= k(r2), per stage."""
    n = len(mus)
    tuner = Tuner(_plan_info(lam, mus, ks[:n], scales[:n]))
    lo, hi = sorted((r1, r2))
    t_lo = tuner.scale_up_targets(lo)
    t_hi = tuner.scale_up_targets(hi)
    for stage in t_lo:
        assert t_lo[stage] <= t_hi[stage], (stage, lo, hi)


@settings(max_examples=60, deadline=None)
@given(_plan_strategy["lam"], _plan_strategy["mus"], _plan_strategy["ks"],
       _plan_strategy["scales"])
def test_planned_rate_recovers_planned_replicas(lam, mus, ks, scales):
    """Property 2: the §5 identity k(lambda_plan) == k_plan, exactly —
    including when the float re-division of rho lands one ulp above the
    integer (the reason for _replicas_for_rate's epsilon)."""
    n = len(mus)
    info = _plan_info(lam, mus, ks[:n], scales[:n])
    tuner = Tuner(info)
    assert tuner.scale_up_targets(lam) == info.planned_replicas


def _telemetry(epoch, t0, t1, arr, stages, queue_depths, miss, service=0.05):
    """Synthetic (possibly adversarial) EpochTelemetry record."""
    prefix = arr[arr <= t1]
    env = TrafficEnvelope.from_trace(prefix, service)
    stele = {
        s: StageTelemetry(stage=s, arrived=0, completed=0, dropped=0,
                          queue_depth=int(q), in_flight=0, replicas=1)
        for s, q in zip(stages, queue_depths)
    }
    n_win = int(((arr > t0) & (arr <= t1)).sum())
    return EpochTelemetry(
        epoch=epoch, t_start=t0, t_end=t1, ingress=n_win,
        ingress_prefix=prefix, observed_envelope=env, stages=stele,
        completed=max(n_win, 1), missed=int(miss), overdue=0, drops=0,
        p99_s=float("nan"))


def _drive(tuner, arr, n_epochs, rng, adversarial=True):
    """Step a ClosedLoopTuner over synthetic telemetry; return events."""
    stages = list(tuner.current)
    t0 = 0.0
    for e in range(1, n_epochs + 1):
        t1 = float(e)
        if adversarial:
            qs = [int(rng.integers(0, 2000)) for _ in stages]
            miss = int(rng.integers(0, 50))
        else:
            qs = [0 for _ in stages]
            miss = 0
        tuner.step(_telemetry(e, t0, t1, arr, stages, qs, miss))
        t0 = t1
    return tuner.events


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_no_scale_down_within_hysteresis(seed):
    """Property 3: under arbitrary telemetry (random queue depths and
    miss counts, bursty random ingress), every scale-down is at least
    DOWNSCALE_HYSTERESIS_S after the previous replica change."""
    rng = np.random.default_rng(seed)
    lam = float(rng.uniform(20, 300))
    n_st = int(rng.integers(1, 4))
    info = _plan_info(lam, [float(rng.uniform(5, 80))] * n_st,
                      [int(rng.integers(1, 12))] * n_st, [1.0] * n_st)
    tuner = ClosedLoopTuner(info)
    # bursty ingress: alternating calm / spike segments
    segs = []
    t = 0.0
    while t < 90.0:
        dur = float(rng.uniform(5, 25))
        rate = lam * float(rng.choice([0.0, 0.3, 1.0, 1.0, 4.0]))
        if rate > 0:
            segs.append(t + gamma_trace(rate, 1.0, dur, seed=seed % 2**16))
        t += dur
    arr = np.sort(np.concatenate(segs)) if segs else np.zeros(0)
    _drive(tuner, arr, 90, rng)
    replica_events = [(t, kind) for (t, kind, _, _) in tuner.events
                      if kind in ("up", "down")]
    last_change = 0.0    # deployment counts as a configuration change
    for t, kind in replica_events:
        if kind == "down" and t != last_change:
            assert t - last_change >= DOWNSCALE_HYSTERESIS_S - 1e-9, \
                (t, last_change, tuner.events)
        last_change = t


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_replicas_never_below_one(seed):
    """Property 4: closed-loop counts stay >= 1, even through dead-air
    traffic (rate 0) and adversarial telemetry pushing scale-down."""
    rng = np.random.default_rng(seed)
    lam = float(rng.uniform(20, 300))
    info = _plan_info(lam, [float(rng.uniform(5, 80))],
                      [int(rng.integers(1, 12))], [1.0])
    tuner = ClosedLoopTuner(info)
    # mostly-silent trace: drives lam_new to ~0 -> the scale-down floor
    arr = gamma_trace(2.0, 1.0, 90.0, seed=seed % 2**16)
    stages = list(tuner.current)
    t0 = 0.0
    for e in range(1, 91):
        t1 = float(e)
        qs = [0 for _ in stages]
        tuner.step(_telemetry(e, t0, t1, arr, stages, qs, 0))
        for s, k in tuner.current.items():
            assert k >= 1, (e, s, tuner.current, tuner.events)
        t0 = t1
    # the schedule's running sums honor the floor too
    for s in stages:
        k = info.planned_replicas[s]
        for _, kind, stage, delta in tuner.events:
            if stage == s and kind in ("up", "down"):
                k += delta
                assert k >= 1


# ------------------------------------------------- loop-level equivalence

@pytest.fixture(scope="module")
def planned_image(image_pipeline):
    pipe, store = image_pipeline
    sample = gamma_trace(lam=150.0, cv=1.0, duration_s=60.0, seed=0)
    res = Planner(pipe, store).plan(sample, SLO)
    assert res.feasible
    est = Estimator(pipe, store)
    info = TunerPlanInfo.from_plan(pipe, res.config, store, sample,
                                   est.service_time(res.config))
    return pipe, store, res, info, sample


def test_open_loop_controller_matches_precomputed_schedule(planned_image):
    """The epoch-stepped driver with the open-loop adapter reproduces
    run_tuner_offline's schedule event for event, and the resulting
    simulation is bit-identical to the precomputed-schedule path."""
    pipe, store, res, info, sample = planned_image
    from repro.serving.cluster import LiveClusterSim
    ramp = np.concatenate([
        gamma_trace(150, 1.0, 30, seed=4),
        30.0 + gamma_trace(320, 1.0, 20, seed=5),
        50.0 + gamma_trace(150, 1.0, 40, seed=6)])
    offline = run_tuner_offline(Tuner(info), ramp)

    sess = ControlLoopSession(pipe, store, res.config, SLO,
                              rpc_delay_s=LiveClusterSim(
                                  pipe, store, res.config, SLO
                              ).engine.rpc_delay_s)
    looped = sess.run(ramp, OpenLoopTunerController(Tuner(info)))
    assert dict(looped.replica_schedules) == dict(offline)

    live = LiveClusterSim(pipe, store, res.config, SLO).run(
        ramp, schedule_fn=lambda arr: run_tuner_offline(Tuner(info), arr))
    np.testing.assert_array_equal(looped.sim.latency, live.sim.latency)
    # same schedule + shared cost-timeline helper => same cost integral
    assert looped.total_cost() == pytest.approx(live.total_cost())


def test_noop_controller_is_identity(planned_image):
    """Feedback disabled => no events, and the run IS the static run."""
    pipe, store, res, info, sample = planned_image
    from repro.sim import SimEngine
    trace = gamma_trace(170, 2.0, 40, seed=9)
    out = ControlLoopSession(pipe, store, res.config, SLO).run(
        trace, NoOpController())
    assert out.events == [] and not any(out.replica_schedules.values())
    static = SimEngine(pipe, store).simulate(res.config, trace, slo_s=SLO)
    np.testing.assert_array_equal(out.sim.latency, static.latency)


def test_closed_loop_reacts_and_recovers(planned_image):
    """Integration: a spike triggers scale-ups (including a backlog
    boost sized at the onset epoch), and the fleet returns to the
    planned neighborhood after the spike leaves the envelope horizon."""
    pipe, store, res, info, sample = planned_image
    spike = np.concatenate([
        sample,
        60.0 + gamma_trace(500, 0.5, 15, seed=11),
        75.0 + gamma_trace(150, 1.0, 85, seed=12)])
    tuner = ClosedLoopTuner(info)
    out = ControlLoopSession(pipe, store, res.config, SLO).run(spike, tuner)
    ups = [e for e in out.events if e.kind == "up"]
    downs = [e for e in out.events if e.kind == "down"]
    assert ups and downs
    # first reaction within a few epochs of the spike start
    assert min(e.t for e in ups) <= 63.0
    for stage, k in tuner.current.items():
        planned = res.config[stage].replicas
        assert 1 <= k <= planned + max(2, planned // 2), (stage, k)


def test_telemetry_causally_consistent_with_final_sim(planned_image):
    """Summing per-epoch miss observations (late completions + newly
    overdue) over the whole run must reproduce the final simulation's
    miss count for every query whose deadline fell inside the stepped
    range — the telemetry a controller saw mid-run is exactly what the
    final schedule's one-shot simulation shows."""
    pipe, store, res, info, sample = planned_image
    spike = np.concatenate([
        sample, 60.0 + gamma_trace(450, 0.6, 12, seed=21),
        72.0 + gamma_trace(150, 1.0, 48, seed=22)])
    out = ControlLoopSession(pipe, store, res.config, SLO).run(
        spike, ClosedLoopTuner(info))
    t_last = max(ep.t_end for ep in out.telemetry)
    misses_seen = sum(ep.misses for ep in out.telemetry)
    deadline = out.sim.arrival + SLO
    in_range = deadline <= t_last
    miss_mask = (out.sim.latency > SLO)
    if out.sim.dropped is not None:
        miss_mask |= out.sim.dropped
    assert misses_seen == int((miss_mask & in_range).sum())
    # ingress accounting closes too
    assert sum(ep.ingress for ep in out.telemetry) == \
        int((spike <= t_last).sum())


def test_run_rejects_unsorted_arrivals(planned_image):
    """Telemetry windows are searchsorted slices: an unsorted trace that
    the engine itself would tolerate must be refused, not mis-counted."""
    pipe, store, res, info, sample = planned_image
    bad = np.concatenate([gamma_trace(50, 1.0, 5, seed=1),
                          gamma_trace(50, 1.0, 5, seed=2)])
    with pytest.raises(ValueError, match="sorted"):
        ControlLoopSession(pipe, store, res.config, SLO).run(
            bad, NoOpController())


def test_arrival_at_time_zero_is_counted(planned_image):
    """Regression: the first epoch window is closed at both ends, so an
    arrival at exactly t=0 lands in epoch 1's ingress count and the
    per-epoch partition of the trace stays exact."""
    pipe, store, res, info, sample = planned_image
    trace = np.concatenate([[0.0], gamma_trace(100, 1.0, 10, seed=3)])
    out = ControlLoopSession(pipe, store, res.config, SLO).run(
        trace, NoOpController())
    t_last = max(ep.t_end for ep in out.telemetry)
    assert sum(ep.ingress for ep in out.telemetry) == \
        int((trace <= t_last).sum())
    assert out.telemetry[0].ingress == int((trace <= 1.0).sum())


def test_epoch_replica_telemetry_tracks_schedule(planned_image):
    """StageTelemetry.replicas reflects the events effective by each
    epoch boundary (activation delay included)."""
    pipe, store, res, info, sample = planned_image
    spike = np.concatenate([sample, 60.0 + gamma_trace(500, 0.5, 10,
                                                       seed=31)])
    out = ControlLoopSession(pipe, store, res.config, SLO).run(
        spike, ClosedLoopTuner(info))
    for ep in out.telemetry:
        for s, stele in ep.stages.items():
            # events decided strictly before this boundary and effective
            # by it (a down decided AT the boundary post-dates the record)
            want = res.config[s].replicas + sum(
                int(e.value) for e in out.events
                if e.kind in ("up", "down") and e.stage == s
                and e.t_effective <= ep.t_end and e.t < ep.t_end)
            assert stele.replicas == want
