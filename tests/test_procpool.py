"""Process-backed replica pool: real OS processes behind the LiveQueue.

Time-budgeted procpool smoke lane (tier-1, alongside the live-executor
and chaos lanes): ``PipelineExecutor(backend="process")`` pairs every
dispatcher thread with a forked worker process fed through a shared-
memory slab (:mod:`repro.serving.procpool`). The whole serving contract
must survive the move off threads — batch formation, replica lifecycle
(spawn/drain), PR 8 fault injection (a scheduled crash SIGKILLs a real
process and its in-flight batch requeues), bounded retry + hedged
duplicates with exactly-once delivery, and the asyncio ingress on top.
Scale stays tiny (1 worker process per replica, millisecond fns) so the
file fits the CI budget.
"""

import os
import time

import numpy as np

from repro.core.pipeline import PipelineConfig, StageConfig, linear_pipeline
from repro.faults import FaultSchedule, RecoveryPolicy, crash, transient
from repro.serving.executor import PipelineExecutor
from repro.serving.ingress import AsyncIngress
from repro.serving.procpool import (
    ProcessReplicaPool,
    ProcReplica,
    ReplicaDead,
    StageWorkerError,
    register_worker_fn,
    resolve_worker_fn,
)


def _sleep_fn(per_batch_s, scale=1):
    def fn(payloads):
        time.sleep(per_batch_s)
        return [p * scale for p in payloads]
    return fn


def _triple(payloads):
    """Module-level (importable) stage fn for the spawn tests."""
    return [p * 3 for p in payloads]


def _linear(n_stages=1, batch=4, replicas=1, **kw):
    names = [f"m{i}" for i in range(n_stages)]
    pipe = linear_pipeline("t", names, {n: ["cpu-1"] for n in names})
    cfg = PipelineConfig({
        s: StageConfig("cpu-1", batch, replicas, **kw)
        for s in pipe.stages})
    return pipe, cfg


def _wait_until(pred, timeout_s=15.0):
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(0.02)
    return pred()


# -- the replica primitive ---------------------------------------------------


def test_proc_replica_runs_batches_in_child_process():
    rep = ProcReplica(_sleep_fn(0.0, scale=3))
    try:
        assert rep.alive() and rep.pid != os.getpid()
        assert rep.run([1, 2, 3]) == [3, 6, 9]
        assert rep.run([5]) == [15]          # slab reused request-to-request
    finally:
        rep.close()
    assert not rep.alive()
    rep.close()                              # idempotent


def test_proc_replica_oversize_batch_falls_back_chunked():
    """A batch bigger than a ring buffer streams through the slab in
    chunks — slower, never wrong — and the stats prove the chunk lane
    (not the legacy inline pipe) carried it."""
    rep = ProcReplica(lambda ps: [p.sum() for p in ps], slab_bytes=256)
    try:
        big = np.ones(50_000)                # ~400 KB >> 128 B buffers
        assert rep.run([big, 2 * big]) == [50_000.0, 100_000.0]
        st = rep.transport_stats()
        assert st.chunk_messages > 0 and st.inline_messages == 0
    finally:
        rep.close()


def test_proc_replica_child_error_keeps_process_alive():
    def fn(payloads):
        if payloads[0] == "boom":
            raise ValueError("bad payload")
        return list(payloads)

    rep = ProcReplica(fn)
    try:
        try:
            rep.run(["boom"])
            raise AssertionError("expected StageWorkerError")
        except StageWorkerError as e:
            assert "bad payload" in str(e)
        assert rep.alive()                   # fn error != replica death
        assert rep.run(["ok"]) == ["ok"]
    finally:
        rep.close()


def test_proc_replica_kill_surfaces_replica_dead():
    rep = ProcReplica(_sleep_fn(10.0))
    try:
        rep.kill()
        try:
            rep.run([1])
            raise AssertionError("expected ReplicaDead")
        except ReplicaDead:
            pass
    finally:
        rep.close()


# -- the executor on the process backend -------------------------------------


def test_process_backend_serves_through_real_processes():
    pipe, cfg = _linear(n_stages=2, batch=4, replicas=2)
    ex = PipelineExecutor(pipe, cfg,
                          {"m0": _sleep_fn(0.002, scale=2),
                           "m1": _sleep_fn(0.002, scale=5)},
                          backend="process")
    assert _wait_until(lambda: ex.live_process_count("s0_m0") == 2)
    pids = ex.worker_pids("s0_m0") + ex.worker_pids("s1_m1")
    assert pids and all(p != os.getpid() for p in pids)
    payloads = {}
    ex.on_request_done = lambda r: payloads.setdefault(r.rid, r.payload)
    lat = ex.serve_trace(np.linspace(0.0, 0.3, 24), lambda i: i,
                         timeout_s=20.0)
    assert np.isfinite(lat).all(), lat
    # outputs really crossed both stage processes: i * 2 * 5
    assert payloads == {i: i * 10 for i in range(24)}
    assert ex.shutdown()
    assert ex.live_process_count("s0_m0") == 0   # no leaked processes


def test_process_backend_scales_both_directions():
    pipe, cfg = _linear(replicas=1, batch=2)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.002)},
                          backend="process")
    ex.scale("s0_m0", 3)
    assert _wait_until(lambda: ex.live_process_count("s0_m0") == 3)
    ex.scale("s0_m0", 1)
    assert _wait_until(lambda: ex.live_process_count("s0_m0") == 1)
    assert ex.replica_target("s0_m0") == 1
    assert ex.shutdown()


def test_crash_kills_real_os_process_and_requeues():
    """The PR 8 fault contract on processes: a scheduled crash takes a
    real OS process down mid-batch; the in-flight batch requeues and
    every request still finishes on the survivor."""
    pipe, cfg = _linear(replicas=2, batch=2)
    fs = FaultSchedule([crash("s0_m0", 0.08)], seed=0)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.05)}, faults=fs,
                          backend="process")
    assert _wait_until(lambda: ex.live_process_count("s0_m0") == 2)
    pids_before = set(ex.worker_pids("s0_m0"))
    lat = ex.serve_trace(np.linspace(0.0, 0.4, 16), lambda i: i,
                         timeout_s=20.0)
    assert np.isfinite(lat).all(), lat   # serve_trace raises on failures
    assert ex.replica_target("s0_m0") == 1
    assert _wait_until(lambda: ex.live_process_count("s0_m0") == 1)
    pids_after = set(ex.worker_pids("s0_m0"))
    assert len(pids_before - pids_after) == 1    # a real pid died
    deltas = ex.fault_deltas()["s0_m0"]
    assert len(deltas) == 1 and deltas[0][1] == -1
    assert ex.shutdown()


def test_crash_then_replacement_on_processes():
    pipe, cfg = _linear(replicas=2, batch=2)
    fs = FaultSchedule([crash("s0_m0", 0.05)], seed=0)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.01)}, faults=fs,
                          backend="process")
    ex.start_run()
    assert _wait_until(lambda: ex.replica_target("s0_m0") == 1)
    ex.add_replicas("s0_m0", 1, t_active=ex.now())
    assert ex.replica_target("s0_m0") == 2
    assert _wait_until(lambda: ex.live_process_count("s0_m0") == 2)
    # final fleet matches the deterministic replay arithmetic the
    # fault bench asserts sim<->live: base - crashes + ups
    assert ex.replica_timeline["s0_m0"][-1][1] == 2
    assert ex.shutdown()


def test_all_dead_stage_fast_fails_on_processes():
    """Both replicas crash and nothing replaces them: serve_trace must
    release the stranded requests promptly (starvation sentinel), not
    grind through the full timeout."""
    pipe, cfg = _linear(replicas=2, batch=2)
    fs = FaultSchedule([crash("s0_m0", 0.05, n=2)], seed=0)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.05)}, faults=fs,
                          backend="process")
    t0 = time.time()
    lat = ex.serve_trace(np.linspace(0.0, 0.3, 12), lambda i: i,
                         timeout_s=30.0)
    assert time.time() - t0 < 8.0, "all-dead stage ate the full timeout"
    assert np.isinf(lat).any()
    assert ex.shutdown()


def test_exactly_once_under_errors_and_hedging_on_processes():
    """Transient errors + hedged duplicates, with service in real
    processes: resolve-once dedup must still deliver at most once."""
    import threading

    pipe, cfg = _linear(n_stages=2, replicas=2, batch=2)
    fs = FaultSchedule(
        [transient("s0_m0", 0.0, 0.2, 0.6)], seed=5,
        recovery=RecoveryPolicy(max_attempts=6, backoff_s=0.02,
                                backoff_mult=1.5, hedge_slack_s=0.4))
    ex = PipelineExecutor(pipe, cfg,
                          {"m0": _sleep_fn(0.004), "m1": _sleep_fn(0.004)},
                          faults=fs, backend="process")
    done_rids = []
    done_lock = threading.Lock()

    def on_done(req):
        with done_lock:
            done_rids.append(req.rid)

    ex.on_request_done = on_done
    lat = ex.serve_trace(np.linspace(0.0, 0.4, 40), lambda i: i,
                         timeout_s=20.0, slo_s=0.5)
    assert len(done_rids) == len(set(done_rids)), "duplicate delivery"
    finished = sorted(r for r, l in zip(range(40), lat) if np.isfinite(l))
    assert set(finished) <= set(done_rids)
    assert ex.shutdown()


# -- spawn-safe entrypoint + worker-fn registry ------------------------------


def test_proc_replica_forced_spawn_start_method():
    """The worker entrypoint is a module-level function and the stage fn
    travels as an importable reference, so a ``spawn`` context (fresh
    interpreter, nothing inherited) serves identically to ``fork``."""
    pool = ProcessReplicaPool(_triple, start_method="spawn")
    try:
        rep = pool.spawn()
        assert rep.alive() and rep.pid != os.getpid()
        out = rep.run([np.arange(4, dtype=np.int32)])
        assert np.array_equal(out[0], np.arange(4, dtype=np.int32) * 3)
        assert rep.run([np.float32(2.0)])[0] == np.float32(6.0)
    finally:
        pool.close_all()


def test_worker_fn_registry_resolves_by_name_under_spawn():
    """A registered name (for fns that are not importable from the
    child, e.g. closures built at runtime) resolves on both ends."""
    register_worker_fn("procpool-test-triple", _triple)
    assert resolve_worker_fn("procpool-test-triple") is _triple
    assert resolve_worker_fn(
        "tests.test_procpool:_triple" if __name__.startswith("tests.")
        else f"{__name__}:_triple") is _triple
    pool = ProcessReplicaPool("procpool-test-triple", start_method="spawn")
    try:
        rep = pool.spawn()
        assert rep.run([np.int64(7)])[0] == 21
    finally:
        pool.close_all()


def test_async_ingress_on_process_backend():
    pipe, cfg = _linear(replicas=2, batch=16)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.002)},
                          backend="process")
    ing = AsyncIngress(ex, clients=32)
    arr = np.sort(np.random.default_rng(0).uniform(0.0, 0.5, 200))
    lat, stats = ing.serve_trace(arr, lambda i: i, timeout_s=20.0,
                                 slo_s=0.5)
    assert np.isfinite(lat).all(), lat
    assert stats.injected == 200
    assert stats.max_lag_s < 0.25          # loose CI bound; bench is tight
    assert ex.injection_stats()["n"] == 200
    assert ex.shutdown()
