"""JAX backend parity: device fills and grids vs the numpy reference.

The device backend's contract (repro.sim.jax_backend) is *bit identity*:
``lax.scan`` fills, device percentile reductions, and the vmapped
(hw, batch, replica) candidate grid must reproduce the numpy kernels to
the last ulp wherever IEEE-754 float64 semantics allow.  These tests
force the device paths (the auto-selection thresholds would otherwise
route small problems to numpy) and compare exactly — not approximately.

Plan-decision identity is the end-to-end bar: Planner and BeamPlanner
must return the same configuration at the same cost on both backends.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.configs.pipelines import get_motif
from repro.core.pipeline import PipelineConfig, StageConfig
from repro.core.planner import BeamPlanner, Planner
from repro.sim import SimEngine, simulate_stage
from repro.sim import jax_backend as jb

pytestmark = pytest.mark.skipif(
    not jb.available(), reason="jax not installed")


# -- helpers ----------------------------------------------------------------

def _both_fills(ready, lut, max_batch, replicas,
                replica_events=None, timeout_s=0.0):
    """Run one fifo fill on both backends, forcing the device kernel."""
    a = simulate_stage("fifo", ready, lut, max_batch, replicas,
                       replica_events, timeout_s)
    old = jb._JAX_FILL_THRESHOLD
    jb._JAX_FILL_THRESHOLD = 0
    try:
        b = simulate_stage("fifo", ready, lut, max_batch, replicas,
                           replica_events, timeout_s, backend="jax")
    finally:
        jb._JAX_FILL_THRESHOLD = old
    return a, b


def _assert_fill_equal(a, b):
    done_a, batches_a, dropped_a = a
    done_b, batches_b, dropped_b = b
    np.testing.assert_array_equal(done_a, done_b)
    np.testing.assert_array_equal(batches_a, batches_b)
    np.testing.assert_array_equal(dropped_a, dropped_b)


def _ready_from_gaps(gaps, rate_scale):
    # fixed-length traces keep the jitted scan's shape cache warm
    g = np.asarray(gaps, dtype=np.float64) * rate_scale
    return np.cumsum(g)


def _lut(max_batch, base, slope):
    lut = np.full(max_batch + 1, -1.0)
    for b in range(1, max_batch + 1):
        lut[b] = base + slope * b
    return lut


# -- fill parity (tentpole bit-identity) ------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    gaps=st.lists(st.floats(min_value=0.0, max_value=0.05),
                  min_size=60, max_size=60),
    max_batch=st.integers(min_value=1, max_value=8),
    replicas=st.integers(min_value=1, max_value=4),
    regime=st.integers(min_value=0, max_value=2),
    timeout_i=st.integers(min_value=0, max_value=1),
)
def test_static_fill_bit_identical(gaps, max_batch, replicas, regime,
                                   timeout_i):
    # regimes: underload, ~critical, overload (service >> arrival gap)
    scale = (4.0, 1.0, 0.05)[regime]
    ready = _ready_from_gaps(gaps, scale)
    lut = _lut(max_batch, base=0.01, slope=0.004)
    timeout_s = (0.0, 0.03)[timeout_i]
    a, b = _both_fills(ready, lut, max_batch, replicas,
                       timeout_s=timeout_s)
    _assert_fill_equal(a, b)


@settings(max_examples=8, deadline=None)
@given(
    gaps=st.lists(st.floats(min_value=0.0, max_value=0.05),
                  min_size=60, max_size=60),
    replicas=st.integers(min_value=1, max_value=3),
)
def test_batch_one_fill_bit_identical(gaps, replicas):
    # B=1 takes a dedicated shortcut in the numpy kernel; the scan must
    # agree with it exactly
    ready = _ready_from_gaps(gaps, 0.5)
    a, b = _both_fills(ready, _lut(1, 0.012, 0.0), 1, replicas)
    _assert_fill_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(
    gaps=st.lists(st.floats(min_value=0.0, max_value=0.05),
                  min_size=60, max_size=60),
    max_batch=st.integers(min_value=1, max_value=6),
    replicas=st.integers(min_value=1, max_value=3),
    frac1=st.floats(min_value=0.05, max_value=0.45),
    frac2=st.floats(min_value=0.5, max_value=0.95),
    delta1=st.integers(min_value=1, max_value=2),
)
def test_dynamic_pool_fill_bit_identical(gaps, max_batch, replicas,
                                         frac1, frac2, delta1):
    ready = _ready_from_gaps(gaps, 0.3)
    span = float(ready[-1]) if ready[-1] > 0 else 1.0
    events = [(frac1 * span, delta1), (frac2 * span, -1)]
    a, b = _both_fills(ready, _lut(max_batch, 0.008, 0.003),
                       max_batch, replicas, replica_events=events)
    _assert_fill_equal(a, b)


def test_zero_replicas_with_scale_up_events():
    # pool starts empty; the first add event brings capacity online
    ready = np.cumsum(np.full(40, 0.01))
    events = [(0.15, 2)]
    a, b = _both_fills(ready, _lut(4, 0.01, 0.002), 4, 0,
                       replica_events=events)
    _assert_fill_equal(a, b)


def test_simultaneous_arrivals_and_ties():
    ready = np.sort(np.concatenate(
        [np.cumsum(np.full(30, 0.02)), np.full(10, 0.3)]))
    a, b = _both_fills(ready, _lut(8, 0.015, 0.001), 8, 2)
    _assert_fill_equal(a, b)


def test_negative_lut_falls_back_to_numpy():
    # unprofiled batch size inside [1, eff]: the device kernel refuses
    # and the dispatcher must return the numpy result unchanged
    ready = np.cumsum(np.full(32, 0.01))
    lut = _lut(4, 0.01, 0.002)
    lut[3] = -1.0
    a, b = _both_fills(ready, lut, 4, 2)
    _assert_fill_equal(a, b)


def test_backend_kwarg_ignored_by_deadline_policies():
    # edf / slo-drop have no device kernels; backend="jax" must be a
    # harmless no-op there
    ready = np.cumsum(np.full(32, 0.01))
    lut = _lut(4, 0.01, 0.002)
    deadlines = ready + 0.25
    for policy in ("edf", "slo-drop"):
        a = simulate_stage(policy, ready, lut, 4, 2, deadline=deadlines)
        b = simulate_stage(policy, ready, lut, 4, 2, deadline=deadlines,
                           backend="jax")
        _assert_fill_equal(a, b)


def test_simulate_stage_rejects_unknown_backend():
    ready = np.cumsum(np.full(8, 0.01))
    with pytest.raises(ValueError, match="backend"):
        simulate_stage("fifo", ready, _lut(2, 0.01, 0.001), 2, 1,
                       backend="tpu")


def test_block_threshold_env_override():
    repo_src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.sim.queueing as q; print(q._BLOCK_THRESHOLD)"],
        env={**os.environ, "REPRO_BLOCK_FILL_THRESHOLD": "123",
             "PYTHONPATH": repo_src},
        capture_output=True, text=True, check=True)
    assert out.stdout.strip() == "123"


# -- percentile parity ------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    vals=st.lists(st.floats(min_value=-5.0, max_value=5.0),
                  min_size=1, max_size=120),
    p_i=st.integers(min_value=0, max_value=1000),
)
def test_percentile_bit_identical(vals, p_i):
    p = p_i / 10.0
    arr = np.asarray(vals, dtype=np.float64)
    host = float(np.percentile(arr, p))
    dev = float(jb.percentile_1d(arr, p))
    assert host == dev or (np.isnan(host) and np.isnan(dev))


@settings(max_examples=10, deadline=None)
@given(
    vals=st.lists(st.floats(min_value=0.0, max_value=2.0),
                  min_size=4, max_size=80),
    n_inf=st.integers(min_value=1, max_value=3),
    p_i=st.integers(min_value=900, max_value=1000),
)
def test_percentile_with_inf_tail(vals, n_inf, p_i):
    # dropped/never-completed queries surface as +inf latencies; the tail
    # percentiles must agree (including inf-inf interpolation -> nan)
    p = p_i / 10.0
    arr = np.asarray(list(vals) + [np.inf] * n_inf, dtype=np.float64)
    with np.errstate(invalid="ignore"):
        host = float(np.percentile(arr, p))
        dev = float(jb.percentile_1d(arr, p))
    assert host == dev or (np.isnan(host) and np.isnan(dev))


# -- session / grid parity --------------------------------------------------

def _poisson_trace(n, rate, seed=0):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, n))


def _base_config(bound):
    return PipelineConfig({
        s: StageConfig(stage.hardware_options[0], 1, 1)
        for s, stage in bound.pipeline.stages.items()
    })


def _sink_grid(bound, stage, hws, batches, reps):
    base = _base_config(bound)
    grid = []
    for hw in hws:
        for b in batches:
            for r in reps:
                cfg = base.copy()
                cfg.stage_configs[stage] = StageConfig(hw, b, r)
                grid.append(cfg)
    return grid


def test_grid_percentile_many_bit_identical_and_engaged():
    bound = get_motif("image-processing")
    engine = SimEngine(bound.pipeline, bound.profiles)
    arr = _poisson_trace(4000, 60.0, seed=3)
    grid = _sink_grid(bound, "classify", ("tpu-v5e-8", "tpu-v5e-4"),
                      (1, 2, 4, 8), range(1, 9))
    assert len(grid) >= jb._GRID_MIN_CANDIDATES

    host = engine.session(arr).percentile_many(grid, 99.0)

    calls = []
    orig = jb.grid_stage_percentiles

    def spy(*a, **kw):
        calls.append(len(a[0]))
        return orig(*a, **kw)

    jb.grid_stage_percentiles = spy
    try:
        dev = engine.session(arr, backend="jax").percentile_many(grid, 99.0)
    finally:
        jb.grid_stage_percentiles = orig

    assert calls, "device grid path did not engage"
    assert host == dev  # exact float equality, element-wise


def test_grid_ineligible_falls_back_to_host_loop():
    # two stages vary vs the pivot -> the device grid must decline and
    # the host loop must still serve identical answers
    bound = get_motif("image-processing")
    engine = SimEngine(bound.pipeline, bound.profiles)
    arr = _poisson_trace(3000, 50.0, seed=5)
    base = _base_config(bound)
    grid = []
    for b in (1, 2, 4, 8):
        for r in (1, 2, 3, 4, 5, 6):
            for pb in (1, 2):
                cfg = base.copy()
                cfg.stage_configs["classify"] = StageConfig("tpu-v5e-8", b, r)
                cfg.stage_configs["preprocess"] = StageConfig("cpu-1", pb, 2)
                grid.append(cfg)

    calls = []
    orig = jb.grid_stage_percentiles
    jb.grid_stage_percentiles = lambda *a, **kw: (
        calls.append(1), orig(*a, **kw))[1]
    try:
        dev = engine.session(arr, backend="jax").percentile_many(grid, 99.0)
    finally:
        jb.grid_stage_percentiles = orig
    host = engine.session(arr).percentile_many(grid, 99.0)

    assert not calls
    assert host == dev


def test_session_simulate_parity_classed_trace():
    # full-session parity on a mixed-SLO trace with a deadline policy in
    # the pipeline: device fills handle the fifo stages, numpy the rest
    bound = get_motif("image-processing")
    engine = SimEngine(bound.pipeline, bound.profiles)
    arr = _poisson_trace(2000, 40.0, seed=11)
    rng = np.random.default_rng(12)
    slo_s = np.where(rng.random(arr.size) < 0.5, 0.15, 0.6)
    cfg = _base_config(bound)
    cfg.stage_configs["classify"] = StageConfig("tpu-v5e-8", 4, 2)
    cfg.stage_configs["preprocess"] = StageConfig(
        "cpu-1", 2, 2, policy="slo-drop")

    host = engine.session(arr, slo_s=slo_s).simulate(cfg)
    old = jb._JAX_FILL_THRESHOLD
    jb._JAX_FILL_THRESHOLD = 0
    try:
        dev = engine.session(arr, slo_s=slo_s,
                             backend="jax").simulate(cfg)
    finally:
        jb._JAX_FILL_THRESHOLD = old
    np.testing.assert_array_equal(host.latency, dev.latency)


# -- plan-decision identity -------------------------------------------------

@pytest.mark.parametrize("motif", ["image-processing", "tf-cascade"])
def test_planner_decision_identity(motif):
    bound = get_motif(motif)
    arr = _poisson_trace(6000, 40.0, seed=7)
    slo = 0.5
    plans = {}
    for backend in ("numpy", "jax"):
        p = Planner(bound.pipeline, bound.profiles, backend=backend)
        plans[backend] = p.plan(arr, slo)
    a, b = plans["numpy"], plans["jax"]
    assert a.feasible == b.feasible
    if a.feasible:
        assert a.config.cache_key() == b.config.cache_key()
        assert a.cost_per_hr == b.cost_per_hr


@pytest.mark.parametrize("motif", ["image-processing", "video-monitoring"])
def test_beam_planner_decision_identity(motif):
    bound = get_motif(motif)
    arr = _poisson_trace(6000, 40.0, seed=9)
    slo = 0.6
    plans = {}
    for backend in ("numpy", "jax"):
        # pin beam_width: the jax default widens the frontier, which is
        # allowed to change the plan — identity is only promised at
        # equal width
        p = BeamPlanner(bound.pipeline, bound.profiles, beam_width=4,
                        backend=backend)
        plans[backend] = p.plan(arr, slo)
    a, b = plans["numpy"], plans["jax"]
    assert a.feasible == b.feasible
    if a.feasible:
        assert a.config.cache_key() == b.config.cache_key()
        assert a.cost_per_hr == b.cost_per_hr
