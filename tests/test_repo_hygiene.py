"""Repo hygiene: no bytecode caches may ever be tracked again.

Follow-up to the accidental ``__pycache__`` commit (removed in
637b35b): ``.gitignore`` prevents *new* cache files from being staged,
but a tracked file is immune to ignore rules — so this asserts the
index itself is clean. CI runs the same check as a workflow step.
"""

import os
import subprocess

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tracked_files():
    try:
        out = subprocess.run(
            ["git", "ls-files"], cwd=REPO_ROOT, capture_output=True,
            text=True, timeout=30, check=True)
    except (OSError, subprocess.SubprocessError):
        pytest.skip("git unavailable or not a git checkout")
    return out.stdout.splitlines()


def test_no_bytecode_caches_tracked():
    bad = [f for f in _tracked_files()
           if "__pycache__" in f.split("/") or f.endswith((".pyc", ".pyo"))]
    assert not bad, f"bytecode caches tracked in git: {bad}"


def test_gitignore_covers_bytecode_caches():
    with open(os.path.join(REPO_ROOT, ".gitignore")) as f:
        rules = {line.strip() for line in f}
    assert "__pycache__/" in rules
    assert "*.pyc" in rules
