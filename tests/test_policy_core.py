"""Policy-core extraction property suite.

Three layers of bit-identity guard the refactor that moved the
batch-formation semantics into :mod:`repro.core.policy`:

1. **pre-refactor references** — frozen verbatim copies of the original
   ``repro.sim.queueing`` ``edf`` / ``slo_drop`` scalar loops (the two
   policies whose formation loops now delegate to the core primitives)
   are compared against the refactored policies on random traces — all
   policies, scalar and classed (per-query) deadlines, dynamic replica
   schedules, shed-margin schedules;
2. **reference simulator** — the core's scalar
   :func:`~repro.core.policy.simulate_stage_ref` (the live executor's
   semantics and the policy-switching path) is bit-identical to every
   dedicated policy, including the blocked vectorized FIFO kernel;
3. **engine threading** — per-stage ``policy_schedules`` route through
   the switched path: a constant schedule equals the dedicated policy
   end-to-end, a mid-run fifo->edf switch is causal (pre-switch batches
   unchanged) and actually changes the discipline, and the control loop
   folds ``kind="policy"`` events into runs.
"""

import heapq

import numpy as np
import pytest

from repro.core.pipeline import PipelineConfig, StageConfig, linear_pipeline
from repro.core.policy import (
    LiveQueue,
    PolicySchedule,
    ReplicaPool,
    ShedMarginSchedule,
    simulate_stage_ref,
)
from repro.core.profiler import ModelSpec, ProfileStore, profile_model_analytic
from repro.sim import ControlEvent, ControlLoopSession, ScheduleController
from repro.sim.queueing import QUEUE_POLICIES, edf, fifo, simulate_stage, slo_drop, switched
from repro.workload.generator import gamma_trace

_FAR_FUTURE = 1e18


# -- frozen PRE-REFACTOR references (verbatim seed copies) ------------------


def _edf_pre_refactor(ready, latency_lut, max_batch, replicas,
                      replica_events=None, timeout_s=0.0, deadline=None,
                      shed_events=None):
    k = ready.shape[0]
    done = np.full(k, _FAR_FUTURE, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return done, np.zeros(0, dtype=np.int64), dropped
    eff_batch = min(int(max_batch), latency_lut.shape[0] - 1)
    pool = ReplicaPool(replicas, replica_events)
    batches = []
    ready_l = ready.tolist()
    lut_l = latency_lut.tolist()
    key_l = deadline.tolist() if deadline is not None else ready_l

    pending = []
    ai = 0
    served = 0
    while served < k:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            break
        f = heapq.heappop(pool.free)
        start = f
        take = []
        retired = False
        while True:
            if pool.events:
                pool.apply_events(start)
                if pool.retire_if_pending(start):
                    retired = True
                    break
            while ai < k and ready_l[ai] <= start:
                heapq.heappush(pending, (key_l[ai], ai))
                ai += 1
            deferred = []
            while pending and len(take) < eff_batch:
                item = heapq.heappop(pending)
                if ready_l[item[1]] <= start:
                    take.append(item[1])
                else:
                    deferred.append(item)
            for item in deferred:
                heapq.heappush(pending, item)
            if take:
                break
            t_next = min((ready_l[i] for _, i in pending), default=np.inf)
            if ai < k and ready_l[ai] < t_next:
                t_next = ready_l[ai]
            start = t_next
        if retired:
            continue
        b = len(take)
        end = start + lut_l[b]
        for i in take:
            done[i] = end
        batches.append(b)
        served += b
        heapq.heappush(pool.free, end)
    return done, np.asarray(batches, dtype=np.int64), dropped


def _slo_drop_pre_refactor(ready, latency_lut, max_batch, replicas,
                           replica_events=None, timeout_s=0.0, deadline=None,
                           shed_events=None):
    import bisect
    if deadline is None:
        return fifo(ready, latency_lut, max_batch, replicas,
                    replica_events, timeout_s=0.0)
    k = ready.shape[0]
    done = np.empty(k, dtype=np.float64)
    dropped = np.zeros(k, dtype=bool)
    if k == 0:
        return done, np.zeros(0, dtype=np.int64), dropped
    eff_batch = min(int(max_batch), latency_lut.shape[0] - 1)
    ready_l = ready.tolist()
    deadline_l = deadline.tolist()
    lut_l = latency_lut.tolist()
    solo_lat = lut_l[1]
    pool = ReplicaPool(replicas, replica_events)
    batches = []
    shed = sorted(shed_events) if shed_events else None
    if shed is not None:
        shed_ts = [t for t, _ in shed]
        shed_ms = [m for _, m in shed]

    ptr = 0
    while ptr < k:
        if not pool.free:
            if pool.has_future_adds():
                pool.fast_forward()
                continue
            done[ptr:] = _FAR_FUTURE
            break
        f = heapq.heappop(pool.free)
        r0 = ready_l[ptr]
        start = r0 if r0 > f else f
        pool.apply_events(start)
        if pool.retire_if_pending(start):
            continue
        floor = start + solo_lat
        if shed is not None:
            si = bisect.bisect_right(shed_ts, start)
            if si:
                floor += shed_ms[si - 1]
        take = []
        i = ptr
        while i < k and ready_l[i] <= start and len(take) < eff_batch:
            if deadline_l[i] < floor:
                dropped[i] = True
                done[i] = np.inf
            else:
                take.append(i)
            i += 1
        ptr = i
        if not take:
            heapq.heappush(pool.free, f)
            continue
        b = len(take)
        end = start + lut_l[b]
        done[take] = end
        batches.append(b)
        heapq.heappush(pool.free, end)
    return done, np.asarray(batches, dtype=np.int64), dropped


# -- random stage-case generator --------------------------------------------


def _random_case(rng, n_max=400):
    n = int(rng.integers(1, n_max))
    ready = np.sort(rng.uniform(0, 30, n))
    if rng.random() < 0.3:              # tie runs exercise run-length paths
        ready = np.round(ready, 1)
        ready.sort()
    max_batch = int(rng.integers(1, 9))
    lut = np.concatenate([[0.0], np.sort(rng.uniform(0.01, 0.3, 8))])
    replicas = int(rng.integers(0, 4))
    events = None
    if rng.random() < 0.5:
        events = sorted(
            (float(rng.uniform(0, 30)), int(rng.choice([-1, 1, 2])))
            for _ in range(int(rng.integers(1, 5))))
        if replicas == 0:
            events = [(0.0, 1)] + events
    if rng.random() < 0.5:              # classed (per-query) deadlines
        slo = rng.choice([0.1, 0.4, 1.5], size=n)
        deadline = ready + slo
    elif rng.random() < 0.7:            # scalar SLO
        deadline = ready + float(rng.uniform(0.05, 1.0))
    else:
        deadline = None
    shed = None
    if rng.random() < 0.4:
        shed = sorted(
            (float(rng.uniform(0, 30)),
             float(rng.choice([-np.inf, 0.0, 0.05, 0.2])))
            for _ in range(2))
    timeout = float(rng.choice([0.0, 0.0, 0.05]))
    return ready, lut, max_batch, replicas, events, timeout, deadline, shed


def _assert_same(a, b, ctx):
    for x, y, name in zip(a, b, ("done", "batches", "dropped")):
        assert np.array_equal(x, y), (ctx, name, x[:8], y[:8])


@pytest.mark.parametrize("seed", range(8))
def test_refactored_policies_match_pre_refactor_references(seed):
    """The extracted-core policies are bit-identical to frozen verbatim
    copies of the pre-refactor loops (scalar AND classed deadlines,
    dynamic pools, shed schedules)."""
    rng = np.random.default_rng(1000 + seed)
    for trial in range(40):
        case = _random_case(rng)
        _assert_same(edf(*case), _edf_pre_refactor(*case),
                     ("edf", seed, trial))
        _assert_same(slo_drop(*case), _slo_drop_pre_refactor(*case),
                     ("slo-drop", seed, trial))


@pytest.mark.parametrize("seed", range(8))
def test_reference_simulator_bit_identical_to_dedicated_policies(seed):
    """simulate_stage_ref == fifo/edf/slo-drop on random traces."""
    rng = np.random.default_rng(2000 + seed)
    for trial in range(40):
        case = _random_case(rng)
        for name, fn in (("fifo", fifo), ("edf", edf),
                         ("slo-drop", slo_drop)):
            _assert_same(
                fn(*case),
                simulate_stage_ref(*case, policy=name),
                (name, seed, trial))


def test_reference_simulator_matches_blocked_fifo_kernel():
    """Long steady trace: the vectorized blocked fill and the scalar
    policy-core stepping agree bit-for-bit."""
    rng = np.random.default_rng(7)
    ready = np.sort(rng.uniform(0, 120, 60_000))
    lut = np.concatenate([[0.0], np.sort(rng.uniform(0.001, 0.01, 16))])
    _assert_same(fifo(ready, lut, 16, 3),
                 simulate_stage_ref(ready, lut, 16, 3, policy="fifo"),
                 ("fifo-block",))


def test_switched_constant_schedule_equals_dedicated():
    """A policy schedule that never switches (or 'switches' to the same
    policy) is the dedicated policy, bit-for-bit."""
    rng = np.random.default_rng(11)
    for _ in range(10):
        case = _random_case(rng)
        for name in QUEUE_POLICIES:
            base = QUEUE_POLICIES[name](*case)
            _assert_same(base, switched(*case, policy=name),
                         (name, "no-events"))
            _assert_same(
                base,
                switched(*case, policy=name, policy_events=[(5.0, name)]),
                (name, "self-switch"))


def test_switch_is_causal_and_changes_discipline():
    """fifo->edf at t: batches dispatched before t match the pure-fifo
    run; after t an urgent late query overtakes the backlog."""
    # 1 replica, 3 s batch-1 service: a backlog builds behind the burst
    # at t=5; the urgent straggler arrives last with the tightest
    # deadline
    ready = np.array([0.0, 5.0, 5.0, 5.1])
    deadline = np.array([50., 50., 50., 5.3])
    lut = np.array([0.0, 3.0])
    t_switch = 4.0
    d_fifo, _, _ = fifo(ready, lut, 1, 1)
    d_sw, _, _ = simulate_stage_ref(
        ready, lut, 1, 1, deadline=deadline,
        policy="fifo", policy_events=[(t_switch, "edf")])
    # pre-switch batches identical (causality)
    pre = [i for i in range(len(ready)) if d_fifo[i] <= t_switch]
    assert pre and all(d_sw[i] == d_fifo[i] for i in pre)
    # post-switch: the urgent query overtakes the older backlog
    assert d_sw[3] < d_sw[2]
    # pure fifo serves it last
    assert d_fifo[3] == d_fifo.max()


def test_shed_margin_schedule_matches_inline_bisect():
    import bisect
    rng = np.random.default_rng(3)
    events = sorted((float(rng.uniform(0, 10)), float(rng.uniform(-1, 1)))
                    for _ in range(6))
    sched = ShedMarginSchedule(events)
    ts = [t for t, _ in events]
    ms = [m for _, m in events]
    for t in np.concatenate([rng.uniform(-1, 12, 200), np.asarray(ts)]):
        si = bisect.bisect_right(ts, t)
        expect = ms[si - 1] if si else 0.0
        assert sched.margin(float(t)) == expect
    assert ShedMarginSchedule(None).margin(3.0) == 0.0
    assert not ShedMarginSchedule([])


def test_policy_schedule_lookup_and_validation():
    ps = PolicySchedule("fifo", [(2.0, "edf"), (5.0, "slo-drop")])
    assert ps.policy_at(0.0) == "fifo"
    assert ps.policy_at(2.0) == "edf"
    assert ps.policy_at(4.999) == "edf"
    assert ps.policy_at(5.0) == "slo-drop"
    assert PolicySchedule("edf").constant()
    with pytest.raises(ValueError):
        PolicySchedule("nope")
    with pytest.raises(ValueError):
        PolicySchedule("fifo", [(1.0, "bogus")])


# -- engine threading --------------------------------------------------------


@pytest.fixture(scope="module")
def mini():
    store = ProfileStore()
    store.add(profile_model_analytic(ModelSpec("m0", 2e9, 1e6, 1e6)))
    store.add(profile_model_analytic(ModelSpec("m1", 2.3e10, 1.2e8, 5e7)))
    pipe = linear_pipeline("mini", ["m0", "m1"])
    return pipe, store


def _cfg(pipe, policy="fifo"):
    return PipelineConfig({
        s: StageConfig("tpu-v5e-1", 8, 2, policy=policy)
        for s in pipe.stages})


def test_engine_policy_schedule_from_t0_equals_config_policy(mini):
    from repro.sim import SimEngine
    pipe, store = mini
    arr = gamma_trace(400, 2.0, 20, seed=5)
    eng = SimEngine(pipe, store)
    stage = pipe.toposort()[1]
    res_sched = eng.simulate(_cfg(pipe), arr, slo_s=0.2,
                             policy_schedules={stage: [(0.0, "edf")]})
    cfg_edf = _cfg(pipe)
    cfg_edf[stage].policy = "edf"
    res_cfg = eng.simulate(cfg_edf, arr, slo_s=0.2)
    # an arrival at exactly t=0 would dispatch at start=0.0 where the
    # schedule boundary is inclusive, so the two runs agree exactly
    assert np.array_equal(res_sched.latency, res_cfg.latency)


def test_engine_policy_schedule_cache_keys_distinct(mini):
    pipe, store = mini
    from repro.sim import SimEngine
    arr = gamma_trace(300, 2.0, 10, seed=6)
    eng = SimEngine(pipe, store)
    sess = eng.session(arr, slo_s=0.2)
    stage = pipe.toposort()[0]
    base = sess.simulate(_cfg(pipe))
    switched_res = sess.simulate(
        _cfg(pipe), policy_schedules={stage: [(3.0, "edf")]})
    again = sess.simulate(_cfg(pipe))
    assert np.array_equal(base.latency, again.latency)
    assert sess.stats["stage_hits"] >= 2      # replay, not recompute
    # distinct schedules must not collide in the cone cache
    k1 = sess.config_key(_cfg(pipe))
    k2 = sess.config_key(_cfg(pipe),
                         policy_schedules={stage: [(3.0, "edf")]})
    assert k1 != k2
    del switched_res


def test_control_loop_policy_event_lands_and_records(mini):
    pipe, store = mini
    cfg = _cfg(pipe)
    arr = gamma_trace(500, 3.0, 20, seed=8)
    stage = pipe.toposort()[1]
    ev = ControlEvent(6.0, 6.0, stage, "policy", 0.0, policy="edf")
    sess = ControlLoopSession(pipe, store, cfg, 0.15)
    res = sess.run(arr, ScheduleController([ev]))
    assert res.policy_schedules == {stage: [(6.0, "edf")]}
    assert [e.kind for e in res.events] == ["policy"]
    # final sim replays under the folded schedule
    direct = ControlLoopSession(pipe, store, cfg, 0.15).engine.simulate(
        cfg, arr, slo_s=0.15, policy_schedules={stage: [(6.0, "edf")]})
    assert np.array_equal(res.sim.latency, direct.latency)


def test_control_loop_rejects_nameless_policy_event(mini):
    pipe, store = mini
    cfg = _cfg(pipe)
    arr = gamma_trace(100, 1.0, 3, seed=9)
    stage = pipe.toposort()[0]
    ev = ControlEvent(1.0, 1.0, stage, "policy", 0.0)
    with pytest.raises(ValueError, match="policy"):
        ControlLoopSession(pipe, store, cfg, 0.15).run(
            arr, ScheduleController([ev]))


# -- LiveQueue (the executor's queue) ---------------------------------------


def test_live_queue_fifo_and_ready_gating():
    q = LiveQueue("fifo")
    q.push("a", ready=0.0)
    q.push("b", ready=0.1)
    q.push("c", ready=5.0)            # not ready yet
    batch, shed = q.form_batch(1.0, max_batch=8)
    assert batch == ["a", "b"] and shed == []
    assert len(q) == 1
    assert q.next_ready_after(1.0) == 5.0
    batch, _ = q.form_batch(5.0, max_batch=8)
    assert batch == ["c"] and len(q) == 0
    assert q.next_ready_after(6.0) is None


def test_live_queue_edf_orders_by_deadline():
    q = LiveQueue("edf")
    q.push("late", ready=0.0, deadline=9.0)
    q.push("urgent", ready=0.2, deadline=1.0)
    q.push("mid", ready=0.1, deadline=5.0)
    batch, _ = q.form_batch(1.0, max_batch=2)
    assert batch == ["urgent", "mid"]
    batch, _ = q.form_batch(1.0, max_batch=2)
    assert batch == ["late"]


def test_live_queue_slo_drop_sheds_hopeless():
    q = LiveQueue("slo-drop")
    q.push("dead", ready=0.0, deadline=1.0)
    q.push("alive", ready=0.0, deadline=10.0)
    batch, shed = q.form_batch(2.0, max_batch=8, solo_latency_s=0.5)
    assert batch == ["alive"] and shed == ["dead"]
    # margin raises the floor
    q.push("tight", ready=2.0, deadline=3.0)
    q.shed_margin = 2.0
    batch, shed = q.form_batch(2.5, max_batch=8, solo_latency_s=0.1)
    assert shed == ["tight"] and batch == []


def test_live_queue_bookkeeping_stays_bounded():
    """Leak regression: a long-running fifo queue must not accumulate
    tombstones — consumed entries leave the item table immediately and
    both internal heaps are pruned, including the deadline heap a
    fifo-only queue never selects from."""
    q = LiveQueue("fifo")
    for i in range(5000):
        q.push(i, ready=float(i), deadline=float(i) + 1.0)
        if i % 7 == 3:
            q.form_batch(float(i), max_batch=8)
    q.form_batch(1e9, max_batch=10**9)
    assert len(q) == 0
    assert len(q._items) == 0 and len(q._ready) == 0
    assert len(q._arr) == 0 and len(q._edf) == 0


def test_live_queue_policy_switch_midstream():
    q = LiveQueue("fifo")
    q.push("old", ready=0.0, deadline=50.0)
    q.push("urgent", ready=0.5, deadline=1.0)
    q.set_policy("edf")
    batch, _ = q.form_batch(1.0, max_batch=1)
    assert batch == ["urgent"]
    with pytest.raises(ValueError):
        q.set_policy("wat")
