"""Profiler: analytic backend shape, interpolation, measured backend."""

import numpy as np
import pytest

from repro.core.hardware import get_hardware
from repro.core.profiler import (
    ModelSpec,
    ModelProfile,
    ProfileStore,
    analytic_batch_latency,
    profile_model_analytic,
    profile_model_measured,
)

SPEC = ModelSpec("m", flops_per_query=2e10, weight_bytes=1e8,
                 act_bytes_per_query=5e7)


def test_latency_increases_with_batch():
    prof = profile_model_analytic(SPEC)
    for hw in prof.hardware_types():
        lats = [prof.batch_latency(hw, b) for b in prof.batch_sizes]
        assert all(b2 >= b1 for b1, b2 in zip(lats, lats[1:]))


def test_throughput_improves_with_batch_on_accelerator():
    """Paper Fig. 3: batching raises accelerator throughput (weight reads
    amortize) until compute-bound."""
    prof = profile_model_analytic(SPEC)
    t1 = prof.throughput("tpu-v5e-1", 1)
    t32 = prof.throughput("tpu-v5e-1", 32)
    assert t32 > t1


def test_non_parallelizable_stage_sees_no_batching_benefit():
    spec = ModelSpec("prep", 2e9, 1e6, 1e6, parallelizable=False)
    prof = profile_model_analytic(spec)
    # throughput roughly flat in batch; accelerator no better than CPU
    t_cpu_1 = prof.throughput("cpu-1", 1)
    t_cpu_32 = prof.throughput("cpu-1", 32)
    assert t_cpu_32 == pytest.approx(t_cpu_1, rel=0.15)
    l_tpu = prof.batch_latency("tpu-v5e-8", 8)
    l_cpu = prof.batch_latency("cpu-1", 8)
    assert l_tpu >= l_cpu  # overhead only hurts


def test_accelerator_speedup_for_parallel_model():
    """The 84x CPU->K80 style gap (paper §2.1) reproduced on the menu."""
    prof = profile_model_analytic(SPEC)
    speedup = prof.max_throughput("tpu-v5e-1") / prof.max_throughput("cpu-1")
    assert speedup > 20


def test_latency_ordering_amortized_batches():
    """§9 planner assumption, relaxed: at batch 1 the bigger slices' fixed
    dispatch overhead can exceed the compute saving (a documented menu
    property the implementation tolerates — BestHardware picks by
    measured batch-1 latency, and DowngradeHW searches all cheaper
    options rather than assuming the ordering). From batch 8 up, where
    overhead is amortized, the strict ordering holds."""
    prof = profile_model_analytic(SPEC)
    order = ["tpu-v5e-8", "tpu-v5e-4", "tpu-v5e-1", "cpu-1"]
    for b in [b for b in prof.batch_sizes if b >= 16]:
        lats = [prof.batch_latency(h, b) for h in order]
        assert lats == sorted(lats), f"ordering violated at batch {b}"


def test_interpolation_between_grid_points():
    prof = profile_model_analytic(SPEC)
    l8 = prof.batch_latency("tpu-v5e-1", 8)
    l16 = prof.batch_latency("tpu-v5e-1", 16)
    l12 = prof.batch_latency("tpu-v5e-1", 12)
    assert l8 <= l12 <= l16


def test_extrapolation_above_grid():
    prof = profile_model_analytic(SPEC)
    l_max = prof.batch_latency("tpu-v5e-1", max(prof.batch_sizes))
    l_big = prof.batch_latency("tpu-v5e-1", 2 * max(prof.batch_sizes))
    assert l_big > l_max


def test_latency_lut():
    prof = profile_model_analytic(SPEC)
    lut = prof.latency_lut("tpu-v5e-1", 16)
    assert lut.shape == (17,)
    assert lut[0] == 0.0
    assert np.all(np.diff(lut[1:]) >= -1e-12)


def test_batch_zero_rejected():
    prof = profile_model_analytic(SPEC)
    with pytest.raises(ValueError):
        prof.batch_latency("cpu-1", 0)


def test_collective_term_on_multichip():
    spec = ModelSpec("m", 2e10, 1e8, 5e7, collective_bytes_per_query=1e7)
    l_multi = analytic_batch_latency(spec, get_hardware("tpu-v5e-4"), 4)
    spec0 = ModelSpec("m", 2e10, 1e8, 5e7, collective_bytes_per_query=0.0)
    l_nocoll = analytic_batch_latency(spec0, get_hardware("tpu-v5e-4"), 4)
    assert l_multi > l_nocoll
    # single chip: no collective term
    l1 = analytic_batch_latency(spec, get_hardware("tpu-v5e-1"), 4)
    l1n = analytic_batch_latency(spec0, get_hardware("tpu-v5e-1"), 4)
    assert l1 == pytest.approx(l1n)


def test_measured_backend_wall_clock():
    import time

    def run_batch(b):
        time.sleep(0.001 * b)

    prof = profile_model_measured("toy", run_batch, batch_sizes=(1, 4),
                                  repeats=1, warmup=0)
    assert prof.batch_latency("cpu-1", 4) > prof.batch_latency("cpu-1", 1)


def test_profile_store():
    store = ProfileStore()
    store.add(profile_model_analytic(SPEC))
    assert "m" in store
    assert store.model_ids() == ["m"]
    with pytest.raises(KeyError):
        store.get("ghost")
