"""BeamPlanner: beam-search refinement over the Alg. 2 action set.

Contract (ISSUE 3 acceptance): on every benchmark pipeline the beam
returns a *feasible* plan costing at most the greedy Planner's — the
greedy fixed point seeds the search and is only ever improved on. The
frontier's successor sets are scored through the session's batched
``percentile_many`` surface, so these tests double as end-to-end
coverage of the batched planner scoring path.
"""

import numpy as np
import pytest

from repro.core.estimator import Estimator
from repro.core.planner import BeamPlanner, Planner
from repro.workload.generator import gamma_trace

SLO = 0.15


def test_beam_never_worse_than_greedy_and_feasible(image_pipeline,
                                                   sample_trace):
    pipe, store = image_pipeline
    g = Planner(pipe, store).plan(sample_trace, SLO)
    b = BeamPlanner(pipe, store, beam_width=4).plan(sample_trace, SLO)
    assert g.feasible and b.feasible
    assert b.cost_per_hr <= g.cost_per_hr + 1e-9
    est = Estimator(pipe, store)
    assert est.simulate(b.config, sample_trace).p99 <= SLO


def test_beam_on_conditional_pipeline(social_pipeline, sample_trace):
    pipe, store = social_pipeline
    g = Planner(pipe, store).plan(sample_trace, SLO)
    b = BeamPlanner(pipe, store, beam_width=3).plan(sample_trace, SLO)
    assert b.feasible
    assert b.cost_per_hr <= g.cost_per_hr + 1e-9
    est = Estimator(pipe, store)
    assert est.simulate(b.config, sample_trace).p99 <= SLO


def test_beam_width_one_still_sound(image_pipeline, sample_trace):
    """Width-1 beam degenerates gracefully (still >= greedy quality)."""
    pipe, store = image_pipeline
    g = Planner(pipe, store).plan(sample_trace, SLO)
    b = BeamPlanner(pipe, store, beam_width=1).plan(sample_trace, SLO)
    assert b.feasible
    assert b.cost_per_hr <= g.cost_per_hr + 1e-9


def test_beam_infeasible_slo_detected(image_pipeline, sample_trace):
    pipe, store = image_pipeline
    res = BeamPlanner(pipe, store).plan(sample_trace, slo=1e-4)
    assert not res.feasible
    assert res.config is None


def test_beam_width_validation(image_pipeline):
    pipe, store = image_pipeline
    with pytest.raises(ValueError, match="beam_width"):
        BeamPlanner(pipe, store, beam_width=0)


def test_beam_bursty_tight_slo_can_beat_greedy(image_pipeline):
    """The §7.2 local-optimum corner (bursty + tight SLO): the beam must
    never lose to greedy, and its batched frontier search is the place
    a win would come from."""
    pipe, store = image_pipeline
    trace = gamma_trace(300, 4.0, 60, seed=44)
    slo = 0.12
    g = Planner(pipe, store).plan(trace, slo)
    b = BeamPlanner(pipe, store, beam_width=6).plan(trace, slo)
    assert b.feasible
    assert b.cost_per_hr <= g.cost_per_hr + 1e-9
    est = Estimator(pipe, store)
    assert est.simulate(b.config, trace).p99 <= slo


def test_beam_classed_plan(image_pipeline, sample_trace):
    """plan_classed works through the beam (multi-class feasibility)."""
    from repro.workload.slo_classes import SLOClass, classed_trace
    pipe, store = image_pipeline
    classes = (SLOClass("tight", lam=30.0, cv=1.0, slo_s=0.12),
               SLOClass("loose", lam=70.0, cv=1.0, slo_s=0.5))
    trace = classed_trace(classes, duration_s=30.0, seed=5)
    g = Planner(pipe, store).plan_classed(trace)
    b = BeamPlanner(pipe, store, beam_width=3).plan_classed(trace)
    assert b.feasible
    assert b.cost_per_hr <= g.cost_per_hr + 1e-9
    assert set(b.per_class_p) == {"tight", "loose"}
    for cls in classes:
        assert b.per_class_p[cls.name] <= cls.slo_s
