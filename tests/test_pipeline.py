"""Pipeline DAG structure, scale factors, config cost accounting."""

import pytest

from repro.core.hardware import HARDWARE_MENU, cheaper_hardware, get_hardware
from repro.core.pipeline import (
    SOURCE,
    Edge,
    Pipeline,
    PipelineConfig,
    Stage,
    StageConfig,
    linear_pipeline,
)


def test_linear_pipeline_structure():
    p = linear_pipeline("p", ["a", "b", "c"])
    assert p.toposort() == ["s0_a", "s1_b", "s2_c"]
    assert p.sinks() == ["s2_c"]
    assert [e.src for e in p.entry_edges()] == [SOURCE]


def test_scale_factors_linear():
    p = linear_pipeline("p", ["a", "b"])
    s = p.scale_factors()
    assert s == {"s0_a": 1.0, "s1_b": 1.0}


def test_scale_factors_conditional(social_pipeline):
    pipe, _ = social_pipeline
    s = pipe.scale_factors()
    assert s["lang_id"] == 1.0
    assert s["img_cls"] == 1.0
    assert s["translate"] == pytest.approx(0.4)
    # categorize: 0.4 (via translate) + 0.6 (direct) + 1.0 (img) capped at 1
    assert s["categorize"] == 1.0


def test_cycle_detection():
    stages = {"a": Stage("a", "m"), "b": Stage("b", "m")}
    edges = [Edge(SOURCE, "a"), Edge("a", "b"), Edge("b", "a")]
    with pytest.raises(ValueError, match="cycle"):
        Pipeline("bad", stages, edges)


def test_unknown_edge_target():
    with pytest.raises(ValueError):
        Pipeline("bad", {"a": Stage("a", "m")},
                 [Edge(SOURCE, "a"), Edge("a", "ghost")])


def test_bad_edge_probability():
    with pytest.raises(ValueError):
        Edge(SOURCE, "a", probability=0.0)
    with pytest.raises(ValueError):
        Edge(SOURCE, "a", probability=1.5)


def test_longest_path(social_pipeline):
    pipe, _ = social_pipeline
    path = pipe.longest_path_stages()
    assert path == ["lang_id", "translate", "categorize"]


def test_config_cost():
    cfg = PipelineConfig({
        "a": StageConfig("tpu-v5e-1", 8, 2),
        "b": StageConfig("cpu-1", 1, 4),
    })
    expect = 2 * get_hardware("tpu-v5e-1").cost_per_hr + \
        4 * get_hardware("cpu-1").cost_per_hr
    assert cfg.cost_per_hr() == pytest.approx(expect)


def test_config_copy_is_deep():
    cfg = PipelineConfig({"a": StageConfig("cpu-1", 1, 1)})
    cp = cfg.copy()
    cp["a"].replicas = 9
    assert cfg["a"].replicas == 1


def test_stageconfig_validation():
    with pytest.raises(KeyError):
        StageConfig("gpu-v100", 1, 1)
    with pytest.raises(ValueError):
        StageConfig("cpu-1", 0, 1)


def test_hardware_menu_latency_ordering():
    """§9 assumption: total ordering of latency across batch sizes."""
    costs = [h.cost_per_hr for h in HARDWARE_MENU]
    assert costs == sorted(costs, reverse=True)


def test_cheaper_hardware():
    cheaper = cheaper_hardware("tpu-v5e-4")
    assert "tpu-v5e-1" in cheaper and "cpu-1" in cheaper
    assert "tpu-v5e-8" not in cheaper
    assert cheaper_hardware("cpu-1") == ()
