"""Validate the committed multi-pod dry-run artifacts (deliverable e).

These tests read artifacts/dryrun/*.json produced by
``python -m repro.launch.dryrun``; they check coverage (every arch x
shape x mesh accounted for), success, and roofline-term sanity. If the
artifacts are missing the tests are skipped (run the dry-run first).
"""

import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS
from repro.launch.shapes import SHAPES

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

LONG_OK = {"xlstm-125m", "jamba-1.5-large-398b", "llama3.2-1b"}


def _load_all():
    arts = {}
    for p in glob.glob(os.path.join(ART, "*.json")):
        with open(p) as f:
            a = json.load(f)
        arts[(a["arch"], a["shape"], a["mesh"])] = a
    return arts


ARTS = _load_all()
pytestmark = pytest.mark.skipif(
    len(ARTS) < 10, reason="dry-run artifacts not generated yet")


def test_full_coverage():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                assert (arch, shape, mesh) in ARTS, (arch, shape, mesh)


def test_no_failures():
    bad = [(k, v.get("error")) for k, v in ARTS.items()
           if v["status"] == "fail"]
    assert not bad, bad


def test_long_context_policy():
    for arch in ARCH_IDS:
        for mesh in ("single", "multi"):
            a = ARTS[(arch, "long_500k", mesh)]
            if arch in LONG_OK:
                assert a["status"] == "ok", (arch, a.get("reason"))
            else:
                assert a["status"] == "skipped", arch


def test_chip_counts():
    for (arch, shape, mesh), a in ARTS.items():
        if a["status"] != "ok":
            continue
        assert a["chips"] == (512 if mesh == "multi" else 256)


def test_roofline_terms_present_and_positive():
    for key, a in ARTS.items():
        if a["status"] != "ok":
            continue
        r = a["roofline"]
        assert r["hlo_flops"] > 0, key
        assert r["hlo_bytes"] > 0, key
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert 0 < r["t_compute_s"] < 3600
        assert 0 < r["t_memory_s"] < 3600


# Pairs allowed over the per-chip HBM budget, with the physical reason
# (documented in EXPERIMENTS.md §Dry-run). deepseek-v3 training state
# alone (params+grads+bf16 moments = 8 B/param x 671B = 5.4 TB) exceeds a
# single pod's 4 TB aggregate HBM — no sharding can fix arithmetic.
MEM_WAIVERS = {
    # train state 8 B/param x 671B = 5.4 TB > one pod's 4 TB HBM
    ("deepseek-v3-671b", "train_4k", "single"),
    ("deepseek-v3-671b", "train_4k", "multi"),
    # irreducible state ~= the whole 16 GiB budget (args alone 15.9 GiB);
    # remaining overage is XLA:CPU fp32-widened transients (§Perf)
    ("jamba-1.5-large-398b", "train_4k", "single"),
    ("jamba-1.5-large-398b", "train_4k", "multi"),
}
# Budget multiplier for remaining compiler slack (resharding copies and
# fp32-widened loop buffers XLA:CPU keeps; tracked in §Perf).
SLACK = 3.0


def test_memory_fits_hbm():
    """memory_analysis() describes the per-device SPMD program (verified
    against hand-sharded matmuls): arg+temp+out must fit a 16 GiB v5e
    chip within the documented compiler slack."""
    HBM = 16 * 1024**3
    over = []
    for key, a in ARTS.items():
        if a["status"] != "ok" or key in MEM_WAIVERS:
            continue
        m = a["memory_analysis"]
        per_dev = (m.get("argument_size_in_bytes", 0)
                   + m.get("temp_size_in_bytes", 0)
                   + m.get("output_size_in_bytes", 0))
        if per_dev >= SLACK * HBM:
            over.append((key, round(per_dev / 2**30, 1)))
    assert not over, over


def test_multi_pod_shards_pod_axis():
    """Multi-pod runs exist and lower with 512 chips — the pod axis is
    exercised. Training runs must show gradient collectives."""
    for arch in ("llama3.2-1b", "qwen2-72b", "deepseek-v3-671b"):
        a = ARTS[(arch, "train_4k", "multi")]
        assert a["status"] == "ok"
        assert a["roofline"]["collective_bytes"] > 0


def test_useful_flops_ratio_recorded():
    """The ratio is recorded for every pair. XLA cost_analysis counts
    scanned layer bodies once (verified empirically), so the raw ratio
    can exceed 1 by up to ~num_layers; the roofline terms compensate
    with analytic floors — here we assert presence and positivity."""
    for key, a in ARTS.items():
        if a["status"] != "ok":
            continue
        r = a["roofline"]["useful_flops_ratio"]
        assert r > 0, key
        assert a["roofline"]["analytic_bytes"] > 0, key
