"""Mixed per-query SLO classes end-to-end (workload -> engine -> planner).

Covers the three contracts of the SLO-class feature:

1. **Golden equivalence** — class-tagging is pure metadata on the
   arrival stream: a single-class trace simulated through the classed
   path (deadline vector + class ids attached) is *bit-identical* to the
   frozen seed implementation and to the untagged engine path.
2. **Per-class accounting** — `SimResult.per_class` partitions the trace
   exactly, and miss rates are measured against each class's own SLO.
3. **Multi-class planning** — `Planner.plan_classed` returns a
   configuration under which every class meets its own percentile
   deadline, never costing more than planning the whole mix at the
   tightest SLO.

Plus the hypothesis property tests (via the tests/_hyp.py shim): EDF
serves ready queries in deadline order, and tagging a class with a
tighter deadline never makes it slower than the uniform-deadline run.
"""

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or deterministic fallback
from test_sim_engine import _random_config, _random_pipeline, _random_trace

from repro.core.pipeline import (
    SOURCE,
    Edge,
    Pipeline,
    PipelineConfig,
    Stage,
    StageConfig,
)
from repro.core.planner import AnnealedPlanner, Planner
from repro.core.profiler import (
    ModelProfile,
    ModelSpec,
    ProfileStore,
    profile_model_analytic,
)
from repro.sim import SimEngine, simulate_stage
from repro.sim.golden import GoldenEstimator
from repro.workload import SLOClass, classed_trace
from repro.workload.generator import gamma_trace

HW = "cpu-1"


def _one_stage(lat_fn, batches=(1, 2, 4, 8)):
    pipe = Pipeline("one", {"m": Stage("m", "m", (HW,))},
                    [Edge(SOURCE, "m")])
    store = ProfileStore()
    store.add(ModelProfile("m", {(HW, b): lat_fn(b) for b in batches},
                           tuple(batches)))
    return pipe, store


MIX = (SLOClass("interactive", 80.0, 2.0, 0.03),
       SLOClass("batch", 140.0, 1.0, 1.0))


# ------------------------------------------------------- trace generation

def test_classed_trace_interleaves_and_tags():
    tr = classed_trace(MIX, 30.0, seed=3)
    assert tr.arrivals.shape == tr.class_ids.shape
    assert (np.diff(tr.arrivals) >= 0).all()
    assert set(np.unique(tr.class_ids)) == {0, 1}
    # each class's sub-stream is exactly its own gamma trace
    for i, c in enumerate(MIX):
        own = gamma_trace(c.lam, c.cv, 30.0, seed=3 + i)
        np.testing.assert_array_equal(tr.arrivals[tr.class_ids == i], own)
    # per-query SLO vector reflects the class tags
    np.testing.assert_array_equal(
        tr.slo_per_query,
        np.where(tr.class_ids == 0, MIX[0].slo_s, MIX[1].slo_s))
    np.testing.assert_array_equal(tr.deadline,
                                  tr.arrivals + tr.slo_per_query)
    assert tr.counts() == {"interactive": int((tr.class_ids == 0).sum()),
                           "batch": int((tr.class_ids == 1).sum())}
    assert tr.min_slo_s == MIX[0].slo_s


def test_classed_trace_single_class_matches_gamma_trace():
    tr = classed_trace([SLOClass("only", 120.0, 1.0, 0.2)], 20.0, seed=9)
    np.testing.assert_array_equal(
        tr.arrivals, gamma_trace(120.0, 1.0, 20.0, seed=9))
    assert (tr.class_ids == 0).all()


def test_classed_trace_rejects_bad_input():
    with pytest.raises(ValueError):
        classed_trace([], 10.0)
    with pytest.raises(ValueError):
        classed_trace([SLOClass("a", 10, 1.0, 0.1),
                       SLOClass("a", 20, 1.0, 0.2)], 10.0)
    with pytest.raises(ValueError):
        SLOClass("bad", 10.0, 1.0, -1.0)


# -------------------------------------------------- golden equivalence guard

def test_single_class_bit_identical_to_seed_randomized():
    """The classed path (deadline vector + tags attached) must NOT perturb
    simulation: on uniform-SLO traces with the paper's fifo policy, the
    per-query latencies equal the frozen seed implementation bit for bit,
    and equal the untagged engine path."""
    rng = np.random.default_rng(99)
    for _ in range(10):
        pipe, store = _random_pipeline(rng, int(rng.integers(1, 5)))
        seed = int(rng.integers(100))
        engine = SimEngine(pipe, store, seed=seed)
        golden = GoldenEstimator(pipe, store, seed=seed)
        arr = _random_trace(rng)
        uniform_slo = np.full(arr.shape[0], 0.25)
        ids = np.zeros(arr.shape[0], dtype=np.int64)
        for _ in range(2):
            cfg = _random_config(rng, pipe)
            tagged = engine.simulate(cfg, arr, slo_s=uniform_slo,
                                     class_ids=ids, class_names=("only",))
            plain = engine.simulate(cfg, arr)
            gold = golden.simulate(cfg, arr)
            np.testing.assert_array_equal(tagged.latency, gold.latency)
            np.testing.assert_array_equal(tagged.latency, plain.latency)
            for s in pipe.stages:
                np.testing.assert_array_equal(
                    tagged.per_stage_batches[s], gold.per_stage_batches[s])


def test_scalar_and_vector_slo_identical():
    """A scalar slo_s and its broadcast vector drive identical deadline
    behavior through the deadline-aware policies."""
    pipe, store = _one_stage(lambda b: 0.004 * b)
    engine = SimEngine(pipe, store)
    tr = classed_trace([SLOClass("only", 250.0, 2.0, 0.05)], 20.0, seed=4)
    for policy in ("edf", "slo-drop"):
        cfg = PipelineConfig({"m": StageConfig(HW, 4, 1, policy=policy)})
        scalar = engine.simulate(cfg, tr.arrivals, slo_s=0.05)
        vector = engine.simulate(cfg, tr.arrivals, slo_s=tr.slo_per_query,
                                 class_ids=tr.class_ids,
                                 class_names=tr.class_names)
        np.testing.assert_array_equal(scalar.latency, vector.latency)


def test_session_rejects_misshapen_vectors():
    pipe, store = _one_stage(lambda b: 0.004 * b)
    engine = SimEngine(pipe, store)
    arr = np.arange(10) * 0.01
    with pytest.raises(ValueError, match="slo_s"):
        engine.session(arr, slo_s=np.zeros(3))
    with pytest.raises(ValueError, match="class_ids"):
        engine.session(arr, class_ids=np.zeros(3, dtype=np.int64))


# --------------------------------------------------- per-class accounting

def test_per_class_breakdown_partitions_trace():
    pipe, store = _one_stage(lambda b: 0.004 * b)
    engine = SimEngine(pipe, store)
    tr = classed_trace(MIX, 30.0, seed=1)
    cfg = PipelineConfig({"m": StageConfig(HW, 4, 2, policy="edf")})
    res = engine.simulate(cfg, tr.arrivals, slo_s=tr.slo_per_query,
                          class_ids=tr.class_ids,
                          class_names=tr.class_names)
    bc = res.per_class()
    assert set(bc) == {"interactive", "batch"}
    assert sum(v["n"] for v in bc.values()) == res.num_queries
    for i, c in enumerate(MIX):
        sel = tr.class_ids == i
        assert bc[c.name]["n"] == int(sel.sum())
        assert bc[c.name]["slo_s"] == c.slo_s
        assert bc[c.name]["p99"] == pytest.approx(
            np.percentile(res.latency[sel], 99.0))
        assert bc[c.name]["miss_rate"] == pytest.approx(
            float((res.latency[sel] > c.slo_s).mean()))
    # overall per-query miss rate is the n-weighted mix of the classes
    want = sum(bc[c.name]["miss_rate"] * bc[c.name]["n"] for c in MIX)
    assert res.per_query_miss_rate() == pytest.approx(want / res.num_queries)
    np.testing.assert_array_equal(res.class_mask("interactive"),
                                  tr.class_ids == 0)


def test_per_class_reports_empty_classes():
    """A named class with zero arrivals must still appear (n=0), and its
    planner constraint is trivially feasible — not a silent KeyError."""
    pipe, store = _one_stage(lambda b: 0.004 * b)
    engine = SimEngine(pipe, store)
    tr = classed_trace([SLOClass("tight", 50.0, 1.0, 0.1),
                        SLOClass("ghost", 0.0, 1.0, 0.5)], 10.0, seed=0)
    assert tr.counts()["ghost"] == 0
    cfg = PipelineConfig({"m": StageConfig(HW, 4, 1)})
    res = engine.simulate(cfg, tr.arrivals, slo_s=tr.slo_per_query,
                          class_ids=tr.class_ids,
                          class_names=tr.class_names)
    bc = res.per_class()
    assert bc["ghost"]["n"] == 0 and bc["ghost"]["miss_rate"] == 0.0
    assert bc["tight"]["n"] == tr.n
    session = engine.session(tr.arrivals, slo_s=tr.slo_per_query,
                             class_ids=tr.class_ids,
                             class_names=tr.class_names)
    assert session.class_percentile(cfg, 99.0, 1) == 0.0


def test_per_class_requires_tags():
    pipe, store = _one_stage(lambda b: 0.004 * b)
    res = SimEngine(pipe, store).simulate(
        PipelineConfig({"m": StageConfig(HW, 1, 1)}), np.zeros(5))
    with pytest.raises(ValueError):
        res.per_class()
    with pytest.raises(ValueError):
        res.per_query_miss_rate()


def test_edf_cuts_tight_class_misses_vs_fifo():
    """The headline scenario: interactive+batch mix through a contended
    stage — EDF must not serve the tight class worse than FIFO does."""
    pipe, store = _one_stage(lambda b: 0.004 * b)
    engine = SimEngine(pipe, store)
    tr = classed_trace(MIX, 60.0, seed=2)
    miss = {}
    for policy in ("fifo", "edf"):
        cfg = PipelineConfig({"m": StageConfig(HW, 4, 1, policy=policy)})
        res = engine.simulate(cfg, tr.arrivals, slo_s=tr.slo_per_query,
                              class_ids=tr.class_ids,
                              class_names=tr.class_names)
        miss[policy] = res.per_class()["interactive"]["miss_rate"]
    assert miss["edf"] <= miss["fifo"]
    assert miss["fifo"] > 0          # the scenario actually has contention


# ------------------------------------------------------ multi-class planner

def _image_pipeline():
    prep = ModelSpec("prep", flops_per_query=2e9, weight_bytes=1e6,
                     act_bytes_per_query=1e6, parallelizable=False)
    cls = ModelSpec("res152", flops_per_query=2.3e10, weight_bytes=1.2e8,
                    act_bytes_per_query=5e7)
    from repro.core.pipeline import linear_pipeline
    store = ProfileStore()
    for s in (prep, cls):
        store.add(profile_model_analytic(s))
    return linear_pipeline("image-processing", ["prep", "res152"]), store


def test_plan_classed_meets_every_class_slo():
    pipe, store = _image_pipeline()
    mix = classed_trace([SLOClass("interactive", 60.0, 1.0, 0.12),
                         SLOClass("batch", 120.0, 1.0, 1.0)], 60.0, seed=0)
    res = Planner(pipe, store).plan_classed(mix)
    assert res.feasible
    assert set(res.per_class_p) == {"interactive", "batch"}
    for c in mix.classes:
        assert res.per_class_p[c.name] <= c.slo_s
    # verify against an independent simulation of the returned config
    engine = SimEngine(pipe, store)
    sim = engine.simulate(res.config, mix.arrivals,
                          slo_s=mix.slo_per_query, class_ids=mix.class_ids,
                          class_names=mix.class_names)
    for name, stats in sim.per_class().items():
        assert stats["p99"] <= dict(
            (c.name, c.slo_s) for c in mix.classes)[name] + 1e-12


def test_plan_classed_never_costlier_than_uniform_tightest():
    """Relaxing the batch class to its own loose SLO can only relax the
    constraint set: the multi-class plan costs at most the uniform plan
    at the tightest SLO."""
    pipe, store = _image_pipeline()
    mix = classed_trace([SLOClass("interactive", 40.0, 1.0, 0.1),
                         SLOClass("batch", 160.0, 1.0, 2.0)], 60.0, seed=1)
    classed = Planner(pipe, store).plan_classed(mix)
    uniform = Planner(pipe, store).plan(mix.arrivals, 0.1)
    assert classed.feasible and uniform.feasible
    assert classed.cost_per_hr <= uniform.cost_per_hr + 1e-9


def test_plan_classed_single_class_matches_plan():
    """One class == the paper's scalar-SLO planning, same configuration."""
    pipe, store = _image_pipeline()
    tr = classed_trace([SLOClass("only", 100.0, 1.0, 0.15)], 60.0, seed=0)
    a = Planner(pipe, store).plan_classed(tr)
    b = Planner(pipe, store).plan(tr.arrivals, 0.15)
    assert a.feasible == b.feasible
    assert a.config.cache_key() == b.config.cache_key()
    assert a.cost_per_hr == b.cost_per_hr


def test_plan_classed_annealed_dispatch():
    pipe, store = _image_pipeline()
    mix = classed_trace([SLOClass("interactive", 60.0, 1.0, 0.12),
                         SLOClass("batch", 120.0, 1.0, 1.0)], 60.0, seed=0)
    greedy = Planner(pipe, store).plan_classed(mix)
    annealed = AnnealedPlanner(pipe, store).plan_classed(mix, steps=40)
    assert annealed.feasible
    assert annealed.cost_per_hr <= greedy.cost_per_hr + 1e-9
    for c in mix.classes:
        assert annealed.per_class_p[c.name] <= c.slo_s


def test_plan_classed_requires_engine_estimator():
    pipe, store = _image_pipeline()
    tr = classed_trace([SLOClass("only", 100.0, 1.0, 0.15)], 20.0, seed=0)
    planner = Planner(pipe, store, estimator=GoldenEstimator(pipe, store))
    with pytest.raises(ValueError, match="multi-class"):
        planner.plan_classed(tr)


# ------------------------------------------------------- property tests

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=8, max_value=80))
def test_edf_serves_ready_queries_in_deadline_order(seed, n):
    """EDF invariant: a query left waiting at a dispatch it was ready for
    must have a deadline no earlier than every query in that batch."""
    rng = np.random.default_rng(seed)
    ready = np.sort(rng.uniform(0.0, 0.3, n))
    deadline = ready + rng.uniform(0.01, 0.4, n)
    lut = np.array([0.0, 0.01, 0.014, 0.017, 0.02])
    max_batch = int(rng.integers(1, 5))
    done, batches, _ = simulate_stage("edf", ready, lut, max_batch, 1,
                                      deadline=deadline)
    assert int(batches.sum()) == n
    # replicas=1: reconstruct each dispatch from its completion time
    for end in np.unique(done):
        members = np.nonzero(done == end)[0]
        start = end - lut[min(len(members), len(lut) - 1)]
        d_max = deadline[members].max()
        waiting = (done > end + 1e-12) & (ready <= start + 1e-12)
        assert (deadline[waiting] >= d_max - 1e-9).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.floats(min_value=0.02, max_value=0.08),
       st.floats(min_value=0.3, max_value=2.0),
       st.integers(min_value=1, max_value=3))
def test_tighter_class_never_worse_than_single_class(seed, tight_slo,
                                                     loose_slo, replicas):
    """Tagging a subset of queries with a TIGHTER deadline must never
    serve that subset worse under EDF than the uniform-deadline run of
    the same trace (where EDF degenerates to arrival order). Holds
    per-query for batch=1 with constant service time (work-conserving,
    equal-service-time exchange argument), hence at p99 too."""
    rng = np.random.default_rng(seed)
    n = 120
    ready = np.sort(rng.uniform(0.0, 0.4, n))
    tight = rng.random(n) < 0.4
    slo_mixed = np.where(tight, tight_slo, loose_slo)
    lut = np.array([0.0, 0.008])          # batch=1, constant service time
    done_mixed, _, _ = simulate_stage("edf", ready, lut, 1, replicas,
                                      deadline=ready + slo_mixed)
    done_uniform, _, _ = simulate_stage("edf", ready, lut, 1, replicas,
                                        deadline=ready + tight_slo)
    assert (done_mixed[tight] <= done_uniform[tight] + 1e-9).all()
    if tight.any():
        lat_mixed = done_mixed[tight] - ready[tight]
        lat_uniform = done_uniform[tight] - ready[tight]
        assert np.percentile(lat_mixed, 99.0) <= \
            np.percentile(lat_uniform, 99.0) + 1e-9
