"""Training substrate: optimizer, data pipeline, checkpointing, trainer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.train import checkpoint
from repro.train.data import SyntheticCorpus, batches
from repro.train.optimizer import AdamW
from repro.train.trainer import Trainer


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, state, grads)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clip():
    opt = AdamW(lr=0.1, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    huge = {"w": jnp.full(3, 1e9)}
    p1, _ = opt.update(params, state, huge)
    # after clipping the step is bounded by lr * O(1)
    assert float(jnp.abs(p1["w"]).max()) < 1.0


def test_adamw_bf16_moments():
    opt = AdamW(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4), jnp.float32)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4, 4))}
    p, s = opt.update(params, state, grads)
    assert p["w"].dtype == jnp.float32
    assert s.nu["w"].dtype == jnp.bfloat16


def test_weight_decay_matrices_only():
    opt = AdamW(lr=0.0, weight_decay=0.5, grad_clip=0.0)
    # lr=0 => no update at all regardless of decay
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones(2)}
    state = opt.init(params)
    p, _ = opt.update(params, state,
                      {"w": jnp.zeros((2, 2)), "b": jnp.zeros(2)})
    np.testing.assert_allclose(p["w"], params["w"])


def test_synthetic_corpus_deterministic():
    c1 = SyntheticCorpus(1000, seed=3)
    c2 = SyntheticCorpus(1000, seed=3)
    r1 = c1.sample(np.random.default_rng(0), 64)
    r2 = c2.sample(np.random.default_rng(0), 64)
    np.testing.assert_array_equal(r1, r2)
    assert r1.max() < 1000


def test_batches_shapes():
    cfg = get_smoke("pixtral-12b")
    it = batches(cfg, batch_size=2, seq_len=32, steps=2)
    b = next(it)
    assert b["tokens"].shape == (2, 32)
    assert b["image_feats"].shape == (2, cfg.num_image_tokens, 1024)
    cfg2 = get_smoke("whisper-small")
    b2 = next(batches(cfg2, 2, 16, steps=1))
    assert b2["frames"].shape == (2, cfg2.encoder_max_frames, 128)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, tree)
    restored = checkpoint.restore(path, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_mismatch_detected(tmp_path):
    path = os.path.join(tmp_path, "c.npz")
    checkpoint.save(path, {"a": jnp.ones(3)})
    with pytest.raises(ValueError, match="mismatch"):
        checkpoint.restore(path, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_trainer_loss_decreases():
    cfg = get_smoke("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    trainer = Trainer(model, opt, log_every=0)
    data = batches(cfg, batch_size=4, seq_len=32, steps=30)
    _, _, losses = trainer.fit(params, data, steps=30)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
