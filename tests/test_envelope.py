"""Traffic envelopes: exact values + hypothesis property tests."""

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core.envelope import (
    IncrementalEnvelope,
    TrafficEnvelope,
    envelope_windows,
    max_queries_in_window,
)


def test_windows_double_up_to_cap():
    w = envelope_windows(0.1, 60.0)
    assert w[0] == pytest.approx(0.1)
    assert w[-1] == pytest.approx(60.0)
    ratios = w[1:-1] / w[:-2]
    assert np.allclose(ratios, 2.0)


def test_max_queries_exact():
    arr = np.array([0.0, 0.1, 0.2, 5.0, 5.01, 5.02, 5.03])
    assert max_queries_in_window(arr, 0.5) == 4    # the 5.0x cluster
    assert max_queries_in_window(arr, 10.0) == 7
    assert max_queries_in_window(arr, 0.05) == 4   # all of [5.0, 5.05)
    assert max_queries_in_window(arr, 0.012) == 2
    assert max_queries_in_window(arr, 0.005) == 1


def test_unsorted_rejected():
    with pytest.raises(ValueError):
        max_queries_in_window(np.array([1.0, 0.5]), 1.0)


def test_envelope_detects_burst_not_rate():
    """Same mean rate, one has a tight burst: only small-window counts
    differ — exactly the §5 motivation."""
    smooth = np.arange(0, 60, 0.1)                      # 10 qps uniform
    bursty = np.concatenate([np.arange(0, 30, 0.1),
                             30.0 + np.arange(100) * 1e-3,
                             np.arange(31, 50.9, 0.1)])  # same total-ish
    ts = 0.05
    e_s = TrafficEnvelope.from_trace(smooth, ts)
    e_b = TrafficEnvelope.from_trace(bursty, ts)
    exceeded, r_max = e_s.exceeded_by(e_b)
    assert exceeded
    assert r_max > 100  # the burst rate, far above the 10 qps mean


def test_exceeded_by_self_is_false():
    arr = np.sort(np.random.default_rng(0).uniform(0, 60, 500))
    env = TrafficEnvelope.from_trace(arr, 0.05)
    exceeded, r = env.exceeded_by(env)
    assert not exceeded and r == 0.0


def test_window_mismatch_raises():
    arr = np.arange(0, 10, 0.1)
    e1 = TrafficEnvelope.from_trace(arr, 0.05)
    e2 = TrafficEnvelope.from_trace(arr, 0.07)
    with pytest.raises(ValueError):
        e1.exceeded_by(e2)


# ---------------------------------------------------------------- properties

arrivals_strategy = st.lists(
    st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
    min_size=1, max_size=300,
).map(lambda xs: np.sort(np.asarray(xs)))


@given(arrivals_strategy, st.floats(min_value=1e-3, max_value=50.0))
@settings(max_examples=60, deadline=None)
def test_count_monotone_in_window(arr, w):
    """Envelope counts are nondecreasing in window width."""
    c1 = max_queries_in_window(arr, w)
    c2 = max_queries_in_window(arr, 2 * w)
    assert c2 >= c1


@given(arrivals_strategy, st.floats(min_value=1e-3, max_value=25.0),
       st.floats(min_value=1e-3, max_value=25.0))
@settings(max_examples=60, deadline=None)
def test_count_subadditive(arr, w1, w2):
    """Network-calculus sub-additivity: q(w1+w2) <= q(w1) + q(w2)."""
    assert max_queries_in_window(arr, w1 + w2) <= \
        max_queries_in_window(arr, w1) + max_queries_in_window(arr, w2)


@given(arrivals_strategy, st.floats(min_value=1e-3, max_value=50.0))
@settings(max_examples=60, deadline=None)
def test_count_bounds(arr, w):
    c = max_queries_in_window(arr, w)
    assert 1 <= c <= arr.size


@given(arrivals_strategy)
@settings(max_examples=40, deadline=None)
def test_superset_trace_never_smaller(arr):
    """Adding arrivals can only raise (or keep) every envelope count."""
    env = TrafficEnvelope.from_trace(arr, 0.05)
    extra = np.sort(np.concatenate([arr, arr + 0.01]))
    env2 = TrafficEnvelope.from_trace(extra, 0.05)
    assert np.all(env2.max_counts >= env.max_counts)


# ------------------------------------------------- incremental envelope

incr_chunks_strategy = st.lists(
    st.lists(st.floats(min_value=0.0, max_value=10.0, allow_nan=False),
             min_size=0, max_size=40),
    min_size=1, max_size=8,
)


@given(incr_chunks_strategy, st.floats(min_value=5e-3, max_value=1.0))
@settings(max_examples=40, deadline=None)
def test_incremental_envelope_matches_from_trace(chunks, ts):
    """The streaming envelope equals the batch recomputation after every
    extend() — the closed-loop telemetry's per-epoch contract."""
    inc = IncrementalEnvelope(ts)
    seen = np.zeros(0)
    t_base = 0.0
    for chunk in chunks:
        new = t_base + np.sort(np.asarray(chunk, dtype=np.float64))
        t_base = float(new[-1]) if new.size else t_base
        seen = np.concatenate([seen, new])
        inc.extend(new)
        batch = TrafficEnvelope.from_trace(seen, ts)
        np.testing.assert_array_equal(inc.snapshot().max_counts,
                                      batch.max_counts)
        np.testing.assert_array_equal(inc.snapshot().windows, batch.windows)


def test_incremental_envelope_rejects_out_of_order():
    inc = IncrementalEnvelope(0.05)
    inc.extend(np.array([1.0, 2.0]))
    with pytest.raises(ValueError, match="extend the observed prefix"):
        inc.extend(np.array([0.5]))
    # unsorted WITHIN a chunk would silently corrupt the searchsorted
    # counts (a 2-arrival chunk must not report a 2-count tiny window)
    with pytest.raises(ValueError, match="sorted"):
        IncrementalEnvelope(0.05).extend(np.array([2.0, 1.0]))


def test_incremental_envelope_empty_extends_are_noops():
    inc = IncrementalEnvelope(0.05)
    inc.extend(np.zeros(0))
    assert inc.n == 0 and np.all(inc.snapshot().max_counts == 0)
    inc.extend(np.array([1.0]))
    counts = inc.snapshot().max_counts.copy()
    inc.extend(np.zeros(0))
    np.testing.assert_array_equal(inc.snapshot().max_counts, counts)
