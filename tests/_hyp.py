"""Hypothesis compatibility shim (tier-1 satellite fix).

The property tests hard-imported ``hypothesis``, which is an *optional*
dependency (see pyproject ``[project.optional-dependencies]``) — on
environments without it the whole suite died at collection. Import
``given``/``settings``/``st`` from here instead:

* with hypothesis installed, this module is a pure re-export;
* without it, a tiny deterministic fallback runs each property on
  ``max_examples`` (capped) seeded pseudo-random draws. No shrinking, no
  adaptive search — but the invariants still get exercised instead of
  the suite failing to collect.

Only the strategy surface the suite actually uses is emulated:
``st.floats``, ``st.integers``, ``st.lists`` and ``.map``.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _FALLBACK_EXAMPLES_CAP = 20
    _FALLBACK_SEED = 0x1FE12

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False, **_kw):
            del _kw
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value=0, max_value=10):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(size)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples=None, **_kw):
        """Records max_examples on the test for @given to pick up."""
        del _kw  # deadline etc. have no fallback equivalent

        def deco(fn):
            if max_examples is not None:
                fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            def wrapper():
                # resolve max_examples at call time so @settings works in
                # either decorator order (hypothesis accepts both)
                n = min(getattr(wrapper, "_hyp_max_examples",
                                getattr(fn, "_hyp_max_examples",
                                        _FALLBACK_EXAMPLES_CAP)),
                        _FALLBACK_EXAMPLES_CAP)
                rng = np.random.default_rng(_FALLBACK_SEED)
                for _ in range(n):
                    fn(*(s.example(rng) for s in strategies),
                       **{k: s.example(rng)
                          for k, s in kw_strategies.items()})
            # no functools.wraps: pytest must see a zero-arg signature,
            # not the strategy parameters (it would demand fixtures)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
