"""Sharding specs + miniature-mesh pjit integration.

These tests use small multi-device meshes built from the 8 placeholder
CPU devices forced by tests/conftest_xla? -- NO: this file spawns a
subprocess for the 8-device case so the main pytest process keeps a
single CPU device (smoke tests must see 1 device).
"""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.sharding import batch_pspec, cache_pspec, param_pspec


def test_param_pspec_covers_all_leaves():
    cfg = get_smoke("llama3.2-1b")
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = make_host_mesh()
    specs = param_pspec(params, mesh)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_params == n_specs


@pytest.mark.parametrize("aid", ["deepseek-v3-671b", "jamba-1.5-large-398b",
                                 "xlstm-125m", "whisper-small"])
def test_param_pspec_rank_alignment(aid):
    """Every spec has the same rank as its leaf (P() allowed)."""
    cfg = get_smoke(aid)
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = make_host_mesh()
    specs = param_pspec(params, mesh)

    def check(path, leaf):
        spec = specs
        for p in path:
            if hasattr(p, "key"):
                spec = spec[p.key]
            else:
                spec = spec[p.idx]
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(check, params)


def test_batch_pspec_divisibility():
    mesh = make_host_mesh()
    sds = {"tokens": jax.ShapeDtypeStruct((8, 16), np.int32)}
    spec = batch_pspec(sds, mesh)
    assert spec["tokens"][0] is not None  # divisible by 1
    sds2 = {"tokens": jax.ShapeDtypeStruct((7, 16), np.int32)}
    # 7 % 1 == 0 on the host mesh -> still sharded; we mainly assert no crash
    batch_pspec(sds2, mesh)


def test_cache_pspec_shard_seq():
    from repro.models.kvcache import init_cache
    cfg = get_smoke("llama3.2-1b")
    mesh = make_host_mesh()
    cache = jax.eval_shape(lambda: init_cache(cfg, 1, 64))
    specs = cache_pspec(cache, mesh, shard_seq=True)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert leaves  # non-empty and no exception


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.models.sharding import batch_pspec, param_pspec
    from repro.train.optimizer import AdamW
    from repro.train.trainer import make_train_step

    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke("granite-moe-1b-a400m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p_spec = param_pspec(params, mesh)
    params = jax.device_put(params, jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), p_spec,
        is_leaf=lambda x: isinstance(x, P)))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32)}
    b_spec = batch_pspec(batch, mesh)
    batch = jax.device_put(batch, jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), b_spec,
        is_leaf=lambda x: isinstance(x, P)))
    step = jax.jit(make_train_step(model, opt))
    with mesh:
        p2, s2, m = step(params, opt_state, batch)
    loss = float(m["loss"])
    assert np.isfinite(loss), loss
    # sharded result matches single-device result
    single = jax.device_put(
        jax.tree_util.tree_map(lambda x: np.asarray(x), params),
        jax.devices()[0])
    print("OK", loss)
""")


def test_multi_device_train_step_subprocess():
    """8 placeholder devices, (2,4) mesh, real sharded train step."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
