"""Live-cluster simulation + frontends + estimator fidelity (Fig. 8/13)."""

import numpy as np
import pytest

from repro.core.estimator import Estimator
from repro.core.planner import Planner
from repro.core.tuner import Tuner, TunerPlanInfo, run_tuner_offline
from repro.serving.cluster import LiveClusterSim
from repro.serving.frontends import FRONTENDS
from repro.workload.generator import gamma_trace

SLO = 0.15


def test_cost_timeline_integrates(image_pipeline):
    pipe, store = image_pipeline
    sample = gamma_trace(100, 1.0, 60, seed=0)
    res = Planner(pipe, store).plan(sample, SLO)
    sim = LiveClusterSim(pipe, store, res.config, SLO)
    run = sim.run(sample)
    # static config: cost == config cost for the whole run
    assert run.mean_cost_per_hr() == pytest.approx(
        res.config.cost_per_hr(), rel=1e-6)
    expected_total = res.config.cost_per_hr() * sample.max() / 3600.0
    assert run.total_cost() == pytest.approx(expected_total, rel=1e-6)


def test_tuned_run_cost_reflects_scaling(image_pipeline):
    pipe, store = image_pipeline
    sample = gamma_trace(100, 1.0, 60, seed=0)
    res = Planner(pipe, store).plan(sample, SLO)
    est = Estimator(pipe, store)
    info = TunerPlanInfo.from_plan(pipe, res.config, store, sample,
                                   est.service_time(res.config))
    # double the traffic: tuner scales up => mean cost above static
    heavy = gamma_trace(220, 1.0, 120, seed=1)
    sim = LiveClusterSim(pipe, store, res.config, SLO)
    tuned = sim.run(heavy, schedule_fn=lambda arr: run_tuner_offline(
        Tuner(info), arr))
    static = sim.run(heavy)
    assert tuned.mean_cost_per_hr() > static.mean_cost_per_hr()
    assert tuned.miss_rate < static.miss_rate


def test_estimator_fidelity_p99_close_to_replay(image_pipeline):
    """Fig. 8 analogue: the planning-time estimate on the sample trace is
    close to the 'measured' replay on an independent same-law trace."""
    pipe, store = image_pipeline
    sample = gamma_trace(150, 4.0, 60, seed=2)
    res = Planner(pipe, store).plan(sample, SLO)
    est = Estimator(pipe, store)
    replay = gamma_trace(150, 4.0, 60, seed=77)
    p99_est = res.estimated_p99
    p99_meas = est.simulate(res.config, replay).p99
    assert p99_meas <= SLO * 1.3
    assert abs(p99_meas - p99_est) < 0.5 * SLO


def test_frontend_overheads_ordered(image_pipeline):
    """Fig. 13: TFS-style serialization raises cost/latency vs Clipper."""
    pipe, store = image_pipeline
    sample = gamma_trace(100, 1.0, 60, seed=3)
    lat = {}
    for name, fe in FRONTENDS.items():
        est = Estimator(pipe, store, rpc_delay_s=fe.hop_delay_s)
        res = Planner(pipe, store, estimator=est).plan(sample, SLO)
        assert res.feasible
        lat[name] = res.estimated_p99
    assert lat["tfs"] > lat["clipper"]


def test_planner_on_both_frontends_meets_slo(image_pipeline):
    pipe, store = image_pipeline
    sample = gamma_trace(100, 1.0, 60, seed=4)
    for name, fe in FRONTENDS.items():
        est = Estimator(pipe, store, rpc_delay_s=fe.hop_delay_s)
        res = Planner(pipe, store, estimator=est).plan(sample, SLO)
        sim = LiveClusterSim(pipe, store, res.config, SLO, frontend=fe)
        run = sim.run(gamma_trace(100, 1.0, 60, seed=5))
        assert run.miss_rate < 0.02, name
