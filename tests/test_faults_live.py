"""Live fault injection: crashes, stragglers, retries on real threads.

Time-budgeted chaos lane (tier-1, run alongside the live-executor
smoke): the same :class:`~repro.faults.FaultSchedule` vocabulary the
simulator consumes drives the real :class:`~repro.serving.executor
.PipelineExecutor` — injected crashes kill actual worker threads at
scheduled instants (cleanly: they must NOT trip the real-bug
``worker_failures`` registry), stragglers stretch observed service
time, and transient errors exercise the bounded-retry + hedging
recovery path. Also here: the AND-join regression (a diamond pipeline
delivers exactly once per request, with and without conditional
branches) and the closed-loop driver's epoch-boundary worker-failure
polling.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import (
    SOURCE,
    Edge,
    Pipeline,
    PipelineConfig,
    Stage,
    StageConfig,
    linear_pipeline,
)
from repro.faults import FaultSchedule, RecoveryPolicy, crash, straggle, transient
from repro.serving.executor import PipelineExecutor, _Request
from repro.serving.loop import LiveControlLoop
from repro.sim import ScheduleController


def _sleep_fn(per_batch_s):
    def fn(payloads):
        time.sleep(per_batch_s)
        return list(payloads)
    return fn


def _linear(n_stages=1, batch=4, replicas=1, **kw):
    names = [f"m{i}" for i in range(n_stages)]
    pipe = linear_pipeline("t", names, {n: ["cpu-1"] for n in names})
    cfg = PipelineConfig({
        s: StageConfig("cpu-1", batch, replicas, **kw)
        for s in pipe.stages})
    return pipe, cfg


def _diamond(prob_c=1.0):
    """a -> (b, c) -> d; the c branch optionally conditional."""
    stages = {n: Stage(n, n, ("cpu-1",)) for n in "abcd"}
    edges = [Edge(SOURCE, "a"), Edge("a", "b"),
             Edge("a", "c", probability=prob_c),
             Edge("b", "d"), Edge("c", "d")]
    pipe = Pipeline("diamond", stages, edges)
    cfg = PipelineConfig({
        s: StageConfig("cpu-1", 4, 1) for s in stages})
    fns = {n: _sleep_fn(0.002) for n in "abcd"}
    return pipe, cfg, fns


# -- AND-join regression (satellite 1) ---------------------------------------


def test_diamond_and_join_exactly_once():
    """The join stage d must serve each request exactly once, after BOTH
    parents delivered — not twice (the pre-fix behavior: each parent
    enqueued independently)."""
    pipe, cfg, fns = _diamond()
    ex = PipelineExecutor(pipe, cfg, fns)
    done_rids = []
    done_lock = threading.Lock()

    def on_done(req):
        with done_lock:
            done_rids.append(req.rid)

    ex.on_request_done = on_done
    lat = ex.serve_trace(np.linspace(0.0, 0.3, 30), lambda i: i,
                         timeout_s=20.0)
    assert np.isfinite(lat).all(), lat
    assert sorted(done_rids) == list(range(30))      # exactly once each
    # the join stage saw each request once, not once per parent
    with ex._stages["d"].cond:
        assert ex._stages["d"].arrived == 30
    assert ex.shutdown()


def test_diamond_conditional_branch_anti_tokens():
    """With a 0.5-probability branch the join must still fire exactly
    once per request whose other parent delivered: the non-activated
    branch sends an anti-token instead of leaving the barrier hanging."""
    pipe, cfg, fns = _diamond(prob_c=0.5)
    ex = PipelineExecutor(pipe, cfg, fns)
    done_rids = []
    done_lock = threading.Lock()

    def on_done(req):
        with done_lock:
            done_rids.append(req.rid)

    ex.on_request_done = on_done
    lat = ex.serve_trace(np.linspace(0.0, 0.3, 30), lambda i: i,
                         timeout_s=20.0)
    assert np.isfinite(lat).all(), lat
    assert sorted(done_rids) == list(range(30))
    with ex._stages["c"].cond:
        c_arrived = ex._stages["c"].arrived
    assert 0 < c_arrived < 30            # the coin actually flipped
    assert ex.shutdown()


# -- injected crashes --------------------------------------------------------


def test_crash_kills_thread_and_requeues_in_flight():
    """A scheduled crash takes a real worker down (clean exit: nothing
    in worker_failures) and its in-flight batch is requeued, so every
    request still finishes on the survivor."""
    pipe, cfg = _linear(replicas=2, batch=2)
    fs = FaultSchedule([crash("s0_m0", 0.08)], seed=0)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.05)}, faults=fs)
    lat = ex.serve_trace(np.linspace(0.0, 0.4, 16), lambda i: i,
                         timeout_s=20.0)
    assert np.isfinite(lat).all(), lat   # serve_trace raises on failures
    assert ex.replica_target("s0_m0") == 1
    deadline = time.time() + 3.0
    while ex.live_worker_count("s0_m0") > 1 and time.time() < deadline:
        time.sleep(0.02)
    assert ex.live_worker_count("s0_m0") == 1
    deltas = ex.fault_deltas()["s0_m0"]
    assert len(deltas) == 1 and deltas[0][1] == -1
    assert ex.shutdown()


def test_crash_then_control_replacement():
    """The recovery story end to end on real threads: a crash halves
    the fleet; a replacement `up` control event restores it."""
    pipe, cfg = _linear(replicas=2, batch=2)
    fs = FaultSchedule([crash("s0_m0", 0.05)], seed=0)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.01)}, faults=fs)
    ex.start_run()
    time.sleep(0.15)                     # let the driver land the crash
    assert ex.replica_target("s0_m0") == 1
    ex.add_replicas("s0_m0", 1, t_active=ex.now())
    assert ex.replica_target("s0_m0") == 2
    deadline = time.time() + 3.0
    while ex.live_worker_count("s0_m0") < 2 and time.time() < deadline:
        time.sleep(0.02)
    assert ex.live_worker_count("s0_m0") == 2
    assert ex.shutdown()


def test_straggle_stretches_observed_latency():
    pipe, cfg = _linear(replicas=1, batch=1)
    base_ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.005)})
    base = base_ex.serve_trace(np.linspace(0.0, 0.3, 10), lambda i: i,
                               timeout_s=10.0)
    assert base_ex.shutdown()
    fs = FaultSchedule([straggle("s0_m0", 0.0, 10.0, 5.0)], seed=0)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.005)}, faults=fs)
    slow = ex.serve_trace(np.linspace(0.0, 0.3, 10), lambda i: i,
                          timeout_s=10.0)
    assert ex.shutdown()
    assert np.isfinite(slow).all()
    assert slow.mean() > base.mean() * 2.0


# -- transient errors + recovery ---------------------------------------------


def test_transient_errors_retried_to_completion():
    """An error window that closes: every request eventually lands."""
    pipe, cfg = _linear(replicas=1, batch=4)
    fs = FaultSchedule(
        [transient("s0_m0", 0.0, 0.15, 1.0)], seed=3,
        recovery=RecoveryPolicy(max_attempts=10, backoff_s=0.05,
                                backoff_mult=2.0))
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.002)}, faults=fs)
    lat = ex.serve_trace(np.linspace(0.0, 0.1, 8), lambda i: i,
                         timeout_s=20.0)
    assert np.isfinite(lat).all(), lat
    assert ex.shutdown()


def test_retries_exhausted_reports_inf_and_run_completes():
    """p=1.0 forever: bounded retries give up, requests report inf, and
    the run terminates promptly instead of spinning."""
    pipe, cfg = _linear(replicas=1, batch=4)
    fs = FaultSchedule(
        [transient("s0_m0", 0.0, 1e9, 1.0)], seed=3,
        recovery=RecoveryPolicy(max_attempts=2, backoff_s=0.01))
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.002)}, faults=fs)
    t0 = time.time()
    lat = ex.serve_trace(np.linspace(0.0, 0.1, 8), lambda i: i,
                         timeout_s=10.0)
    assert time.time() - t0 < 8.0
    assert np.isinf(lat).all()
    assert ex.shutdown()


def test_exactly_once_under_errors_and_hedging():
    """Property (a): with transient errors AND hedged duplicates armed,
    each request is delivered at most once (resolve-once dedup) and the
    completion callback fires exactly once per finished request."""
    pipe, cfg = _linear(n_stages=2, replicas=2, batch=2)
    fs = FaultSchedule(
        [transient("s0_m0", 0.0, 0.2, 0.6)], seed=5,
        recovery=RecoveryPolicy(max_attempts=6, backoff_s=0.02,
                                backoff_mult=1.5, hedge_slack_s=0.4))
    ex = PipelineExecutor(pipe, cfg,
                          {"m0": _sleep_fn(0.004), "m1": _sleep_fn(0.004)},
                          faults=fs)
    done_rids = []
    done_lock = threading.Lock()

    def on_done(req):
        with done_lock:
            done_rids.append(req.rid)

    ex.on_request_done = on_done
    lat = ex.serve_trace(np.linspace(0.0, 0.4, 40), lambda i: i,
                         timeout_s=20.0, slo_s=0.5)
    assert len(done_rids) == len(set(done_rids)), "duplicate delivery"
    finished = sorted(r for r, l in zip(range(40), lat)
                      if np.isfinite(l))
    assert set(finished) <= set(done_rids)
    assert ex.shutdown()


# -- closed-loop failure polling (satellite 2) -------------------------------


def test_loop_surfaces_worker_failure_at_epoch_boundary():
    """A real worker crash (uncaught exception) recorded mid-run must
    fail the loop at the NEXT epoch boundary, not at drain time."""
    pipe, cfg = _linear(replicas=1, batch=2)
    ex = PipelineExecutor(pipe, cfg, {"m0": _sleep_fn(0.002)})
    loop = LiveControlLoop(ex, slo=0.5, epoch_s=0.25, drain_timeout_s=30.0)

    def sabotage():
        time.sleep(0.3)
        ex._note_worker_failure("s0_m0", RuntimeError("worker died"))

    threading.Thread(target=sabotage, daemon=True).start()
    trace = np.linspace(0.0, 6.0, 60)
    t0 = time.time()
    with pytest.raises(RuntimeError, match="worker thread"):
        loop.run(trace, ScheduleController([]), lambda i: i)
    # caught at an epoch boundary (~0.5 s), far before the 6 s trace
    # ends or the 30 s drain budget is spent
    assert time.time() - t0 < 4.0
    assert ex.shutdown()
