"""Pallas kernels: interpret=True sweeps vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 512, 4, 1, 128),    # MQA, larger d
    (2, 128, 2, 2, 32),     # small head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_sweep(b, s, h, kv, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, (b, s, h, d), dtype)
    k = _rand(k2, (b, s, kv, d), dtype)
    v = _rand(k3, (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               **TOL[dtype])


def test_flash_non_causal():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(k1, (2, 128, 4, 64), jnp.float32)
    k = _rand(k2, (2, 256, 4, 64), jnp.float32)
    v = _rand(k3, (2, 256, 4, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_flash_cross_lengths_causal_offset():
    """Sq < Sk: causal diagonal offset (chunked prefill pattern)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(k1, (1, 128, 4, 64), jnp.float32)
    k = _rand(k2, (1, 384, 4, 64), jnp.float32)
    v = _rand(k3, (1, 384, 4, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [64, 128, 200])
def test_flash_sliding_window(window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(k1, (1, 256, 4, 64), jnp.float32)
    k = _rand(k2, (1, 256, 2, 64), jnp.float32)
    v = _rand(k3, (1, 256, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_flash_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(k1, (1, 256, 2, 64), jnp.float32)
    k = _rand(k2, (1, 256, 2, 64), jnp.float32)
    v = _rand(k3, (1, 256, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=64, block_k=256, interpret=True)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_flash_rejects_ragged():
    q = jnp.zeros((1, 100, 2, 64))
    k = jnp.zeros((1, 100, 2, 64))
    with pytest.raises(ValueError):
        flash_attention(q, k, k, block_q=64, interpret=True)


# ------------------------------------------------------------ decode attention

@pytest.mark.parametrize("b,smax,h,kv,d", [
    (1, 512, 4, 4, 64),
    (2, 1024, 8, 2, 64),
    (4, 512, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_sweep(b, smax, h, kv, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(k1, (b, 1, h, d), dtype)
    k = _rand(k2, (b, smax, kv, d), dtype)
    v = _rand(k3, (b, smax, kv, d), dtype)
    vl = smax // 2 + 17
    out = decode_attention(q, k, v, vl, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, vl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("vl", [1, 511, 512])
def test_decode_valid_len_edges(vl):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(6), 3)
    q = _rand(k1, (1, 1, 4, 64), jnp.float32)
    k = _rand(k2, (1, 512, 2, 64), jnp.float32)
    v = _rand(k3, (1, 512, 2, 64), jnp.float32)
    out = decode_attention(q, k, v, vl, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, vl)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_decode_sliding_window():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(k1, (2, 1, 4, 64), jnp.float32)
    k = _rand(k2, (2, 512, 2, 64), jnp.float32)
    v = _rand(k3, (2, 512, 2, 64), jnp.float32)
    out = decode_attention(q, k, v, 400, window=128, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, 400, window=128)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(4, 512), (2, 16, 256), (1, 128),
                                   (3, 5, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    x = _rand(k1, shape, dtype)
    g = _rand(k2, shape[-1:], dtype)
    out = rmsnorm(x, g, interpret=True)
    exp = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               **TOL[dtype])


def test_rmsnorm_ragged_rows():
    """Row counts not divisible by the block fall back to row-at-a-time."""
    x = jax.random.normal(jax.random.PRNGKey(9), (7, 320))
    g = jnp.ones((320,))
    out = rmsnorm(x, g, block_rows=4, interpret=True)
    np.testing.assert_allclose(out, ref.rmsnorm_ref(x, g), atol=2e-5,
                               rtol=2e-5)


# ----------------------------------------------------------------- ops dispatch

def test_ops_dispatch_cpu_uses_ref(monkeypatch):
    from repro.kernels import ops
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 2, 32))
    out = ops.attention(q, q, q, None, jnp.float32, kind="causal")
    assert out.shape == q.shape


def test_ops_force_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    from repro.kernels import ops
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 2, 64))
    got = ops.attention(q, q, q, None, jnp.float32, kind="causal")
    exp = ref.flash_attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(got, exp, atol=2e-5, rtol=2e-5)


# ------------------------------------------------------- xla_flash (+ VJP)

from repro.kernels.xla_flash import flash_attention_xla  # noqa: E402


@pytest.mark.parametrize("sq,sk,h,kv,causal,window", [
    (256, 256, 4, 4, True, 0),
    (128, 384, 4, 2, True, 0),
    (256, 256, 4, 1, False, 0),
    (256, 256, 8, 2, True, 64),
    (100, 200, 4, 2, True, 0),      # ragged -> padded path
])
def test_xla_flash_forward_and_grads(sq, sk, h, kv, causal, window):
    """Forward vs oracle AND custom-VJP gradients vs oracle autodiff."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (2, sq, h, 64))
    k = jax.random.normal(ks[1], (2, sk, kv, 64))
    v = jax.random.normal(ks[2], (2, sk, kv, 64))
    do = jax.random.normal(ks[3], (2, sq, h, 64))

    out = flash_attention_xla(q, k, v, causal=causal, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)

    def f_flash(q, k, v):
        return (flash_attention_xla(q, k, v, causal=causal,
                                    window=window) * do).sum()

    def f_ref(q, k, v):
        return (ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window) * do).sum()

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)


def test_xla_flash_matches_pallas_interpret():
    """Both flash implementations agree with each other."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    a = flash_attention_xla(q, k, v, causal=True)
    b = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------- mamba scan

from repro.kernels.mamba_scan import mamba_scan  # noqa: E402


@pytest.mark.slow   # interpret-mode fori_loop over full sequences: ~3 min
@pytest.mark.parametrize("b,s,d,n,chunk,dblk", [
    (2, 512, 256, 16, 128, 128),
    (1, 256, 128, 32, 256, 128),    # single chunk
    (3, 384, 192, 16, 128, 192),    # non-pow2 batch/dims
    (2, 128, 256, 8, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_sweep(b, s, d, n, chunk, dblk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    dt = jax.nn.softplus(_rand(ks[0], (b, s, d), dtype) * 0.3)
    x = _rand(ks[1], (b, s, d), dtype)
    bm = _rand(ks[2], (b, s, n), dtype) * 0.5
    cm = _rand(ks[3], (b, s, n), dtype) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    h0 = jax.random.normal(ks[5], (b, d, n), jnp.float32) * 0.1
    y, h = mamba_scan(dt, x, bm, cm, a, h0, chunk=chunk, d_block=dblk,
                      interpret=True)
    ye, he = ref.mamba_scan_ref(dt, x, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **TOL[dtype])
    np.testing.assert_allclose(h, he, atol=5e-5, rtol=5e-5)


def test_mamba_state_carry_across_calls():
    """Two half-sequence kernel calls chained == one full-sequence call."""
    ks = jax.random.split(jax.random.PRNGKey(12), 6)
    b, s, d, n = 1, 256, 128, 16
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)) * 0.3)
    x = jax.random.normal(ks[1], (b, s, d))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    h0 = jnp.zeros((b, d, n), jnp.float32)
    y_full, h_full = mamba_scan(dt, x, bm, cm, a, h0, chunk=128,
                                interpret=True)
    half = s // 2
    y1, h1 = mamba_scan(dt[:, :half], x[:, :half], bm[:, :half],
                        cm[:, :half], a, h0, chunk=128, interpret=True)
    y2, h2 = mamba_scan(dt[:, half:], x[:, half:], bm[:, half:],
                        cm[:, half:], a, h1, chunk=128, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), y_full,
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(h2, h_full, atol=2e-5, rtol=2e-5)


def test_ops_mamba_chunk_interpret_matches_xla(monkeypatch):
    """ops dispatch: forced-interpret kernel path == associative-scan path."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(13), 6)
    b, s, d, n = 2, 128, 64, 16
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)) * 0.3)
    x = jax.random.normal(ks[1], (b, s, d))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    h0 = jnp.zeros((b, d, n), jnp.float32)
    y_xla, h_xla = ops.mamba_chunk(dt, x, bm, cm, a, h0)
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    y_k, h_k = ops.mamba_chunk(dt, x, bm, cm, a, h0)
    np.testing.assert_allclose(y_k, y_xla, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(h_k, h_xla, atol=2e-5, rtol=2e-5)
