"""Fault vocabulary + failure-aware simulation (tier-1, sim-only).

The fault subsystem's contract, pinned as properties (``tests/_hyp``):

* **replay determinism** — the same seed and fault schedule through a
  fresh engine is bit-identical (property (c) of the fault issue);
* **identity** — ``faults=None``, an *empty* ``FaultSchedule`` and the
  omitted argument are all bit-identical to the fault-free golden path
  (property (d)): shipping the subsystem must not perturb a single
  existing output;
* **bounded retry** — retries never exceed ``max_attempts`` and the
  backoff sequence is monotone non-decreasing (property (b));
* **cone-key hygiene** — a session that simulated under faults must
  still return bit-identical fault-free results afterwards (the KEY01
  ``_fault_key`` dimension, exercised dynamically);
* **recovery loop** — the ClosedLoopTuner replaces crashed capacity
  through the ordinary ControlEvent path, and ``failure_recovery=False``
  switches that off;
* **planner headroom** — ``failure_headroom=f`` yields a plan that
  stays feasible after losing ``f`` replicas from any single stage.

The live-thread half lives in ``tests/test_faults_live.py``.
"""

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core.estimator import Estimator
from repro.core.pipeline import PipelineConfig, StageConfig, linear_pipeline
from repro.core.planner import Planner
from repro.core.profiler import ModelProfile, ProfileStore
from repro.core.tuner import ClosedLoopTuner, TunerPlanInfo
from repro.faults import (
    Fault,
    FaultSchedule,
    RecoveryPolicy,
    crash,
    straggle,
    transient,
)
from repro.sim import ControlLoopSession, SimEngine
from repro.workload.generator import gamma_trace

HW = "cpu-1"
SLO = 0.15


def _pipeline(n_stages=2, base=0.004, slope=0.001):
    names = [f"m{i}" for i in range(n_stages)]
    pipe = linear_pipeline("f", names, {n: [HW] for n in names})
    store = ProfileStore()
    for i, nm in enumerate(names):
        table = {(HW, b): base * (1 + 0.3 * i) + slope * b
                 for b in (1, 2, 4, 8, 16, 32)}
        store.add(ModelProfile(nm, table, (1, 2, 4, 8, 16, 32)))
    return pipe, store


def _config(pipe, batch=4, replicas=2, **kw):
    return PipelineConfig({
        s: StageConfig(HW, batch, replicas, **kw) for s in pipe.stages})


# -- vocabulary --------------------------------------------------------------


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault("melt", "s0_m0", 0.0, 1.0, 1.0)          # unknown kind
    with pytest.raises(ValueError):
        crash("s0_m0", -1.0)                           # negative time
    with pytest.raises(ValueError):
        Fault("crash", "s0_m0", 1.0, 2.0, 1.0)         # crash is a point
    with pytest.raises(ValueError):
        straggle("s0_m0", 2.0, 1.0, 3.0)               # inverted window
    with pytest.raises(ValueError):
        transient("s0_m0", 0.0, 1.0, 1.5)              # p out of [0, 1]
    with pytest.raises(ValueError):
        straggle("s0_m0", 0.0, 1.0, 0.5)               # speedup, not a fault


def test_schedule_key_folds_every_component():
    """Two schedules differing in any one component must key apart —
    the dynamic twin of the KEY01 ``_fault_key`` registry entry."""
    base = [crash("a", 1.0), straggle("a", 2.0, 3.0, 4.0),
            transient("b", 0.5, 1.5, 0.25)]
    k0 = FaultSchedule(base, seed=7).key()
    assert FaultSchedule(base, seed=7).key() == k0          # deterministic
    variants = [
        FaultSchedule(base, seed=8),                        # seed
        FaultSchedule(base[:-1], seed=7),                   # event set
        FaultSchedule([crash("a", 1.5)] + base[1:], seed=7),  # t0
        FaultSchedule([base[0], straggle("a", 2.0, 3.5, 4.0),
                       base[2]], seed=7),                   # t1
        FaultSchedule([base[0], base[1],
                       transient("b", 0.5, 1.5, 0.5)], seed=7),  # value
        FaultSchedule(base, seed=7,
                      recovery=RecoveryPolicy(max_attempts=5)),  # recovery
    ]
    assert len({v.key() for v in variants} | {k0}) == len(variants) + 1
    assert not FaultSchedule([])
    assert FaultSchedule(base)


def test_backoff_monotone_and_bounded():
    """Property (b): the backoff sequence is monotone non-decreasing
    and a request is attempted at most max_attempts times."""
    rec = RecoveryPolicy(max_attempts=4, backoff_s=0.01, backoff_mult=2.0)
    seq = [rec.backoff(i) for i in range(1, rec.max_attempts + 1)]
    assert all(b >= 0.0 for b in seq)
    assert all(b2 >= b1 for b1, b2 in zip(seq, seq[1:]))

    # p=1.0 transient: every attempt fails, so every query must be
    # dropped after exactly bounded retries — never an infinite loop
    pipe, store = _pipeline(1)
    cfg = _config(pipe)
    arr = gamma_trace(50.0, 1.0, 2.0, seed=3)
    fs = FaultSchedule([transient("s0_m0", 0.0, 1e9, 1.0)], seed=1,
                       recovery=rec)
    res = SimEngine(pipe, store, seed=0).simulate(cfg, arr, slo_s=SLO,
                                                  fault_schedules=fs)
    assert res.dropped is not None and res.dropped.all()
    assert np.isinf(res.latency).all()


# -- identity + determinism --------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**16))
def test_no_fault_schedule_is_identity(seed):
    """Property (d): None, omitted, and an EMPTY FaultSchedule are all
    bit-identical — the subsystem is invisible until armed."""
    pipe, store = _pipeline(2)
    cfg = _config(pipe)
    arr = gamma_trace(80.0, 1.0, 3.0, seed=seed)
    eng = SimEngine(pipe, store, seed=0)
    base = eng.simulate(cfg, arr, slo_s=SLO)
    omitted = SimEngine(pipe, store, seed=0).simulate(cfg, arr, slo_s=SLO,
                                                      fault_schedules=None)
    empty = SimEngine(pipe, store, seed=0).simulate(
        cfg, arr, slo_s=SLO, fault_schedules=FaultSchedule([]))
    np.testing.assert_array_equal(base.latency, omitted.latency)
    np.testing.assert_array_equal(base.latency, empty.latency)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**16),
       st.floats(min_value=0.05, max_value=0.95))
def test_same_seed_fault_replay_bit_identical(seed, p_err):
    """Property (c): the full fault mix under one seed replays bit-
    identically through a fresh engine."""
    pipe, store = _pipeline(2)
    cfg = _config(pipe)
    arr = gamma_trace(100.0, 1.0, 4.0, seed=seed)
    fs = FaultSchedule(
        [crash("s0_m0", 1.0),
         straggle("s1_m1", 0.5, 2.5, 3.0),
         transient("s0_m0", 0.0, 4.0, p_err)],
        seed=seed, recovery=RecoveryPolicy(max_attempts=3, backoff_s=0.01))
    r1 = SimEngine(pipe, store, seed=0).simulate(cfg, arr, slo_s=SLO,
                                                 fault_schedules=fs)
    r2 = SimEngine(pipe, store, seed=0).simulate(cfg, arr, slo_s=SLO,
                                                 fault_schedules=fs)
    np.testing.assert_array_equal(r1.latency, r2.latency)
    if r1.dropped is None:
        assert r2.dropped is None
    else:
        np.testing.assert_array_equal(r1.dropped, r2.dropped)


def test_session_cache_keyed_on_faults():
    """A session that simulated under faults must afterwards return the
    fault-free result bit-identically — the cone cache keys the fault
    dimension (KEY01's ``_fault_key``), so no stale-entry collision."""
    pipe, store = _pipeline(2)
    cfg = _config(pipe)
    arr = gamma_trace(80.0, 1.0, 3.0, seed=11)
    sess = SimEngine(pipe, store, seed=0).session(arr, slo_s=SLO)
    clean = sess.simulate(cfg)
    fs = FaultSchedule([straggle("s0_m0", 0.0, 3.0, 5.0)], seed=2)
    faulty = sess.simulate(cfg, fault_schedules=fs)
    assert not np.array_equal(clean.latency, faulty.latency)
    again = sess.simulate(cfg)
    np.testing.assert_array_equal(clean.latency, again.latency)
    faulty2 = sess.simulate(cfg, fault_schedules=fs)
    np.testing.assert_array_equal(faulty.latency, faulty2.latency)


# -- fault semantics ---------------------------------------------------------


def test_crash_loses_capacity_and_all_dead_starves():
    """Crashing one of two replicas degrades throughput; crashing both
    starves the stage — unserved queries carry the far-future sentinel
    (not Inf: they are stuck, not shed) and are not marked dropped."""
    pipe, store = _pipeline(1)
    cfg = _config(pipe, replicas=2)
    arr = gamma_trace(120.0, 1.0, 3.0, seed=5)
    eng = SimEngine(pipe, store, seed=0)
    base = eng.simulate(cfg, arr, slo_s=SLO)

    one = eng.simulate(cfg, arr, slo_s=SLO, fault_schedules=FaultSchedule(
        [crash("s0_m0", 0.5)], seed=0))
    assert one.latency.mean() > base.latency.mean()
    assert np.isfinite(one.latency).all()

    dead = eng.simulate(cfg, arr, slo_s=SLO, fault_schedules=FaultSchedule(
        [crash("s0_m0", 0.5, n=2)], seed=0))
    starved = dead.latency > 1e17
    assert starved.any()
    assert dead.dropped is None or not dead.dropped[starved].any()

    # a replacement replica (the recovery path's control event) un-starves
    healed = eng.simulate(
        cfg, arr, replica_schedules={"s0_m0": [(1.0, 1)]}, slo_s=SLO,
        fault_schedules=FaultSchedule([crash("s0_m0", 0.5, n=2)], seed=0))
    assert np.isfinite(healed.latency).all()


def test_transient_retry_recovers_and_recovery_off_drops():
    """An error window that closes lets retries land (finite latencies);
    with recovery disabled the same faults drop every affected query."""
    pipe, store = _pipeline(1)
    cfg = _config(pipe)
    arr = np.sort(gamma_trace(60.0, 1.0, 0.4, seed=7))
    fs_on = FaultSchedule(
        [transient("s0_m0", 0.0, 0.5, 1.0)], seed=1,
        recovery=RecoveryPolicy(max_attempts=8, backoff_s=0.2,
                                backoff_mult=2.0))
    eng = SimEngine(pipe, store, seed=0)
    res_on = eng.simulate(cfg, arr, slo_s=SLO, fault_schedules=fs_on)
    assert np.isfinite(res_on.latency).all()

    fs_off = FaultSchedule([transient("s0_m0", 0.0, 0.5, 1.0)], seed=1,
                           recovery=RecoveryPolicy(enabled=False))
    res_off = eng.simulate(cfg, arr, slo_s=SLO, fault_schedules=fs_off)
    assert res_off.dropped is not None and res_off.dropped.all()


def test_straggle_window_slows_only_inside():
    pipe, store = _pipeline(1)
    cfg = _config(pipe, replicas=4)
    arr = np.arange(0.0, 4.0, 0.05)           # sparse: no queueing
    eng = SimEngine(pipe, store, seed=0)
    base = eng.simulate(cfg, arr, slo_s=SLO)
    fs = FaultSchedule([straggle("s0_m0", 1.0, 2.0, 10.0)], seed=0)
    slow = eng.simulate(cfg, arr, slo_s=SLO, fault_schedules=fs)
    inside = (arr >= 1.0) & (arr < 2.0)
    assert (slow.latency[inside] > base.latency[inside]).all()
    np.testing.assert_allclose(slow.latency[arr < 0.9],
                               base.latency[arr < 0.9])


# -- closed-loop recovery ----------------------------------------------------


@pytest.fixture(scope="module")
def planned(image_pipeline):
    pipe, store = image_pipeline
    sample = gamma_trace(lam=150.0, cv=1.0, duration_s=60.0, seed=0)
    res = Planner(pipe, store).plan(sample, SLO)
    assert res.feasible
    est = Estimator(pipe, store)
    info = TunerPlanInfo.from_plan(pipe, res.config, store, sample,
                                   est.service_time(res.config))
    return pipe, store, res, info


def _crashed_stage(res):
    # crash a stage that planned >= 2 replicas if one exists
    return max(res.config.stage_configs,
               key=lambda s: res.config[s].replicas)


def test_tuner_replaces_crashed_capacity(planned):
    """The ClosedLoopTuner reads capacity loss off telemetry (alive <
    provisioned) and emits replacement ups through the ordinary
    ControlEvent path; the final fleet is restored to plan."""
    pipe, store, res, info = planned
    stage = _crashed_stage(res)
    arr = gamma_trace(150.0, 1.0, 40.0, seed=13)
    fs = FaultSchedule([crash(stage, 10.0)], seed=0)
    tuner = ClosedLoopTuner(info)
    out = ControlLoopSession(pipe, store, res.config, SLO).run(
        arr, tuner, faults=fs)
    ups = [e for e in out.events
           if e.stage == stage and e.kind == "up"]
    assert ups, "no replacement up was emitted for the crashed stage"
    # final fleet (plan + control deltas) minus the crash loss == plan
    final = res.config[stage].replicas + sum(
        d for (_, d) in out.replica_schedules.get(stage, ()))
    assert final - 1 >= res.config[stage].replicas


def test_tuner_failure_recovery_off(planned):
    """failure_recovery=False: the same crash provisions strictly fewer
    replacement replicas for the crashed stage than recovery-on."""
    pipe, store, res, info = planned
    stage = _crashed_stage(res)
    arr = gamma_trace(150.0, 1.0, 40.0, seed=13)
    fs = FaultSchedule([crash(stage, 10.0)], seed=0)
    out_off = ControlLoopSession(pipe, store, res.config, SLO).run(
        arr, ClosedLoopTuner(info, failure_recovery=False), faults=fs)
    out_on = ControlLoopSession(pipe, store, res.config, SLO).run(
        arr, ClosedLoopTuner(info), faults=fs)

    def ups(out):
        return sum(int(e.value) for e in out.events
                   if e.stage == stage and e.kind == "up")

    assert ups(out_on) > ups(out_off)


# -- planner headroom --------------------------------------------------------


def test_planner_failure_headroom(image_pipeline):
    """failure_headroom=1 plans survive losing one replica from any
    single stage, at a cost no lower than the headroom-free plan."""
    pipe, store = image_pipeline
    sample = gamma_trace(lam=150.0, cv=1.0, duration_s=60.0, seed=0)
    base = Planner(pipe, store).plan(sample, SLO)
    hard_planner = Planner(pipe, store, failure_headroom=1)
    hard = hard_planner.plan(sample, SLO)
    assert hard.feasible
    assert hard.config.cost_per_hr() >= base.config.cost_per_hr()
    for s in pipe.stages:
        assert hard.config[s].replicas >= base.config[s].replicas
        probe = hard.config.copy()
        if probe[s].replicas > 1:
            probe[s].replicas -= 1
            assert hard_planner._feasible(probe, SLO), (
                f"headroom plan not resilient to losing one {s} replica")
