"""Tuner: traffic-envelope detection + scaling rules (§5)."""

import numpy as np
import pytest

from repro.core.estimator import Estimator
from repro.core.planner import Planner
from repro.core.tuner import Tuner, TunerPlanInfo, run_tuner_offline
from repro.serving.cluster import LiveClusterSim
from repro.workload.generator import gamma_trace, rate_ramp_trace, cv_ramp_trace

SLO = 0.15


@pytest.fixture(scope="module")
def planned_image(image_pipeline):
    pipe, store = image_pipeline
    sample = gamma_trace(lam=150.0, cv=1.0, duration_s=60.0, seed=0)
    res = Planner(pipe, store).plan(sample, SLO)
    assert res.feasible
    est = Estimator(pipe, store)
    info = TunerPlanInfo.from_plan(pipe, res.config, store, sample,
                                   est.service_time(res.config))
    return pipe, store, res, info, sample


def test_planned_rate_recovers_planned_replicas(planned_image):
    """k_m formula at r_max = lambda_plan returns the planned count."""
    pipe, store, res, info, sample = planned_image
    lam = sample.size / (sample.max() - sample.min())
    for stage in pipe.stages:
        k = np.ceil(lam * info.scale_factors[stage]
                    / (info.mu[stage] * info.rho[stage]))
        assert int(k) == res.config[stage].replicas


def test_no_scaling_on_planned_workload(planned_image):
    """Same-distribution traffic must not trigger scale-up oscillation."""
    pipe, store, res, info, sample = planned_image
    tuner = Tuner(info)
    same = gamma_trace(lam=150.0, cv=1.0, duration_s=60.0, seed=0)
    run_tuner_offline(tuner, same)
    ups = [e for e in tuner.events if e[1] == "up"]
    assert not ups


def test_scale_up_on_rate_increase(planned_image):
    pipe, store, res, info, sample = planned_image
    tuner = Tuner(info)
    ramp = rate_ramp_trace(150, 300, 1.0, pre_s=20, ramp_s=10, post_s=30,
                           seed=2)
    run_tuner_offline(tuner, ramp)
    assert any(e[1] == "up" for e in tuner.events)
    for stage in tuner.current:
        assert tuner.current[stage] >= res.config[stage].replicas or \
            any(e[1] == "down" for e in tuner.events)


def test_scale_up_on_burstiness_increase(planned_image):
    """Fig. 11: CV change at constant mean rate is detected."""
    pipe, store, res, info, sample = planned_image
    tuner = Tuner(info)
    ramp = cv_ramp_trace(150, 1.0, 6.0, pre_s=20, ramp_s=10, post_s=30,
                         seed=3)
    run_tuner_offline(tuner, ramp)
    assert any(e[1] == "up" for e in tuner.events)


def test_scale_down_after_drop_with_hysteresis(planned_image):
    pipe, store, res, info, sample = planned_image
    tuner = Tuner(info)
    # 30 s at planned rate then near-silence
    head = gamma_trace(150, 1.0, 30, seed=4)
    tail = 30.0 + gamma_trace(2.0, 1.0, 60, seed=5)
    trace = np.concatenate([head, tail])
    run_tuner_offline(tuner, trace)
    downs = [e for e in tuner.events if e[1] == "down"]
    assert downs
    # hysteresis: no down event within 15 s of a previous change
    times = sorted(e[0] for e in tuner.events)
    for t_prev, t_next in zip(times, times[1:]):
        ev_next = [e for e in tuner.events if e[0] == t_next]
        if all(e[1] == "down" for e in ev_next):
            assert t_next - t_prev >= 15.0 - 1e-9 or t_next == t_prev


def test_tuner_maintains_slo_on_ramp(planned_image):
    """End-to-end (Fig. 10): with the tuner, the miss rate on a rate ramp
    stays near zero; without it, the static plan misses."""
    pipe, store, res, info, sample = planned_image
    ramp = rate_ramp_trace(150, 250, 1.0, pre_s=30, ramp_s=30, post_s=60,
                           seed=6)
    sim = LiveClusterSim(pipe, store, res.config, SLO)
    static = sim.run(ramp)
    tuned = sim.run(ramp, schedule_fn=lambda arr: run_tuner_offline(
        Tuner(TunerPlanInfo.from_plan(
            pipe, res.config, store, sample,
            Estimator(pipe, store).service_time(res.config))), arr))
    assert tuned.miss_rate <= static.miss_rate
    # residual misses are the detect->activate staircase during the ramp
    # (5 s replica activation per §5); benchmarks/fig10 measures
    # 0.001-0.04 across ramp speeds, matching the paper's transient
    assert tuned.miss_rate < 0.05


def test_scale_down_reduces_cost(planned_image):
    pipe, store, res, info, sample = planned_image
    head = gamma_trace(150, 1.0, 30, seed=7)
    tail = 30.0 + gamma_trace(2.0, 1.0, 120, seed=8)
    trace = np.concatenate([head, tail])
    sim = LiveClusterSim(pipe, store, res.config, SLO)
    static = sim.run(trace)
    tuned = sim.run(trace, schedule_fn=lambda arr: run_tuner_offline(
        Tuner(info), arr))
    assert tuned.total_cost() < static.total_cost()


def test_no_premature_scale_down_at_startup(planned_image):
    """Regression (EXPERIMENTS.md §Paper-validation): a 1-second-old trace
    must not be read as a full 30 s observation window — the tuner once
    halved the fleet at t=1 s and missed 99% of queries on flat traces."""
    pipe, store, res, info, sample = planned_image
    tuner = Tuner(info)
    flat = gamma_trace(150, 1.0, 40, seed=123)
    for t in (1.0, 2.0, 5.0, 10.0):
        tuner.step(t, flat[flat <= t])
    downs = [e for e in tuner.events if e[1] == "down"]
    assert not downs, downs


class _ScriptedTuner:
    """Minimal tuner stand-in: replays a scripted {t: {stage: k}} plan."""

    def __init__(self, initial, script):
        self.current = dict(initial)
        self.script = script

    def step(self, now, arrivals_so_far):
        for t, targets in self.script.items():
            if abs(now - t) < 1e-9:
                self.current.update(targets)
        return dict(self.current)


def test_offline_schedule_sorted_with_overlapping_up_down():
    """Regression: a scale-up lands at t + activation_delay_s but a
    scale-down lands at t, so a down issued within the activation window
    of an up used to yield an UNSORTED event list — violating the sorted
    (t, +/-1) contract `_ReplicaPool.apply_events` assumes."""
    tuner = _ScriptedTuner({"m": 2}, {1.0: {"m": 4}, 3.0: {"m": 1}})
    sched = run_tuner_offline(tuner, np.arange(0.0, 10.0, 0.5),
                              activation_delay_s=5.0)
    evs = sched["m"]
    # up of +2 issued at t=1 (lands at 6.0), down of -3 issued at t=3
    assert (3.0, -3) in evs and (6.0, 2) in evs
    assert evs == sorted(evs, key=lambda e: e[0])


def test_offline_schedule_sorted_for_all_stages_on_real_tuner(planned_image):
    """The real tuner's schedules honor the sorted contract too, even with
    an activation delay longer than the downscale hysteresis."""
    pipe, store, res, info, sample = planned_image
    head = gamma_trace(150, 1.0, 30, seed=4)
    tail = 30.0 + gamma_trace(2.0, 1.0, 60, seed=5)
    trace = np.concatenate([head, tail])
    for delay in (5.0, 20.0):
        sched = run_tuner_offline(Tuner(info), trace,
                                  activation_delay_s=delay)
        for stage, evs in sched.items():
            assert evs == sorted(evs, key=lambda e: e[0]), (stage, delay)


def test_plan_info_degenerate_sample_traces(planned_image):
    """Regression: lam = n / (max - min) diverged on 0-1 arrival traces
    (and on simultaneous arrivals). Degenerate samples now read as "no
    planned rate" with rho = 1 (scale exactly to demand) — NOT a tiny
    rho floor, which would make _replicas_for_rate (divides by rho)
    request millions of replicas on the first real traffic."""
    pipe, store, res, info, sample = planned_image
    est = Estimator(pipe, store)
    st = est.service_time(res.config)
    for trace in (np.zeros(0), np.array([1.0]), np.array([2.0, 2.0, 2.0])):
        got = TunerPlanInfo.from_plan(pipe, res.config, store, trace, st)
        for stage in pipe.stages:
            assert got.rho[stage] == 1.0, (stage, got.rho)
        # a tuner built from the degenerate plan must still function and
        # scale to (bounded) real demand: k = ceil(rate * s / mu)
        tuner = Tuner(got)
        burst = np.sort(np.random.default_rng(0).uniform(0, 1.0, 100))
        target = tuner.step(1.0, burst)
        for stage, k in target.items():
            need = np.ceil(100.0 * got.scale_factors[stage]
                           / got.mu[stage])
            assert 1 <= k <= max(need * 4, res.config[stage].replicas * 4), \
                (stage, k)


def test_flat_trace_stays_near_plan(planned_image):
    """A fresh same-law flat trace must not drift far from the planned
    replica counts (envelope detection tolerates sampling noise)."""
    pipe, store, res, info, sample = planned_image
    tuner = Tuner(info)
    flat = gamma_trace(150, 1.0, 90, seed=124)
    run_tuner_offline(tuner, flat)
    for stage, k in tuner.current.items():
        planned = res.config[stage].replicas
        assert k <= planned + max(2, planned // 2), (stage, k, planned)
