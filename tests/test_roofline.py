"""Roofline analysis: HLO collective parsing + term arithmetic."""

import pytest

from repro.core.hardware import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.analysis import (
    RooflineReport,
    collective_bytes_from_hlo,
    model_flops_estimate,
    roofline_terms,
)

HLO = """
HloModule jit_train_step

ENTRY %main {
  %p0 = bf16[256,4096,2048]{2,1,0} parameter(0)
  %ag = bf16[256,4096,2048]{2,1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024,512]{1,0} all-reduce(%x), to_apply=%add
  %ar2.1 = f32[128]{0} all-reduce(%y), to_apply=%add
  %rs = bf16[16,1024]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[8,64,128]{2,1,0} all-to-all(%w), dimensions={0}
  %cp = u32[4]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ags = (bf16[2,2]{1,0}, bf16[2,2]{1,0}) all-gather-start(%q)
  %normal = f32[512,512]{1,0} dot(%a, %b)
  ROOT %t = tuple(%ar)
}
"""


def test_collective_parsing_kinds():
    got = collective_bytes_from_hlo(HLO)
    assert got["all-gather"] == 256 * 4096 * 2048 * 2 + 2 * 2 * 2 * 2
    assert got["all-reduce"] == 1024 * 512 * 4 + 128 * 4
    assert got["reduce-scatter"] == 16 * 1024 * 2
    assert got["all-to-all"] == 8 * 64 * 128 * 2
    assert got["collective-permute"] == 4 * 4


def test_done_lines_not_double_counted():
    hlo = """
  %s = bf16[128,128]{1,0} all-gather-start(%p)
  %d = bf16[128,128]{1,0} all-gather-done(%s)
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-gather"] == 128 * 128 * 2


def test_non_collective_ops_ignored():
    hlo = "%x = f32[64]{0} add(%a, %b)\n%y = f32[64]{0} dot(%a, %b)"
    assert sum(collective_bytes_from_hlo(hlo).values()) == 0


def test_roofline_terms_arithmetic():
    """hlo_* values are PER-DEVICE (cost_analysis describes the SPMD
    partitioned program), so terms divide by a single chip's peak."""
    rep = RooflineReport(
        arch="a", shape="s", mesh="single", chips=256,
        hlo_flops=1e15, hlo_bytes=1e12, collective_bytes=1e10,
        collectives_by_kind={}, model_flops=0.2e18)
    assert rep.t_compute == pytest.approx(1e15 / PEAK_FLOPS_BF16)
    assert rep.t_memory == pytest.approx(1e12 / HBM_BW)
    assert rep.t_collective == pytest.approx(1e10 / ICI_BW)
    assert rep.bottleneck == "compute"
    assert rep.total_hlo_flops == pytest.approx(256e15)
    assert rep.useful_flops_ratio == pytest.approx(0.2e18 / 256e15)
    assert rep.step_time == rep.t_compute


def test_roofline_analytic_floors():
    """Scan bodies are counted once by cost_analysis; the analytic floors
    (model_flops/chips, analytic_bytes) take over when larger."""
    rep = RooflineReport(
        arch="a", shape="s", mesh="single", chips=256,
        hlo_flops=1e12, hlo_bytes=1e9, collective_bytes=0.0,
        collectives_by_kind={}, model_flops=2.56e18,
        analytic_bytes=5e12)
    assert rep.t_compute == pytest.approx(1e16 / PEAK_FLOPS_BF16)
    assert rep.t_memory == pytest.approx(5e12 / HBM_BW)


def test_loop_trip_count_correction():
    """Collectives inside scan bodies are multiplied by the trip count."""
    from repro.roofline.analysis import collective_bytes_from_hlo
    hlo = """
HloModule m

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%x), to_apply=%add
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %limit = s32[] constant(12)
  %cmp = pred[] compare(%i, %limit), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %w = (s32[], f32[64]) while(%init), condition=%cond.1, body=%body.1
  %ar2 = f32[128]{0} all-reduce(%y), to_apply=%add
}
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-reduce"] == 12 * 64 * 4 + 128 * 4


def test_bottleneck_switches():
    rep = RooflineReport("a", "s", "m", 1, hlo_flops=1.0, hlo_bytes=1e12,
                         collective_bytes=0.0, collectives_by_kind={},
                         model_flops=1.0)
    assert rep.bottleneck == "memory"
    rep2 = RooflineReport("a", "s", "m", 1, hlo_flops=1.0, hlo_bytes=1.0,
                          collective_bytes=1e12, collectives_by_kind={},
                          model_flops=1.0)
    assert rep2.bottleneck == "collective"


def test_model_flops_estimate():
    assert model_flops_estimate(1e9, 1e6, "train") == 6e15
    assert model_flops_estimate(1e9, 1e6, "decode") == 2e15


def test_roofline_terms_from_cost_analysis():
    rep = roofline_terms("a", "s", "single", 4,
                         cost_analysis={"flops": 100.0,
                                        "bytes accessed": 50.0},
                         hlo_text=HLO, model_flops=90.0)
    assert rep.hlo_flops == 100.0
    assert rep.collective_bytes > 0
